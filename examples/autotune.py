"""Mist auto-tuning walkthrough (the paper's core workflow): compare
restricted search spaces against full co-optimization for an assigned
architecture on the production mesh, and show the per-stage heterogeneous
plan Mist finds.

    PYTHONPATH=src python examples/autotune.py [--arch qwen1.5-32b]
"""
import argparse

from repro.configs.base import ShapeConfig, get_arch
from repro.core.costmodel import estimate_plan
from repro.core.tuner import tune


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = ShapeConfig("t", args.seq, args.global_batch, "train")
    print(f"{cfg.name}: {cfg.param_count() / 1e9:.1f}B params on "
          f"{args.devices} chips, global batch {args.global_batch}\n")
    print(f"{'space':10s} {'step(s)':>9s} {'samples/s':>10s} "
          f"{'speedup':>8s}  plan")

    base = None
    for space in ("none", "megatron", "ckpt", "zero", "offload", "mist"):
        rep = tune(cfg, shape, args.devices, space=space,
                   stage_counts=(1, 2, 4), grad_accums=(2, 4, 8, 16))
        if rep.plan is None:
            print(f"{space:10s} {'OOM':>9s}")
            continue
        if base is None:
            base = rep.objective
        s0 = rep.plan.stages[0]
        desc = (f"S={rep.best_S} G={rep.best_G} dp={s0.dp} tp={s0.tp} "
                f"zero={s0.zero} "
                f"ckpt={min(s0.ckpt_layers, s0.layers)}/{s0.layers} "
                f"oo={s0.oo:.2f} ao={s0.ao:.2f}")
        print(f"{space:10s} {rep.objective:9.3f} "
              f"{rep.throughput_samples:10.2f} "
              f"{base / rep.objective:7.2f}x  {desc}")

    # show the winning plan end-to-end estimate
    rep = tune(cfg, shape, args.devices, space="mist",
               stage_counts=(1, 2, 4), grad_accums=(2, 4, 8, 16))
    if rep.plan is not None:
        est = estimate_plan(cfg, shape, rep.plan)
        print(f"\nbest plan stage detail "
              f"(mem/chip {est['mem_peak_max'] / 2**30:.1f} GiB):")
        for i, st in enumerate(rep.plan.stages):
            print(f"  stage {i}: layers={st.layers} b={st.micro_batch} "
                  f"dp={st.dp} tp={st.tp} zero={st.zero} "
                  f"ckpt={min(st.ckpt_layers, st.layers)} wo={st.wo:.2f} "
                  f"go={st.go:.2f} oo={st.oo:.2f} ao={st.ao:.2f}")


if __name__ == "__main__":
    main()
