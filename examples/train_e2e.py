"""End-to-end training driver: a ~100M-parameter LLaMa-style model trained
for a few hundred steps on the host devices, with the full production
substrate — Mist-tuned execution knobs, packed data pipeline, async sharded
checkpoints, fault-tolerant loop, and resume.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ArchConfig, register
from repro.core.plan import single_stage_plan
from repro.launch.mesh import make_host_mesh
from repro.models.zoo import build_model
from repro.training.data import BatchSpec, SyntheticLM
from repro.training.loop import LoopConfig, TrainLoop
from repro.training.step import init_sharded_state, make_train_step

# ~100M params: 12 x 512 with a 32k vocab
M100 = ArchConfig(
    name="llama-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=1536, vocab_size=32000,
    norm_type="rmsnorm", act="silu", mlp_gated=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    model = build_model(M100)
    n_params = M100.param_count()
    print(f"model: {M100.name}, {n_params / 1e6:.1f}M params")

    mesh = make_host_mesh(1, 1)
    plan = single_stage_plan(M100.num_layers, dp=1, tp=1,
                             micro_batch=args.batch // 2, grad_accum=2,
                             zero=0, ckpt_layers=M100.num_layers // 2)
    data = SyntheticLM(BatchSpec(global_batch=args.batch, seq_len=args.seq,
                                 vocab_size=M100.vocab_size), seed=7)

    with compat.set_mesh(mesh):
        step = make_train_step(model, plan, mesh)
        state, shardings = init_sharded_state(model, plan, mesh,
                                              jax.random.PRNGKey(0))
        start = 0
        if args.resume:
            from repro.training.checkpoint import Checkpointer
            ck = Checkpointer(args.ckpt_dir)
            if ck.latest_step() is not None:
                start, state, _ = ck.restore(shardings=shardings)
                print(f"resumed from step {start}")

        def batches(i):
            b = data.batch(i)
            return {k: jnp.asarray(v) for k, v in b.items()}

        loop = TrainLoop(step.fn, state, batches, ckpt_dir=args.ckpt_dir,
                         cfg=LoopConfig(total_steps=args.steps,
                                        ckpt_every=50, log_every=25),
                         state_shardings=shardings,
                         meta={"arch": M100.name, "plan": plan.to_json()})
        loop._step = start
        t0 = time.time()
        stats = loop.run()
        dt = time.time() - t0

    tok_s = stats.steps_done * args.batch * args.seq / dt
    print(f"\ntrained {stats.steps_done} steps in {dt:.0f}s "
          f"({tok_s / 1e3:.1f}K tokens/s on host CPU)")
    k = max(1, len(stats.losses) // 10)
    print("loss curve:", " ".join(f"{np.mean(stats.losses[i:i + k]):.3f}"
                                  for i in range(0, len(stats.losses), k)))
    assert stats.losses[-1] < stats.losses[0], "loss must decrease"
    print(f"checkpoints under {args.ckpt_dir}: resume with --resume")


if __name__ == "__main__":
    main()
