"""Batched serving example: prefill a batch of prompts, then decode with a
shared KV cache — exercising the same serve_step the decode-shape dry-run
cells lower.

    PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-2.7b]

``--continuous`` serves the same prompts through the continuous-batching
engine (paged KV cache, docs/continuous-batching.md) with fewer decode
slots than requests, and asserts every request's tokens equal the static
batch's rows — batching policy must not move numerics.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import single_stage_plan
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.models.zoo import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b",
                    help="any assigned arch (reduced config is served)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--continuous", action="store_true",
                    help="also serve via the continuous-batching engine "
                         "and assert per-request token identity")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    mesh = make_host_mesh(1, 1)
    plan = single_stage_plan(cfg.num_layers, dp=1, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0)
    max_len = args.prompt_len + args.gen
    if args.continuous and max_len % args.page_size:
        raise SystemExit(f"--page-size {args.page_size} must divide "
                         f"prompt-len + gen = {max_len}")
    with compat.set_mesh(mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(1)
        prompts = jax.random.randint(
            rng, (args.batch, args.prompt_len), 0,
            cfg.vocab_size).astype(jnp.int32)
        t0 = time.time()
        toks = generate(model, params, prompts, args.gen, mesh, plan)
        dt = time.time() - t0
        if args.continuous:
            from repro.serving import ContinuousBatchingEngine
            eng = ContinuousBatchingEngine(
                model, params, plan, mesh, slots=args.slots,
                max_len=max_len, page_size=args.page_size)
            for i in range(args.batch):
                eng.submit({"tokens": prompts[i:i + 1]}, args.gen, rid=i)
            t1 = time.time()
            res = eng.run()
            dt_c = time.time() - t1
    total = args.batch * args.gen
    print(f"{cfg.name}: generated {total} tokens for {args.batch} requests "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s, host CPU)")
    for i in range(min(2, args.batch)):
        print(f"  request {i}: {np.asarray(toks[i])[:12]} ...")
    if args.continuous:
        ref = np.asarray(toks)
        for i in range(args.batch):
            assert np.array_equal(res[i], ref[i]), \
                f"continuous tokens diverged from static (request {i})"
        print(f"  continuous ({args.slots} slots, page_size "
              f"{args.page_size}): {total} tokens in {dt_c:.2f}s "
              f"({total / dt_c:.1f} tok/s, {eng.steps_run} decode steps); "
              f"all {args.batch} requests token-identical to the static "
              f"batch")


if __name__ == "__main__":
    main()
