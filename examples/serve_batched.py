"""Batched serving example: prefill a batch of prompts, then decode with a
shared KV cache — exercising the same serve_step the decode-shape dry-run
cells lower.

    PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-2.7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import single_stage_plan
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.models.zoo import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b",
                    help="any assigned arch (reduced config is served)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    mesh = make_host_mesh(1, 1)
    plan = single_stage_plan(cfg.num_layers, dp=1, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0)
    with compat.set_mesh(mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(1)
        prompts = jax.random.randint(
            rng, (args.batch, args.prompt_len), 0,
            cfg.vocab_size).astype(jnp.int32)
        t0 = time.time()
        toks = generate(model, params, prompts, args.gen, mesh, plan)
        dt = time.time() - t0
    total = args.batch * args.gen
    print(f"{cfg.name}: generated {total} tokens for {args.batch} requests "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s, host CPU)")
    for i in range(min(2, args.batch)):
        print(f"  request {i}: {np.asarray(toks[i])[:12]} ...")


if __name__ == "__main__":
    main()
