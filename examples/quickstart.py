"""Quickstart: tune a Mist plan for an assigned architecture, inspect it,
and run a few training steps of the reduced config locally.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ShapeConfig, get_arch
from repro.core.costmodel import estimate_plan
from repro.core.plan import single_stage_plan
from repro.core.tuner import tune
from repro.launch.mesh import make_host_mesh
from repro.models.zoo import build_model
from repro.training.step import init_sharded_state, make_train_step


def main():
    # ---- 1. auto-tune a training plan for the production target ----------
    arch = get_arch("granite-3-8b")
    shape = ShapeConfig("train", seq_len=4096, global_batch=64, kind="train")
    print(f"tuning {arch.name} ({arch.param_count() / 1e9:.1f}B params) "
          f"for 32 TPU-v5e chips, global batch {shape.global_batch} ...")
    report = tune(arch, shape, n_devices=32, space="mist",
                  stage_counts=(1, 2), grad_accums=(2, 4, 8))
    print(f"  evaluated {report.n_points} configurations in "
          f"{report.tune_seconds:.1f}s")
    print(f"  predicted step time {report.objective:.2f}s "
          f"({report.throughput_tokens / 1e6:.2f}M tokens/s)")
    print(report.plan.to_json())

    est = estimate_plan(arch, shape, report.plan)
    print(f"  modeled peak memory/chip: "
          f"{est['mem_peak_max'] / 2**30:.2f} GiB (fits: {est['fits']})")

    # ---- 2. train the reduced config for a few steps locally -------------
    rcfg = arch.reduced()
    model = build_model(rcfg)
    mesh = make_host_mesh(1, 1)
    tuned = report.plan.stages[0]
    plan = single_stage_plan(rcfg.num_layers, dp=1, tp=1, micro_batch=4,
                             grad_accum=2, zero=tuned.zero,
                             ckpt_layers=min(tuned.ckpt_layers,
                                             rcfg.num_layers))
    with compat.set_mesh(mesh):
        step = make_train_step(model, plan, mesh, donate=False)
        state, _ = init_sharded_state(model, plan, mesh,
                                      jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        batch = {
            "tokens": jax.random.randint(key, (8, 128), 0, rcfg.vocab_size),
            "labels": jax.random.randint(key, (8, 128), 0, rcfg.vocab_size),
        }
        print("training the reduced config (same code paths, tiny dims):")
        for i in range(5):
            state, metrics = step.fn(state, batch)
            print(f"  step {i}: loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
