"""Paper Fig. 13: speedup breakdown by incrementally enlarging the search
space (Megatron baseline -> +CKPT -> +ZeRO -> +offload -> full Mist ->
+imbalance awareness), GPT on 8/16/32 chips, normalized to the Megatron
space."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import FAST_TUNE, emit, gpt_config, train_shape
from repro.core.tuner import tune

STEPS = ("megatron", "ckpt", "zero", "offload", "mist")


def run(size: str = "6.7b", dev_counts=(8, 16, 32), gbs: int = 128
        ) -> List[str]:
    rows = []
    for n_dev in dev_counts:
        cfg = gpt_config(size)
        shape = train_shape(gbs, seq=2048)
        base = None
        for space in STEPS:
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE)
            dt = (time.perf_counter() - t0) * 1e6
            if rep.plan is None:
                rows.append(emit(f"breakdown/{n_dev}dev/{space}", dt, "OOM"))
                continue
            if base is None:
                base = rep.objective
            rows.append(emit(
                f"breakdown/{n_dev}dev/{space}", dt,
                f"rel_speedup={base / rep.objective:.3f}x "
                f"thpt={rep.throughput_samples:.2f}samp/s"))
        # imbalance-awareness ablation on the full space
        t0 = time.perf_counter()
        blind = tune(cfg, shape, n_dev, space="mist",
                     imbalance_aware=False, **FAST_TUNE)
        dt = (time.perf_counter() - t0) * 1e6
        if blind.plan is not None and base is not None:
            # evaluate the blind plan under the true (imbalance-aware) model
            from repro.core.costmodel import estimate_plan
            t_blind = estimate_plan(cfg, shape, blind.plan)["t_step"]
            rows.append(emit(
                f"breakdown/{n_dev}dev/mist-imbalance-blind", dt,
                f"rel_speedup={base / t_blind:.3f}x"))
    return rows


if __name__ == "__main__":
    run()
