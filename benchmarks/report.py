"""Render the §Dry-run / §Roofline markdown tables from results/dryrun JSONs
and patch them into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import pathlib
import re
from typing import List, Optional

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"

ARCH_ORDER = ["zamba2-2.7b", "qwen2-72b", "minicpm3-4b", "granite-3-8b",
              "qwen1.5-32b", "dbrx-132b", "qwen2-moe-a2.7b", "xlstm-1.3b",
              "internvl2-1b", "whisper-small"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _cells(mesh: str, tag: str = "") -> List[dict]:
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = RESULTS / f"{arch}_{shape}_{mesh}{tag}.json"
            if p.exists():
                out.append(json.loads(p.read_text()))
    return out


def table(mesh: str, tag: str = "") -> str:
    rows = ["| arch | shape | t_comp (s) | t_mem art/TPU (s) | "
            "t_coll art/TPU (s) | bottleneck | frac art/TPU | useful | "
            "GiB/chip art/TPU | fits | compile (s) |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for rec in _cells(mesh, tag):
        if rec.get("skipped"):
            continue
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"FAILED | — | — | — | — | — |")
            continue
        r = rec["roofline"]
        m = rec["memory"]
        an = m.get("analytic_bytes")
        fits = m.get("fits_16GiB_analytic", m["fits_16GiB"])
        tm_t = r.get("t_memory_analytic")
        tc_t = r.get("t_collective_tpu")
        fr_t = r.get("roofline_fraction_tpu")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['t_compute']:.2f} | "
            f"{r['t_memory']:.2f}/"
            + (f"{tm_t:.2f}" if tm_t is not None else "—") + " | "
            f"{r['t_collective']:.2f}/"
            + (f"{tc_t:.2f}" if tc_t is not None else "—") + " | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f}/"
            + (f"{fr_t:.3f}" if fr_t is not None else "—") + " | "
            f"{r['useful_ratio']:.2f} | "
            f"{m['device_total_bytes'] / 2**30:.1f}/"
            + (f"{an / 2**30:.1f}" if an else "—")
            + f" | {'✓' if fits else '✗'} | {rec['compile_s']:.0f} |")
    n = len([r for r in _cells(mesh, tag) if r.get("ok")])
    nskip = len([r for r in _cells(mesh, tag) if r.get("skipped")])
    rows.append("")
    rows.append(f"({n} compiled cells + {nskip} spec-mandated skips on "
                f"mesh {mesh}{' tag ' + tag if tag else ''})")
    return "\n".join(rows)


def patch_experiments() -> None:
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    for marker, mesh in (("<!-- ROOFLINE_TABLE_SP -->", "16x16"),
                         ("<!-- ROOFLINE_TABLE_MP -->", "2x16x16")):
        tbl = table(mesh)
        block = f"{marker}\n{tbl}"
        # replace marker + any previously generated table after it
        pat = re.escape(marker) + r"(\n\|.*?\n\n\(\d+ compiled[^\n]*\))?"
        text = re.sub(pat, block, text, count=1, flags=re.S)
    exp.write_text(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    patch_experiments()
