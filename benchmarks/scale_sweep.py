"""Paper Fig. 14 (model-depth sweep) + Fig. 15 (global-batch sweep):
robustness of the co-optimization win across scales, GPT-22B-class on 32
chips (Fig. 15) and depth-varied GPT on 32 chips (Fig. 14)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import FAST_TUNE, emit, gpt_config, train_shape
from repro.core.tuner import tune


def run_depth(depths=(16, 32, 48, 64, 80), n_dev: int = 32, gbs: int = 64
              ) -> List[str]:
    rows = []
    for L in depths:
        cfg = gpt_config("6.7b").replace(name=f"gpt3-{L}L", num_layers=L)
        shape = train_shape(gbs, seq=2048)
        res = {}
        for space in ("megatron", "ckpt", "mist"):
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE)
            dt = (time.perf_counter() - t0) * 1e6
            res[space] = rep.objective if rep.plan else float("inf")
            rows.append(emit(
                f"scale/depth{L}/{space}", dt,
                f"thpt={rep.throughput_samples:.2f}samp/s"
                if rep.plan else "OOM"))
        if res["megatron"] < float("inf"):
            rows.append(emit(
                f"scale/depth{L}/speedup", 0.0,
                f"mist_vs_megatron={res['megatron'] / res['mist']:.3f}x"))
    return rows


def run_batch(batches=(32, 64, 128, 256, 512), n_dev: int = 32,
              size: str = "13b") -> List[str]:
    rows = []
    for gbs in batches:
        cfg = gpt_config(size)
        shape = train_shape(gbs, seq=2048)
        res = {}
        for space in ("megatron", "mist"):
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE)
            dt = (time.perf_counter() - t0) * 1e6
            res[space] = rep.objective if rep.plan else float("inf")
            rows.append(emit(
                f"scale/batch{gbs}/{space}", dt,
                f"thpt={rep.throughput_samples:.2f}samp/s"
                if rep.plan else "OOM"))
        if res["megatron"] < float("inf") and res["mist"] < float("inf"):
            rows.append(emit(
                f"scale/batch{gbs}/speedup", 0.0,
                f"mist_vs_megatron={res['megatron'] / res['mist']:.3f}x"))
    return rows


def run() -> List[str]:
    return run_depth() + run_batch()


if __name__ == "__main__":
    run()
