"""Benchmark runner: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only motivation,roofline
    PYTHONPATH=src python -m benchmarks.run --fast     # trimmed sweeps

Prints ``name,us_per_call,derived`` CSV rows (one per measurement)."""
from __future__ import annotations

import argparse
import sys
import time
import traceback


SECTIONS = ("interference", "tuning_time", "motivation", "breakdown",
            "e2e", "scale", "accuracy", "roofline")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    print("name,us_per_call,derived")
    t_all = time.time()
    failures = 0

    def section(name, fn):
        nonlocal failures
        if name not in only:
            return
        t0 = time.time()
        try:
            fn()
            print(f"{name}/__elapsed,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/__elapsed,{(time.time() - t0) * 1e6:.0f},FAILED")

    from benchmarks import (accuracy, breakdown, e2e_throughput,
                            interference_bench, motivation, roofline,
                            scale_sweep, tuning_time)

    section("interference", interference_bench.run)
    section("tuning_time",
            (lambda: tuning_time.run_tuning_time("6.7b", 16, 32)
             + tuning_time.run_batch_speedup()) if args.fast
            else tuning_time.run)
    section("motivation",
            (lambda: motivation.run(ssizes_fast())) if args.fast
            else motivation.run)
    section("breakdown",
            (lambda: breakdown.run("2.6b", (8, 16), 32)) if args.fast
            else breakdown.run)
    section("e2e",
            (lambda: e2e_throughput.run(cells_fast(), ("gpt",)))
            if args.fast else e2e_throughput.run)
    section("scale",
            (lambda: scale_sweep.run_depth((16, 32), 16, 32)
             + scale_sweep.run_batch((32, 128), 16, "6.7b"))
            if args.fast else scale_sweep.run)
    section("accuracy", accuracy.run)
    section("roofline", roofline.run)

    print(f"__total,{(time.time() - t_all) * 1e6:.0f},"
          f"failures={failures}")
    return 1 if failures else 0


def ssizes_fast():
    return (("2.6b", 4, 8),)


def cells_fast():
    return [("1.3b", 8, 32), ("2.6b", 16, 64)]


if __name__ == "__main__":
    sys.exit(main())
