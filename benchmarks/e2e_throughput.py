"""Paper Fig. 11/12 analogue: end-to-end (modeled) training throughput of
the Mist plan vs Megatron-style / DeepSpeed-style / Aceso-style restricted
search spaces, across model sizes and chip counts, for GPT and LLaMa
families.

The paper measures wall-clock on L4/A100 clusters; this container has no
TPU, so throughput is the cost model's Eq. 1 estimate for the TPU-v5e
target — the *relative* speedups are the reproduced quantity (paper C1:
Mist >= 1 vs every restricted space, avg 1.27-1.28x vs the strongest)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (FAST_TUNE, PAPER_CELLS, emit, gpt_config,
                               llama_config, train_shape)
from repro.core.tuner import tune

SPACES = ("megatron", "zero", "ckpt", "mist")


def run(cells=PAPER_CELLS[:4], families=("gpt", "llama")) -> List[str]:
    rows = []
    speedups = {s: [] for s in SPACES}
    for fam in families:
        make = gpt_config if fam == "gpt" else llama_config
        for size, n_dev, gbs in cells:
            cfg = make(size)
            shape = train_shape(gbs, seq=2048)
            thpt = {}
            for space in SPACES:
                t0 = time.perf_counter()
                rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE)
                dt = (time.perf_counter() - t0) * 1e6
                thpt[space] = rep.throughput_samples if rep.plan else 0.0
                rows.append(emit(
                    f"e2e/{fam}-{size}/{n_dev}dev/{space}", dt,
                    f"thpt={thpt[space]:.2f}samp/s"
                    + ("" if rep.plan else " OOM")))
            best_restricted = max(thpt[s] for s in SPACES if s != "mist")
            if best_restricted > 0:
                sp = thpt["mist"] / best_restricted
                speedups["mist"].append(sp)
                rows.append(emit(
                    f"e2e/{fam}-{size}/{n_dev}dev/speedup", 0.0,
                    f"mist_vs_best_restricted={sp:.3f}x"))
    if speedups["mist"]:
        g = float(np.exp(np.mean(np.log(speedups["mist"]))))
        rows.append(emit("e2e/geomean_speedup", 0.0, f"{g:.3f}x"))
    return rows


if __name__ == "__main__":
    run()
