"""Paper Fig. 16: tuning time as optimizations are enabled one by one
(GPT-22B on 32 chips), plus three engine-level measurements:

  * batched symbolic substitution vs a per-config evaluation loop (the
    paper's >1e5x-vs-simulators claim, isolated to the batching win),
  * the compiled tuning engine (expression tapes + struct-of-arrays grids +
    frontier memoization) vs the legacy interpreted engine kept in-tree as
    the pre-refactor baseline — `tune(..., engine=...)` selects the path
    and both return identical frontiers/objectives/plans, and
  * the parallel (S, G) sweep executor (`core/sweep.py`,
    `tune(..., workers=N)`) vs the serial compiled engine (`workers=0`):
    G-collapsed hypothesis sweeps + across-unit batched refinement +
    per-cell MILPs on a persistent forked worker pool, with the frontier
    memo sharded across workers and merged at the join.  Selected plans
    are asserted byte-identical.  Reported cold (worker caches cleared
    between runs) and warm (the persistent workers' knob-tuple caches
    left alone — what repeated `tune()` calls in one session observe).

A fourth measurement compares the tape *evaluation backends* on one large
candidate grid (`run_backend_speedup`): the numpy instruction loop vs the
jax lowering (`Tape.lower_jax`) in both exact mode (per-op device
execution, bitwise identical under x64 — what `backend="jax"` runs) and
fused mode (one `jax.jit` program; FMA-contracted on CPU, so only close,
not bitwise).  On accelerators the fused path is the headline; on a
small CPU host expect parity-or-overhead below the `auto` threshold —
which is exactly why `auto` thresholds on grid size.

A fifth measurement (`run_memory_agreement`) closes the tuner->runtime
loop: for every feasible golden-plan config, the symbolic memory
prediction that selected the plan vs the layout-evaluated bytes of its
lowering (`repro.lowering`), asserted within `MEMORY_REL_TOL` — both in
total AND per term (state / act / transient / logits, each normalized
by the predicted total so a future accuracy regression is attributable
to a specific term).  The --json document carries the full per-config
comparison, including the per-term breakdown, as the
`predicted_vs_lowered_memory` table (uploaded as a CI artifact).

A sixth, opt-in measurement (`run_distributed_speedup`, flags
--distributed / --distributed-only) covers the distributed executor
(docs/distributed-sweep.md): one golden cell tuned cold-serial, fanned
out to two real `tools/tune_worker.py` daemon processes over the socket
RPC (byte-identical plan asserted), and answered warm from a persistent
`MemoStore` — the warm path is asserted >= 100x faster than the cold
sweep.

Run with --smoke for a CI-sized invocation; --json PATH additionally
writes the emitted rows as a JSON document (uploaded as a CI artifact).
"""
from __future__ import annotations

import json
import sys
import time
from typing import List

import numpy as np

from benchmarks.common import FAST_TUNE, emit, gpt_config, train_shape
from repro.core.costmodel import StageCostModel
from repro.core.schedule import candidate_grid, enumerate_candidates
from repro.core.tuner import tune

STEPS = ("megatron", "ckpt", "zero", "offload", "mist")


def run_tuning_time(size: str = "22b", n_dev: int = 32, gbs: int = 64
                    ) -> List[str]:
    rows = []
    for space in STEPS:
        t0 = time.perf_counter()
        rep = tune(gpt_config(size), train_shape(gbs, 2048), n_dev,
                   space=space, **FAST_TUNE)
        dt = time.perf_counter() - t0
        rows.append(emit(
            f"tuning_time/{space}", dt * 1e6,
            f"seconds={dt:.2f} points={rep.n_points} milps={rep.n_milp} "
            f"feasible={rep.plan is not None}"))
    return rows


def run_engine_speedup(size: str = "6.7b", n_dev: int = 32, gbs: int = 64,
                       space: str = "mist", repeats: int = 3) -> List[str]:
    """Compiled engine vs the legacy pre-refactor path, same machine, same
    (identical, asserted) results.  A warm-up tune first so one-time module
    imports (scipy HiGHS, etc.) don't pollute either side; each engine is
    timed min-of-N to suppress scheduler noise (min vs min is the standard
    noise-free microbenchmark estimate).  `workers=0` pins the compiled
    engine to its serial (PR-1) path so this row keeps measuring the
    compilation win in isolation."""
    cfg, shape = gpt_config(size), train_shape(gbs, 2048)
    tune(cfg, shape, n_dev, space="megatron", **FAST_TUNE)   # warm-up

    def best_of(n, **kw):
        rep, best = None, float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE, **kw)
            best = min(best, time.perf_counter() - t0)
        return rep, best

    new, t_new = best_of(repeats, workers=0)
    old, t_old = best_of(repeats, engine="legacy")
    assert new.objective == old.objective and new.plan == old.plan, \
        "engine equivalence violated"
    return [
        emit("tuning_time/engine_compiled", t_new * 1e6,
             f"seconds={t_new:.2f} points={new.n_points} space={space}"),
        emit("tuning_time/engine_legacy", t_old * 1e6,
             f"seconds={t_old:.2f} points={old.n_points} space={space}"),
        emit("tuning_time/engine_speedup", 0.0,
             f"{t_old / t_new:.1f}x identical_results=True"),
    ]


def run_parallel_speedup(size: str = "6.7b", n_dev: int = 32, gbs: int = 64,
                         space: str = "mist", workers: int = 4,
                         repeats: int = 5) -> List[str]:
    """Parallel sweep executor vs the serial compiled engine.

    Cold rows clear the persistent workers' knob-tuple caches between
    runs, so they measure the per-tune executor speedup (parallel sweeps
    + batched refinement + parallel MILPs).  The warm row leaves the
    worker caches alone, which is what a session issuing many `tune()`
    calls actually experiences.  Byte-identical plans are asserted
    between every serial and parallel invocation."""
    from repro.core.sweep import clear_worker_caches, warm_pool
    cfg, shape = gpt_config(size), train_shape(gbs, 2048)
    tune(cfg, shape, n_dev, space=space, workers=workers,
         **FAST_TUNE)                                        # warm pool

    def best_of(n, *, clear=False, **kw):
        rep, best = None, float("inf")
        for _ in range(n):
            if clear:
                # fresh worker processes (deterministically cold caches),
                # but the one-time pool fork is paid before the timer —
                # it is session setup, not per-tune cost
                clear_worker_caches()
                warm_pool(workers)
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE, **kw)
            best = min(best, time.perf_counter() - t0)
        return rep, best

    ser, t_ser = best_of(repeats, workers=0)
    cold, t_cold = best_of(repeats, clear=True, workers=workers)
    warm, t_warm = best_of(repeats, workers=workers)
    for rep in (cold, warm):
        assert rep.objective == ser.objective and rep.plan == ser.plan \
            and rep.per_sg == ser.per_sg, "executor equivalence violated"
    hitrate = cold.n_cache_hits / max(1, cold.n_cache_hits
                                      + cold.n_cache_misses)
    warm_hitrate = warm.n_cache_hits / max(1, warm.n_cache_hits
                                           + warm.n_cache_misses)
    return [
        emit("tuning_time/parallel_serial", t_ser * 1e6,
             f"seconds={t_ser:.2f} workers=0 space={space}"),
        emit(f"tuning_time/parallel_workers{workers}_cold", t_cold * 1e6,
             f"seconds={t_cold:.2f} cache_hitrate={hitrate:.2f} "
             f"memo_swept={cold.n_swept}"),
        emit(f"tuning_time/parallel_workers{workers}_warm", t_warm * 1e6,
             f"seconds={t_warm:.2f} cache_hitrate={warm_hitrate:.2f}"),
        emit("tuning_time/parallel_speedup", 0.0,
             f"{t_ser / t_warm:.1f}x warm {t_ser / t_cold:.1f}x cold "
             f"identical_plans=True"),
    ]


def run_backend_speedup(size: str = "6.7b", rows: int = 1_000_000,
                        repeats: int = 3) -> List[str]:
    """Tape backends on one large synthetic candidate grid: numpy vs jax
    exact (bitwise-asserted) vs jax fused (`jax.jit`, closeness-asserted;
    its one-time compile is reported separately from the steady state).
    Emits a skip row — instead of failing — when jax is unavailable, so
    numpy-only containers still run the benchmark file end to end."""
    from repro import compat
    cfg = gpt_config(size)
    scm = StageCostModel(cfg, 2048)
    tape = scm.tape_time
    rng = np.random.default_rng(0)
    env = {name: rng.uniform(1.0, 8.0, rows)
           for name, _slot in tape.sym_loads}

    def best_of(fn):
        b = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            b = min(b, time.perf_counter() - t0)
        return b

    scratch = tape.make_scratch()
    ref = tape.run(env, scratch)
    t_np = best_of(lambda: tape.run(env, scratch))
    out = [emit("tuning_time/backend_numpy", t_np * 1e6,
                f"seconds={t_np:.3f} rows={rows} instrs={len(tape)}")]
    if not compat.has_jax():
        out.append(emit("tuning_time/backend_jax", 0.0,
                        "skipped=jax_unavailable"))
        return out
    jax, _jnp = compat.require_jax()
    with compat.enable_x64():
        exact = tape.lower_jax()
        run_exact = lambda: jax.block_until_ready(  # noqa: E731
            list(exact(env).values()))
        got = exact(env)
        for k in ref:
            g = np.asarray(got[k])
            r = np.broadcast_to(ref[k], g.shape)
            if tape.jax_bitexact:       # same guard the dispatcher uses
                assert np.array_equal(r, g), \
                    f"jax exact backend not bitwise identical on {k}"
            else:                       # pow/log2 tape: closeness only
                assert np.allclose(g, r, rtol=1e-12, atol=0), \
                    f"jax exact backend drifted on non-bitexact op: {k}"
        t_ex = best_of(run_exact)
        fused = tape.lower_jax(fused=True)
        t0 = time.perf_counter()
        fgot = fused(env)
        jax.block_until_ready(list(fgot.values()))
        t_compile = time.perf_counter() - t0
        rel = 0.0
        for k in ref:
            f = np.asarray(fgot[k])
            r = np.broadcast_to(ref[k], f.shape)
            denom = np.maximum(np.abs(r), 1e-300)
            rel = max(rel, float(np.max(np.abs(f - r) / denom)))
        # FMA contraction drift is ~1-2 ulp per op, but cancellation in
        # the d_delta-style subtractions amplifies it; ~1e-10 observed
        assert rel < 1e-8, \
            f"jax fused backend drifted beyond expectations: {rel:.2e}"
        t_fu = best_of(lambda: jax.block_until_ready(
            list(fused(env).values())))
    out += [
        emit("tuning_time/backend_jax_exact", t_ex * 1e6,
             f"seconds={t_ex:.3f} bitwise_identical={tape.jax_bitexact}"),
        emit("tuning_time/backend_jax_fused", t_fu * 1e6,
             f"seconds={t_fu:.3f} compile_s={t_compile:.2f} "
             f"max_rel_err={rel:.1e}"),
        emit("tuning_time/backend_speedup", 0.0,
             f"{t_np / t_ex:.2f}x exact {t_np / t_fu:.2f}x fused "
             f"(numpy/jax; >1 means jax wins)"),
    ]
    return out


def memory_agreement_table() -> List[dict]:
    """Predicted-vs-lowered memory agreement per golden-plan config: the
    symbolic estimate that selected each plan vs the spec-walked bytes of
    its lowering (`repro.lowering.memory_consistency`).  Infeasible golden
    cells (no plan pinned) emit a skip entry; numpy-only containers (no
    jax → no PartitionSpec tables) skip the whole table."""
    from repro import compat
    if not compat.has_jax():
        return [{"skipped": "jax_unavailable"}]
    from repro.configs.base import ShapeConfig, get_arch
    from repro.core import golden
    from repro.core.plan import Plan
    from repro.lowering import MEMORY_REL_TOL, memory_consistency

    w = golden._WORKLOAD
    shape = ShapeConfig("golden", w["seq_len"], w["global_batch"], "train")
    table = []
    for space in golden.GOLDEN_SPACES:
        for arch in golden.GOLDEN_ARCHS:
            path = golden.golden_path(space, arch)
            if not path.exists():
                continue
            doc = json.loads(path.read_text())["doc"]
            row = {"space": space, "arch": arch}
            if doc["plan"] is None:
                table.append({**row, "skipped": "infeasible"})
                continue
            plan = Plan.from_json(json.dumps(doc["plan"]))
            mc = memory_consistency(get_arch(arch), shape, plan)
            table.append({
                **row,
                "predicted_bytes": mc["predicted_bytes"],
                "lowered_bytes": mc["lowered_bytes"],
                "rel_error": mc["rel_error"],
                "within_tol": mc["within_tol"],
                "tol": MEMORY_REL_TOL,
                # per-term breakdown at the lowered peak stage; rel
                # errors are normalized by the predicted TOTAL bytes
                # (what the disagreement is worth against the budget)
                "terms": mc["terms"],
            })
    return table


def run_memory_agreement(table: List[dict] = None) -> List[str]:
    rows = []
    for r in (memory_agreement_table() if table is None else table):
        if "space" not in r:
            rows.append(emit("tuning_time/memory_agreement", 0.0,
                             f"skipped={r['skipped']}"))
            continue
        name = f"tuning_time/memory_agreement/{r['space']}_{r['arch']}"
        if "skipped" in r:
            rows.append(emit(name, 0.0, f"skipped={r['skipped']}"))
        else:
            assert r["within_tol"], r   # the lowering contract, enforced
            per_term = {k: v["rel_error"] for k, v in r["terms"].items()
                        if k in ("state", "act", "transient", "logits")}
            for k, rel in per_term.items():     # ... term by term, too
                assert rel <= r["tol"], (name, k, rel, r)
            rows.append(emit(
                name, 0.0,
                f"predicted_GiB={r['predicted_bytes'] / 2**30:.3f} "
                f"lowered_GiB={r['lowered_bytes'] / 2**30:.3f} "
                f"rel_error={r['rel_error']:.4f} "
                + " ".join(f"rel_{k}={v:.4f}"
                           for k, v in per_term.items())))
    return rows


def _spawn_tune_worker(repo_root, timeout: float = 60.0):
    """Launch `tools/tune_worker.py --port 0` as a subprocess and return
    (Popen, "host:port") once it prints its bound address."""
    import os
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    src = str(repo_root / "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [_sys.executable, str(repo_root / "tools" / "tune_worker.py"),
         "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, env=env,
        text=True, bufsize=1)
    t0 = time.perf_counter()
    line = proc.stdout.readline()
    if "listening on" not in line or time.perf_counter() - t0 > timeout:
        proc.kill()
        raise RuntimeError(f"tune_worker failed to start: {line!r}")
    return proc, line.rsplit(" ", 1)[-1].strip()


def run_distributed_speedup(repeats: int = 3) -> List[str]:
    """The distributed table (docs/distributed-sweep.md): one golden cell
    tuned cold-serial, fanned out to two real `tools/tune_worker.py`
    daemon processes over the socket RPC, and served warm from a
    persistent memo store — with every variant's plan asserted identical
    to serial, and the warm-memo path asserted >= 100x faster than the
    cold sweep (the ROADMAP "milliseconds when warm" target)."""
    import pathlib
    import tempfile

    from repro.configs.base import ShapeConfig, get_arch
    from repro.core import golden, remote
    from repro.core.tuner import MistTuner, TuneSpec

    w = golden._WORKLOAD
    arch = get_arch(golden.GOLDEN_ARCHS[0])
    base = dict(arch=arch, seq_len=w["seq_len"],
                global_batch=w["global_batch"], n_devices=w["n_devices"],
                space="mist", stage_counts=w["stage_counts"],
                grad_accums=w["grad_accums"])

    def best_of(n, **kw):
        rep, best = None, float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            rep = MistTuner(TuneSpec(**base, **kw)).tune()
            best = min(best, time.perf_counter() - t0)
        return rep, best

    ser, t_ser = best_of(repeats, workers=0)

    repo_root = pathlib.Path(__file__).resolve().parent.parent
    procs_addrs = [_spawn_tune_worker(repo_root) for _ in range(2)]
    try:
        hosts = tuple(a for _p, a in procs_addrs)
        dist, t_dist = best_of(repeats, workers=2, hosts=hosts)
        assert dist.objective == ser.objective and dist.plan == ser.plan \
            and dist.per_sg == ser.per_sg, "multi-host plan diverged"
        assert dist.hosts_used == 2 and dist.n_host_failures == 0, \
            (dist.hosts_used, dist.n_host_failures)
    finally:
        for proc, addr in procs_addrs:
            try:
                remote.request(addr, "shutdown", timeout=5, retries=0)
            except Exception:
                proc.kill()
            proc.wait(timeout=10)

    with tempfile.TemporaryDirectory() as memo_dir:
        cold, t_cold = best_of(1, memo_dir=memo_dir)
        assert not cold.from_memo
        warm, t_warm = best_of(repeats, memo_dir=memo_dir)
        assert warm.from_memo, "second tune() missed the report cache"
        assert warm.plan == ser.plan and warm.objective == ser.objective, \
            "memo-store plan diverged"
        speedup = t_cold / t_warm
        assert speedup >= 100, \
            f"warm memo path only {speedup:.0f}x faster than cold"

    return [
        emit("tuning_time/distributed_serial", t_ser * 1e6,
             f"seconds={t_ser:.2f} workers=0"),
        emit("tuning_time/distributed_hosts2", t_dist * 1e6,
             f"seconds={t_dist:.2f} hosts=2 workers=2 "
             f"host_failures={dist.n_host_failures} identical_plan=True"),
        emit("tuning_time/distributed_memo_cold", t_cold * 1e6,
             f"seconds={t_cold:.2f} memo_store=cold"),
        emit("tuning_time/distributed_memo_warm", t_warm * 1e6,
             f"seconds={t_warm:.5f} from_memo=True"),
        emit("tuning_time/distributed_speedup", 0.0,
             f"{speedup:.0f}x warm-memo {t_ser / t_dist:.2f}x hosts2 "
             f"identical_plans=True"),
    ]


def run_batch_speedup(size: str = "6.7b") -> List[str]:
    """Batched symbolic substitution vs per-config evaluation loop."""
    cfg = gpt_config(size)
    scm = StageCostModel(cfg, 2048)
    grid = candidate_grid(cfg, n_devices=32, layers=32, global_batch=64,
                          grad_accum=8)
    env = grid.env(layers=32, grad_accum=8)
    # batched (compiled tape over the whole struct-of-arrays grid)
    t0 = time.perf_counter()
    scm.evaluate(env)
    t_batched = time.perf_counter() - t0
    # per-config loop (sample to keep runtime sane, scale up)
    cands = list(enumerate_candidates(cfg, n_devices=32, layers=32,
                                      global_batch=64, grad_accum=8))
    sample = cands[:: max(1, len(cands) // 200)][:200]
    t0 = time.perf_counter()
    for c in sample:
        e1 = scm.env_from_candidates([c], layers=32, grad_accum=8)
        scm.evaluate(e1)
    t_loop = (time.perf_counter() - t0) / len(sample) * len(cands)
    ratio = t_loop / t_batched
    rows = [
        emit("tuning_time/batched_eval", t_batched / len(grid) * 1e6,
             f"n={len(grid)} total_s={t_batched:.4f}"),
        emit("tuning_time/per_config_eval", t_loop / len(cands) * 1e6,
             f"extrapolated_total_s={t_loop:.2f}"),
        emit("tuning_time/batching_speedup", 0.0, f"{ratio:.0f}x"),
    ]
    return rows


def run(smoke: bool = False, mem_table: List[dict] = None) -> List[str]:
    if smoke:
        return (run_tuning_time(size="1.3b", n_dev=8, gbs=16)
                + run_engine_speedup(size="1.3b", n_dev=8, gbs=16)
                + run_parallel_speedup(size="1.3b", n_dev=8, gbs=16,
                                       repeats=3)
                + run_batch_speedup(size="1.3b")
                + run_backend_speedup(size="1.3b", rows=120_000, repeats=2)
                + run_memory_agreement(mem_table))
    return (run_tuning_time() + run_engine_speedup()
            + run_parallel_speedup() + run_batch_speedup()
            + run_backend_speedup() + run_memory_agreement(mem_table))


def rows_to_json(rows: List[str], mem_table: List[dict] = None) -> dict:
    out = []
    for r in rows:
        name, value, notes = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(value),
                    "notes": notes})
    return {"benchmark": "tuning_time", "rows": out,
            "predicted_vs_lowered_memory":
                memory_agreement_table() if mem_table is None else mem_table}


if __name__ == "__main__":
    # --distributed appends the multi-host + memo-store table
    # (docs/distributed-sweep.md) to the standard run; --distributed-only
    # runs just that table (the CI fan-out smoke job), skipping the
    # memory-agreement recomputation.  Both ride the --json artifact.
    if "--distributed-only" in sys.argv:
        mem_table: List[dict] = []
        rows = run_distributed_speedup()
    else:
        mem_table = memory_agreement_table()   # computed once, used twice
        rows = run(smoke="--smoke" in sys.argv, mem_table=mem_table)
        if "--distributed" in sys.argv:
            rows += run_distributed_speedup()
    if "--json" in sys.argv:
        path = sys.argv[sys.argv.index("--json") + 1]
        with open(path, "w") as f:
            json.dump(rows_to_json(rows, mem_table), f, indent=2)
        print(f"wrote {path}")
