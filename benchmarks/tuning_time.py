"""Paper Fig. 16: tuning time as optimizations are enabled one by one
(GPT-22B on 32 chips), plus the symbolic-batched vs per-config-loop
evaluation speed ratio (the paper's >1e5 x claim vs simulators; here
measured against a per-point re-evaluation of our own model, isolating the
batching win)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import FAST_TUNE, emit, gpt_config, train_shape
from repro.core.costmodel import StageCostModel
from repro.core.schedule import Candidate, enumerate_candidates
from repro.core.tuner import tune

STEPS = ("megatron", "ckpt", "zero", "offload", "mist")


def run_tuning_time(size: str = "22b", n_dev: int = 32, gbs: int = 64
                    ) -> List[str]:
    rows = []
    for space in STEPS:
        t0 = time.perf_counter()
        rep = tune(gpt_config(size), train_shape(gbs, 2048), n_dev,
                   space=space, **FAST_TUNE)
        dt = time.perf_counter() - t0
        rows.append(emit(
            f"tuning_time/{space}", dt * 1e6,
            f"seconds={dt:.2f} points={rep.n_points} milps={rep.n_milp} "
            f"feasible={rep.plan is not None}"))
    return rows


def run_batch_speedup(size: str = "6.7b") -> List[str]:
    """Batched symbolic substitution vs per-config evaluation loop."""
    cfg = gpt_config(size)
    scm = StageCostModel(cfg, 2048)
    cands = list(enumerate_candidates(cfg, n_devices=32, layers=32,
                                      global_batch=64, grad_accum=8))
    env = scm.env_from_candidates(cands, layers=32, grad_accum=8)
    # batched
    t0 = time.perf_counter()
    scm.evaluate(env)
    t_batched = time.perf_counter() - t0
    # per-config loop (sample to keep runtime sane, scale up)
    sample = cands[:: max(1, len(cands) // 200)][:200]
    t0 = time.perf_counter()
    for c in sample:
        e1 = scm.env_from_candidates([c], layers=32, grad_accum=8)
        scm.evaluate(e1)
    t_loop = (time.perf_counter() - t0) / len(sample) * len(cands)
    ratio = t_loop / t_batched
    rows = [
        emit("tuning_time/batched_eval", t_batched / len(cands) * 1e6,
             f"n={len(cands)} total_s={t_batched:.4f}"),
        emit("tuning_time/per_config_eval", t_loop / len(cands) * 1e6,
             f"extrapolated_total_s={t_loop:.2f}"),
        emit("tuning_time/batching_speedup", 0.0, f"{ratio:.0f}x"),
    ]
    return rows


def run() -> List[str]:
    return run_tuning_time() + run_batch_speedup()


if __name__ == "__main__":
    run()
