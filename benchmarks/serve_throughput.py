"""Serving throughput: the ``serve`` search space measured end to end
(docs/serving.md).

Three measurement groups:

  * **predicted memory bitwise** — the symbolic serve cost model the
    tuner ranks candidates with equals ``memory_report()`` on the tuned
    plan's lowering, bitwise (the two-evaluation contract on the serve
    path).  Asserted, not reported.
  * **tokens identical** — ``generate()`` under the tuned plan emits the
    same token ids as under the dp-only baseline plan (plan choice moves
    work around; it must not move numerics).  Asserted for bf16 plans.
  * **tok/s tuned vs baseline** — measured greedy-decode throughput of
    both plans.  When the tuner selects exactly the baseline plan (it
    does on a single device, where dp=1/tp=1 is the whole grid), one
    measurement serves both rows and the ratio is exactly 1 — the
    benchmark never flakes on timing noise in a degenerate cell.

Run with --smoke for a CI-sized invocation (reduced golden arch, small
batch/lengths, one rep); --json PATH additionally writes the rows as a
JSON document (uploaded as a CI artifact next to the kernel-tuning
report).

``--trace`` runs the continuous-batching headline instead: a mixed
prompt/output-length trace served by ``ContinuousBatchingEngine``
(paged KV cache, docs/continuous-batching.md) vs plen-bucketed static
batches of the same requests at the same global max_len.  Per-request
token identity and a >= 1.25x useful-tok/s ratio are asserted.
"""
from __future__ import annotations

import json
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import compat
from repro.configs.base import ShapeConfig, get_arch
from repro.core.costmodel import estimate_serve_plan
from repro.core.plan import single_stage_plan
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate, tuned_serve_plan
from repro.lowering import lower_plan
from repro.models.zoo import build_model

SMOKE_ARCH = "granite-3-8b"


def _measure(model, params, prompts, gen, mesh, plan, low, reps: int):
    """Best-of-reps wall-clock of a full generate() call; returns
    (tok/s, tokens)."""
    b = prompts.shape[0]
    best = float("inf")
    toks = None
    for _ in range(reps):
        t0 = time.perf_counter()
        toks = generate(model, params, prompts, gen, mesh, plan,
                        lowered=low)
        jax.block_until_ready(toks)
        best = min(best, time.perf_counter() - t0)
    return b * gen / best, np.asarray(toks)


def run_cell(arch_name: str, *, smoke: bool, batch: int, prompt_len: int,
             gen: int, reps: int) -> List[str]:
    cfg = get_arch(arch_name)
    if smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    n = len(jax.devices())
    max_len = prompt_len + gen
    shape = ShapeConfig("serve", max_len, batch, "decode")

    base_plan = single_stage_plan(cfg.num_layers, dp=n, tp=1, micro_batch=1,
                                  grad_accum=1, zero=0, ckpt_layers=0)
    plan, report = tuned_serve_plan(cfg, batch=batch, max_len=max_len,
                                    n_devices=n)
    st = plan.stages[0]

    # group 1: predicted serve memory == lowered report, bitwise
    mesh = make_host_mesh(st.dp, st.tp)
    low = lower_plan(cfg, shape, plan, mesh)
    rep_mem = low.memory_report()
    est = estimate_serve_plan(cfg, shape, plan)
    assert est["mem_decode"] == rep_mem.peak_bytes, \
        f"serve cost model drifted from memory_report: " \
        f"{est['mem_decode']} != {rep_mem.peak_bytes}"
    rows = [emit(f"serve_throughput/predicted_mem_bitwise/{cfg.name}",
                 rep_mem.peak_bytes / 2**20,
                 f"MiB plan=dp{st.dp}_tp{st.tp}_z{st.zero}_"
                 f"{plan.kv_cache_dtype} tune_seconds="
                 f"{report.tune_seconds:.2f}")]

    base_mesh = make_host_mesh(n, 1)
    base_low = lower_plan(cfg, shape, base_plan, base_mesh)
    same_plan = plan.to_json() == base_plan.to_json()

    with compat.set_mesh(base_mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0,
            cfg.vocab_size).astype(jnp.int32)
        base_tps, base_toks = _measure(model, params, prompts, gen,
                                       base_mesh, base_plan, base_low, reps)
    if same_plan:
        tuned_tps, tuned_toks = base_tps, base_toks
    else:
        with compat.set_mesh(mesh):
            params, _ = model.init(jax.random.PRNGKey(0))
            prompts = jax.random.randint(
                jax.random.PRNGKey(1), (batch, prompt_len), 0,
                cfg.vocab_size).astype(jnp.int32)
            tuned_tps, tuned_toks = _measure(model, params, prompts, gen,
                                             mesh, plan, low, reps)

    # group 2: plan choice must not move numerics (bf16 plans; the int8
    # fallback intentionally perturbs logits and is exempt)
    tokens_match = bool((tuned_toks == base_toks).all())
    if plan.kv_cache_dtype == "bf16":
        assert tokens_match, "tuned plan changed the generated tokens"

    speedup = tuned_tps / base_tps
    rows += [
        emit(f"serve_throughput/baseline_tok_s/{cfg.name}", base_tps,
             f"plan=dp{n}_tp1_z0_bf16 reps={reps}"),
        emit(f"serve_throughput/tuned_tok_s/{cfg.name}", tuned_tps,
             f"plan=dp{st.dp}_tp{st.tp}_z{st.zero}_{plan.kv_cache_dtype} "
             f"same_plan_as_baseline={same_plan}"),
        emit(f"serve_throughput/speedup/{cfg.name}", speedup,
             f"tokens_match={tokens_match} "
             f"predicted_tok_s={report.throughput_tokens:.1f}"),
    ]
    return rows


def run(smoke: bool = False) -> List[str]:
    if smoke:
        return run_cell(SMOKE_ARCH, smoke=True, batch=4, prompt_len=16,
                        gen=8, reps=1)
    rows = []
    for arch in ("granite-3-8b", "qwen2-moe-a2.7b"):
        rows += run_cell(arch, smoke=True, batch=8, prompt_len=64,
                         gen=32, reps=3)
    return rows


# ---------------------------------------------------------------------------
# --trace: continuous batching vs static batching on a mixed-length trace
# ---------------------------------------------------------------------------

# prompt-length buckets with one long request + short tails each: static
# batching decodes every bucket until its LONGEST request finishes
# (head-of-line blocking, bucket after bucket), continuous batching
# retires the shorts immediately AND runs the four long tails in
# parallel across its slots
TRACE_MAX_LEN = 64
TRACE_SLOTS = 4
TRACE_PAGE = 8
TRACE_BUCKETS = ((4, (48, 2, 2, 2)), (8, (46, 2, 2, 2)),
                 (12, (46, 2, 2, 2)), (16, (44, 2, 2, 2)))


def _trace_requests(cfg):
    """One prompt batch per bucket (rows are the per-request prompts)."""
    out = []
    for i, (plen, gens) in enumerate(TRACE_BUCKETS):
        prompts = jax.random.randint(
            jax.random.PRNGKey(10 + i), (len(gens), plen), 0,
            cfg.vocab_size).astype(jnp.int32)
        out.append((prompts, gens))
    return out


def _make_static_steps(model, low, batch: int):
    """Compile the static path's prefill/decode programs ONCE — the
    engine amortizes its compiles across the whole trace, so the
    baseline must too or the ratio measures recompilation, not
    batching policy."""
    from repro.training.step import make_prefill_step, make_serve_step
    prefill = make_prefill_step(model, return_cache=True, lowered=low)
    serve = make_serve_step(model, batch=batch, max_len=TRACE_MAX_LEN,
                            donate=False, lowered=low)
    return prefill, serve


def _static_trace(prefill, serve, params, buckets):
    """Static baseline (generate() semantics, prebuilt steps): each
    bucket decodes until its LONGEST request finishes, at the engine's
    global max_len.  Returns (useful tok/s, per-bucket token arrays)."""
    from repro.models.zoo import pad_caches
    t0 = time.perf_counter()
    outs = []
    for prompts, gens in buckets:
        logits, caches = prefill.fn(params, {"tokens": prompts})
        caches = pad_caches(caches, TRACE_MAX_LEN - prompts.shape[1])
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        for _ in range(max(gens) - 1):
            logits, caches = serve.fn(params, tok, caches)
            tok = jnp.argmax(logits[:, -1],
                             axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        outs.append(np.asarray(jnp.concatenate(out, axis=1)))
    dt = time.perf_counter() - t0
    useful = sum(sum(gens) for _, gens in buckets)
    return useful / dt, outs


def _continuous_trace(eng, buckets):
    """Submit every request (FCFS, bucket order) and drain the engine.
    Returns (useful tok/s, {rid: tokens})."""
    rid = 0
    for prompts, gens in buckets:
        for r, g in enumerate(gens):
            eng.submit({"tokens": prompts[r:r + 1]}, g, rid=rid)
            rid += 1
    t0 = time.perf_counter()
    res = eng.run()
    dt = time.perf_counter() - t0
    useful = sum(sum(gens) for _, gens in buckets)
    return useful / dt, res


def run_trace(reps: int = 2) -> List[str]:
    """Continuous-vs-static headline on the mixed trace.  Token identity
    per request and the >= 1.25x tok/s ratio are asserted, not merely
    reported (docs/continuous-batching.md)."""
    from repro.serving import ContinuousBatchingEngine

    cfg = get_arch(SMOKE_ARCH).reduced()
    model = build_model(cfg)
    plan = single_stage_plan(cfg.num_layers, dp=1, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0)
    mesh = make_host_mesh(1, 1)
    low = lower_plan(cfg, None, plan, mesh)
    with compat.set_mesh(mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        buckets = _trace_requests(cfg)
        eng = ContinuousBatchingEngine(
            model, params, plan, mesh, slots=TRACE_SLOTS,
            max_len=TRACE_MAX_LEN, page_size=TRACE_PAGE, lowered=low)
        prefill, serve = _make_static_steps(
            model, low, batch=len(TRACE_BUCKETS[0][1]))
        # warmup: compile both paths' prefill/decode programs off-clock
        _static_trace(prefill, serve, params, buckets)
        _continuous_trace(eng, buckets)
        static_tps = cont_tps = 0.0
        refs, res = None, None
        for _ in range(reps):
            tps, refs = _static_trace(prefill, serve, params, buckets)
            static_tps = max(static_tps, tps)
            tps, res = _continuous_trace(eng, buckets)
            cont_tps = max(cont_tps, tps)

    # per-request token identity: the engine's tokens are the static
    # rows' prefixes (greedy decode is deterministic)
    rid = 0
    for ref, (_, gens) in zip(refs, buckets):
        for r, g in enumerate(gens):
            assert np.array_equal(res[rid], ref[r][:g]), \
                f"continuous tokens diverged from static (request {rid})"
            rid += 1
    speedup = cont_tps / static_tps
    assert speedup >= 1.25, \
        f"continuous/static tok/s ratio {speedup:.2f} below 1.25"
    n_req = sum(len(g) for _, g in TRACE_BUCKETS)
    return [
        emit(f"serve_throughput/trace_static_tok_s/{cfg.name}", static_tps,
             f"requests={n_req} buckets={len(TRACE_BUCKETS)} reps={reps}"),
        emit(f"serve_throughput/trace_continuous_tok_s/{cfg.name}",
             cont_tps, f"slots={TRACE_SLOTS} page_size={TRACE_PAGE} "
             f"max_len={TRACE_MAX_LEN}"),
        emit(f"serve_throughput/trace_speedup/{cfg.name}", speedup,
             "tokens_match=True floor=1.25"),
    ]


def rows_to_json(rows: List[str]) -> dict:
    out = []
    for r in rows:
        name, value, notes = r.split(",", 2)
        out.append({"name": name, "value": float(value), "notes": notes})
    return {"benchmark": "serve_throughput", "rows": out}


if __name__ == "__main__":
    if "--trace" in sys.argv:
        rows = run_trace()
    else:
        rows = run(smoke="--smoke" in sys.argv)
    if "--json" in sys.argv:
        path = sys.argv[sys.argv.index("--json") + 1]
        with open(path, "w") as f:
            json.dump(rows_to_json(rows), f, indent=2)
        print(f"wrote {path}")
