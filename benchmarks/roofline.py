"""§Roofline deliverable: the 3-term roofline table for every compiled
(arch x shape x mesh) dry-run cell, read from results/dryrun/*.json."""
from __future__ import annotations

import json
import pathlib
from typing import List, Optional

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: Optional[str] = None, tag: str = "") -> List[dict]:
    cells = []
    for p in sorted(RESULTS.glob(f"*{tag}.json")):
        rec = json.loads(p.read_text())
        if not rec.get("ok"):
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run() -> List[str]:
    rows = []
    cells = load_cells()
    for rec in cells:
        r = rec["roofline"]
        mem = rec["memory"]
        rows.append(emit(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            rec.get("compile_s", 0.0) * 1e6,
            f"t_comp={r['t_compute']:.3f}s t_mem={r['t_memory']:.3f}s "
            f"t_coll={r['t_collective']:.3f}s "
            f"bottleneck={r['bottleneck']} "
            f"roofline_frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_ratio']:.2f} "
            f"dev={mem['device_total_bytes'] / 2**30:.2f}GiB "
            f"fits={mem['fits_16GiB']}"))
    if not cells:
        rows.append(emit("roofline/none", 0.0,
                         "no dry-run artifacts; run repro.launch.dryrun"))
    else:
        worst = min(cells,
                    key=lambda c: c["roofline"]["roofline_fraction"])
        rows.append(emit(
            "roofline/worst_cell", 0.0,
            f"{worst['arch']}/{worst['shape']}/{worst['mesh']} "
            f"frac={worst['roofline']['roofline_fraction']:.3f}"))
    return rows


if __name__ == "__main__":
    run()
