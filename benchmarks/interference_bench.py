"""Paper Alg. 1 benchmark: batched interference estimation throughput and
fit quality (synthetic calibration, mirroring the paper's data-driven
factor fitting)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import emit
from repro.core.interference import InterferenceModel


def run(n: int = 200_000) -> List[str]:
    rows = []
    m = InterferenceModel()
    rng = np.random.default_rng(0)
    ch = rng.uniform(0.0, 5.0, size=(n, 4))
    ch[rng.uniform(size=(n, 4)) < 0.35] = 0.0
    # warm + time batched prediction
    m.predict(ch[:100, 0], ch[:100, 1], ch[:100, 2], ch[:100, 3])
    t0 = time.perf_counter()
    out = m.predict(ch[:, 0], ch[:, 1], ch[:, 2], ch[:, 3])
    dt = time.perf_counter() - t0
    rows.append(emit("interference/batched_predict", dt / n * 1e6,
                     f"n={n} total_s={dt:.3f}"))

    # fit quality: perturb factors, re-fit from 32 samples
    true = InterferenceModel()
    for k in true.factors:
        true.factors[k] = tuple(f * rng.uniform(0.95, 1.15)
                                for f in true.factors[k])
    samples = []
    for _ in range(32):
        c = rng.uniform(0.0, 4.0, size=4)
        c[rng.uniform(size=4) < 0.4] = 0.0
        samples.append((tuple(c), float(true.predict(*c))))
    fit = InterferenceModel()
    t0 = time.perf_counter()
    err = fit.calibrate(samples)
    dt = time.perf_counter() - t0
    rows.append(emit("interference/calibrate", dt * 1e6,
                     f"post_fit_rel_err={err:.2%}"))
    return rows


if __name__ == "__main__":
    run()
