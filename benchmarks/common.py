"""Shared benchmark machinery: the paper's GPT/LLaMa workload family
(Table 4 sizes), CSV emission, and timing helpers."""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig

# paper Table 4 model family: [1.3, 2.6, 6.7, 13, 22] B params
_GPT_DIMS = {
    "1.3b": dict(num_layers=24, d_model=2048, num_heads=16, d_ff=8192),
    "2.6b": dict(num_layers=32, d_model=2560, num_heads=20, d_ff=10240),
    "6.7b": dict(num_layers=32, d_model=4096, num_heads=32, d_ff=16384),
    "13b": dict(num_layers=40, d_model=5120, num_heads=40, d_ff=20480),
    "22b": dict(num_layers=48, d_model=6144, num_heads=48, d_ff=24576),
}


def gpt_config(size: str) -> ArchConfig:
    """GPT-3-style decoder (paper's primary workload): LN, GELU, ungated."""
    d = _GPT_DIMS[size]
    return ArchConfig(
        name=f"gpt3-{size}", family="dense", vocab_size=50257,
        num_kv_heads=d["num_heads"], norm_type="layernorm", act="gelu",
        mlp_gated=False, qkv_bias=False, **d)


def llama_config(size: str) -> ArchConfig:
    """LLaMa-style: RMSNorm + SwiGLU (2/3 d_ff rule) + RoPE."""
    d = dict(_GPT_DIMS[size])
    d["d_ff"] = int(d["d_ff"] * 2 // 3 // 256 * 256)
    return ArchConfig(
        name=f"llama-{size}", family="dense", vocab_size=32000,
        num_kv_heads=d["num_heads"], norm_type="rmsnorm", act="silu",
        mlp_gated=True, **d)


def train_shape(global_batch: int, seq: int = 4096) -> ShapeConfig:
    return ShapeConfig(f"b{global_batch}", seq, global_batch, "train")


# paper practice: scale batch and chips with model size
PAPER_CELLS: List[Tuple[str, int, int]] = [
    # (size, n_devices, global_batch)
    ("1.3b", 8, 32),
    ("2.6b", 16, 64),
    ("6.7b", 32, 128),
    ("13b", 64, 256),
    ("22b", 128, 512),
]


@contextmanager
def timed(out: Dict[str, float], key: str):
    t0 = time.perf_counter()
    yield
    out[key] = time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.3f},{derived}"
    print(row)
    return row


FAST_TUNE = dict(stage_counts=(1, 2, 4), grad_accums=(2, 4, 8, 16))
