"""Paper Fig. 2 + Fig. 3 (motivation): tuning parallelism alone OOMs or
under-performs; each memory optimization co-tuned with parallelism helps;
comprehensive co-optimization wins.

GPT-2.6B on 4 chips (Fig. 2 analogue) and GPT-6.7B on 8 chips (Fig. 3
analogue), modeled for the TPU-v5e target.  Prints one row per search space
with the chosen plan — the speedup structure mirrors the paper's
(parallelism-only infeasible/slow -> +CKPT -> +ZeRO -> +offload -> co-opt).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import emit, gpt_config, train_shape, FAST_TUNE
from repro.core.tuner import tune

# "none": parallelism only (no memory optimization at all) — Fig. 2(a)
SPACES = ("none", "megatron", "ckpt", "zero", "offload", "mist")


def run(sizes=(("2.6b", 4, 8), ("6.7b", 8, 512))) -> List[str]:
    rows = []
    for size, n_dev, gbs in sizes:
        cfg = gpt_config(size)
        shape = train_shape(gbs, seq=4096 if size == "2.6b" else 2048)
        base = None
        for space in SPACES:
            t0 = time.perf_counter()
            rep = tune(cfg, shape, n_dev, space=space, **FAST_TUNE)
            dt = (time.perf_counter() - t0) * 1e6
            if rep.plan is None:
                rows.append(emit(f"motivation/{size}/{space}", dt, "OOM"))
                continue
            if base is None and space == "megatron":
                base = rep.objective
            sp = (base / rep.objective) if base else 1.0
            s0 = rep.plan.stages[0]
            desc = (f"thpt={rep.throughput_samples:.2f}samp/s "
                    f"speedup={sp:.2f}x S={rep.best_S} G={rep.best_G} "
                    f"dp={s0.dp} tp={s0.tp} z={s0.zero} "
                    f"ckpt={min(s0.ckpt_layers, s0.layers)}/{s0.layers} "
                    f"oo={s0.oo:.2f} ao={s0.ao:.2f}")
            rows.append(emit(f"motivation/{size}/{space}", dt, desc))
    return rows


if __name__ == "__main__":
    run()
