"""Paper §6.6 analogue: cost-model prediction accuracy.

The paper validates predicted runtime/memory against measured hardware
(1.79% / 2.10% error).  Without a TPU, the ground truth here is the
compiled XLA artifact from the dry-run: the symbolic cost model's FLOPs,
state-memory, and collective-byte predictions are compared against the
trip-count-weighted HLO analysis of every compiled (arch x shape) cell in
results/dryrun/."""
from __future__ import annotations

import json
import pathlib
from typing import List

import numpy as np

from benchmarks.common import emit
from repro.configs.base import SHAPES, get_arch
from repro.core.costmodel import StageCostModel
from repro.core.hardware import V5E
from repro.core.plan import Plan
from repro.core.schedule import Candidate

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def predict_cell(rec) -> dict:
    """Cost-model predictions for one dry-run record's plan."""
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    plan = Plan.from_json(json.dumps(rec["plan"]))
    st = plan.stages[0]
    scm = StageCostModel(cfg, shape.seq_len, sequence_parallel=
                         plan.sequence_parallel)
    cand = Candidate(b=st.micro_batch, dp=st.dp, tp=st.tp, zero=st.zero,
                     ckpt=min(st.ckpt_layers, st.layers), wo=st.wo,
                     go=st.go, oo=st.oo, ao=st.ao)
    env = scm.env_from_candidates([cand], layers=st.layers,
                                  grad_accum=plan.grad_accum)
    out = scm.evaluate(env)
    items = out["items"]
    G = plan.grad_accum
    # per-device dot flops per STEP (G microbatches + recompute)
    flops_expr_s = float(np.asarray(
        (scm.items["fwd"] + scm.items["bwd"]
         + scm.items["recompute"]).evaluate(scm._env(env))).reshape(-1)[0])
    # invert the time model back to flops: t * peak * eff / (1 + vpu_tax)
    tok = st.micro_batch * shape.seq_len
    eff = scm.cp.mxu_eff_floor + (scm.cp.mxu_eff_peak
                                  - scm.cp.mxu_eff_floor) * (
        tok / (tok + scm.cp.mxu_sat_tokens))
    pred_flops = (flops_expr_s / (1 + scm.cp.vpu_tax) * V5E.peak_flops_bf16
                  * eff) * G
    # collective wire bytes per step
    def sc(key):
        return float(np.asarray(items[key]).reshape(-1)[0])
    coll_s = sum(sc(k) for k in
                 ("tp_fwd", "tp_bwd", "zero3_allgather_fwd",
                  "zero3_allgather_bwd", "zero2_reduce_scatter")) * G \
        + sc("dp_grad_sync") + sc("zero1_param_allgather")
    pred_coll = coll_s * V5E.ici_bw_total * scm.cp.ici_eff
    return {"flops": pred_flops, "coll_bytes": pred_coll,
            "mem": float(out["mem_peak"][0])}


def run() -> List[str]:
    rows = []
    errs_f, errs_c, errs_m = [], [], []
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if not rec.get("ok") or rec.get("mesh") != "16x16":
            continue
        if rec["shape"] != "train_4k" or len(rec["plan"]["stages"]) != 1:
            continue
        recs.append(rec)
    from repro.core.hardware import V5E
    for rec in recs:
        pred = predict_cell(rec)
        hlo = rec["hlo_stats"]
        # ground truths: TPU-corrected collective bytes (the raw artifact
        # carries XLA:CPU's f32 promotion), analytic memory when present
        coll_gt = hlo["collective_wire_bytes"]
        t_tpu = rec["roofline"].get("t_collective_tpu")
        if t_tpu:
            coll_gt = t_tpu * V5E.ici_bw_total
        # memory ground truth stays the INDEPENDENT artifact number (the
        # analytic_bytes field is itself cost-model-derived for train cells)
        mem = rec["memory"]["device_total_bytes"]
        ef = abs(pred["flops"] - hlo["dot_flops"]) / hlo["dot_flops"]
        ec_ = abs(pred["coll_bytes"] - coll_gt) / max(coll_gt, 1.0)
        em = abs(pred["mem"] - mem) / mem
        errs_f.append(ef); errs_c.append(ec_); errs_m.append(em)
        rows.append(emit(
            f"accuracy/{rec['arch']}/{rec['shape']}", 0.0,
            f"flops_err={ef:.1%} coll_err={ec_:.1%} mem_err={em:.1%}"))
    if errs_f:
        rows.append(emit(
            "accuracy/mean", 0.0,
            f"flops={np.mean(errs_f):.1%} coll={np.mean(errs_c):.1%} "
            f"mem={np.mean(errs_m):.1%} over {len(errs_f)} cells"))
    else:
        rows.append(emit("accuracy/mean", 0.0,
                         "no dry-run artifacts; run repro.launch.dryrun"))
    return rows


if __name__ == "__main__":
    run()
