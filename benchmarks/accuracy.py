"""Paper §6.6 analogue: cost-model prediction accuracy.

The paper validates predicted runtime/memory against measured hardware
(1.79% / 2.10% error).  Two ground truths here:

* **artifact mode** (``run``, the default benchmark section): compiled
  XLA dry-run artifacts in results/dryrun — the symbolic cost model's
  FLOPs, state-memory, and collective-byte predictions against the
  trip-count-weighted HLO analysis of each compiled (arch x shape) cell.
* **measured mode** (``run_measured`` / ``--measured``): the calibration
  subsystem's host-executed golden cells (repro.calibration;
  docs/calibration.md) — predicted vs MEASURED step time, before and
  after fitting ``CostParams``/``InterferenceModel``, the way Fig. 11
  reports it.  ``--json`` writes the full report artifact (the CI
  calibration smoke uploads it).

Both modes read the model through its public surface only —
``evaluate_flops`` (the model's own kernel-config-invariant dot-flops
counts; no inline efficiency-formula inversion to drift) and
``env_from_candidates``/``evaluate`` with the plan's kernel knobs bound,
so the PR 6 kernel roofline delta is priced rather than ignored.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import emit
from repro.configs.base import SHAPES, get_arch
from repro.core.costmodel import StageCostModel
from repro.core.hardware import V5E
from repro.core.plan import Plan
from repro.core.schedule import Candidate

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def predict_cell(rec) -> dict:
    """Cost-model predictions for one dry-run record's plan."""
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    plan = Plan.from_json(json.dumps(rec["plan"]))
    st = plan.stages[0]
    scm = StageCostModel(cfg, shape.seq_len,
                         sequence_parallel=plan.sequence_parallel)
    kc = plan.kernel   # bind the plan's kernel tiles: tuned-kernel plans
    cand = Candidate(  # carry the roofline delta in their time items
        b=st.micro_batch, dp=st.dp, tp=st.tp, zero=st.zero,
        ckpt=min(st.ckpt_layers, st.layers), wo=st.wo,
        go=st.go, oo=st.oo, ao=st.ao,
        qb=kc.attn_q_block, kvb=kc.attn_kv_block,
        rnb=kc.rmsnorm_block, sch=kc.ssd_chunk)
    env = scm.env_from_candidates([cand], layers=st.layers,
                                  grad_accum=plan.grad_accum)
    out = scm.evaluate(env)
    items = out["items"]
    G = plan.grad_accum
    # per-device dot flops per STEP (G microbatches + recompute), straight
    # from the model's own flops exprs — kernel-config invariant, where
    # inverting the time items would not be (smax floor + kernel delta)
    fl = scm.evaluate_flops(env)
    pred_flops = float(sum(
        np.asarray(fl[k]).reshape(-1)[0]
        for k in ("fwd", "bwd", "recompute"))) * G

    # collective wire bytes per step
    def sc(key):
        return float(np.asarray(items[key]).reshape(-1)[0])
    coll_s = sum(sc(k) for k in
                 ("tp_fwd", "tp_bwd", "zero3_allgather_fwd",
                  "zero3_allgather_bwd", "zero2_reduce_scatter")) * G \
        + sc("dp_grad_sync") + sc("zero1_param_allgather")
    pred_coll = coll_s * scm.hw.ici_bw_total * scm.cp.ici_eff
    return {"flops": pred_flops, "coll_bytes": pred_coll,
            "mem": float(out["mem_peak"][0])}


def run() -> List[str]:
    rows = []
    errs_f, errs_c, errs_m = [], [], []
    recs = []
    # the artifact comparison needs single-stage 16x16/train_4k cells (the
    # production dry-run grid the roofline corrections were derived for);
    # everything else is counted and reported, never silently dropped
    skipped: Dict[str, int] = {"not_ok": 0, "mesh": 0, "shape": 0,
                               "multi_stage": 0}
    for p in sorted(RESULTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if not rec.get("ok"):
            skipped["not_ok"] += 1
            continue
        if rec.get("mesh") != "16x16":
            skipped["mesh"] += 1
            continue
        if rec["shape"] != "train_4k":
            skipped["shape"] += 1
            continue
        if len(rec["plan"]["stages"]) != 1:
            skipped["multi_stage"] += 1
            continue
        recs.append(rec)
    for rec in recs:
        pred = predict_cell(rec)
        hlo = rec["hlo_stats"]
        # ground truths: TPU-corrected collective bytes (the raw artifact
        # carries XLA:CPU's f32 promotion), analytic memory when present
        coll_gt = hlo["collective_wire_bytes"]
        t_tpu = rec["roofline"].get("t_collective_tpu")
        if t_tpu:
            coll_gt = t_tpu * V5E.ici_bw_total
        # memory ground truth stays the INDEPENDENT artifact number (the
        # analytic_bytes field is itself cost-model-derived for train cells)
        mem = rec["memory"]["device_total_bytes"]
        ef = abs(pred["flops"] - hlo["dot_flops"]) / hlo["dot_flops"]
        ec_ = abs(pred["coll_bytes"] - coll_gt) / max(coll_gt, 1.0)
        em = abs(pred["mem"] - mem) / mem
        errs_f.append(ef); errs_c.append(ec_); errs_m.append(em)
        rows.append(emit(
            f"accuracy/{rec['arch']}/{rec['shape']}", 0.0,
            f"flops_err={ef:.1%} coll_err={ec_:.1%} mem_err={em:.1%}"))
    if errs_f:
        rows.append(emit(
            "accuracy/mean", 0.0,
            f"flops={np.mean(errs_f):.1%} coll={np.mean(errs_c):.1%} "
            f"mem={np.mean(errs_m):.1%} over {len(errs_f)} cells"))
    else:
        rows.append(emit("accuracy/mean", 0.0,
                         "no dry-run artifacts; run repro.launch.dryrun"))
    n_skip = sum(skipped.values())
    if n_skip:   # no-silent-caps: say what was dropped and why
        detail = " ".join(f"{k}={v}" for k, v in skipped.items() if v)
        rows.append(emit("accuracy/skipped", 0.0,
                         f"{n_skip} artifacts excluded: {detail}"))
    return rows


def run_measured(*, archs: Optional[Sequence[str]] = None, steps: int = 4,
                 warmup: int = 2, seq_len: int = 128, smoke: bool = False,
                 json_path: Optional[str] = None):
    """Measured-ground-truth mode: execute the golden cells, fit a
    profile, and report predicted-vs-measured step-time error before and
    after fitting (paper Fig. 11 style)."""
    from repro.calibration.driver import run_calibration, write_report
    from repro.calibration.measure import GOLDEN_ARCHS

    report = run_calibration(
        archs=tuple(archs or GOLDEN_ARCHS),
        steps=min(steps, 3) if smoke else steps,
        warmup=min(warmup, 1) if smoke else warmup,
        seq_len=seq_len, max_cells_per_arch=2 if smoke else None)
    rows = []
    for c in report.get("cells", []):
        rows.append(emit(
            f"accuracy_measured/{c['label']}", c["t_measured"] * 1e6,
            f"err_uncal={c['err_uncalibrated']:.1%} "
            f"err_fit={c['err_fitted']:.1%}"))
    if report.get("error"):
        rows.append(emit("accuracy_measured/mean", 0.0, report["error"]))
    else:
        rows.append(emit(
            "accuracy_measured/mean", 0.0,
            f"uncal={report['mean_err_uncalibrated']:.1%} "
            f"fitted={report['mean_err_fitted']:.1%} "
            f"improved={report['improved']} over {report['n_cells']} cells"))
    if report.get("skipped_cells"):
        names = "; ".join(f"{s['arch']}/{s['label']}"
                          for s in report["skipped_cells"])
        rows.append(emit(
            "accuracy_measured/skipped", 0.0,
            f"{len(report['skipped_cells'])} cells not measured: {names}"))
    if json_path:
        write_report(report, json_path)
    return rows, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--measured", action="store_true",
                    help="measured-step-time ground truth (executes the "
                         "golden cells) instead of dry-run artifacts")
    ap.add_argument("--archs", default=None,
                    help="comma-separated archs (measured mode)")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized measured run (2 cells/arch, 3 steps)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the measured-mode report artifact")
    args = ap.parse_args(argv)
    # emit() already prints each row as it is produced
    if args.measured:
        _rows, report = run_measured(
            archs=(tuple(a for a in args.archs.split(",") if a)
                   if args.archs else None),
            steps=args.steps, seq_len=args.seq_len, smoke=args.smoke,
            json_path=args.json)
        if report.get("error"):
            return 1
        # fitting making things WORSE than the defaults is a bug (the
        # keep-if-better guard in fit_profile should make it impossible)
        return 1 if (report["mean_err_fitted"]
                     > report["mean_err_uncalibrated"] + 1e-12) else 0
    run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
