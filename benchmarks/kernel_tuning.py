"""Kernel-config tuning: the tile/block dimension of the plan space
(docs/kernel-tuning.md), measured end to end.

Four measurement groups:

  * **frozen-default byte-identity** — with the kernel dimension frozen
    to the default tuple (the default ``TuneSpec``), golden cells
    reproduce their committed fixtures fingerprint-for-fingerprint, and
    passing ``kernel_grid=DEFAULT_KERNEL_GRID`` explicitly selects a
    byte-identical plan.  The kernel machinery must be invisible until
    actually swept — this is the benchmark-level twin of
    ``tools/regen_golden.py --check``.
  * **tuned vs default** — the same cell swept with
    ``kernel_tune=True``: the tuner's objective with the kernel
    dimension open vs frozen (the default tuple rides in every legal
    grid, so tuned <= default is asserted, not hoped), the selected
    tile tuple, and the roofline-predicted per-op kernel times for
    both.
  * **verify-by-compile** — every tuner-selected config is instantiated
    through the real Pallas kernels (``interpret=True`` off-TPU) via
    ``repro.kernels.autotune.verify_config``; a config that fails to
    compile fails the benchmark.
  * **measured kernel step time** — ``bench_config`` medians through
    the real kernels for the default vs the selected tiles (host
    interpret mode off-TPU: absolute numbers are simulation-speed, the
    tile-to-tile *ratio* is the signal; on a TPU host the same rows are
    hardware medians).

Run with --smoke for a CI-sized invocation (reduced golden arch, one
fixture cell, one bench rep); --json PATH additionally writes the rows
as a JSON document (uploaded as a CI artifact next to the tuning-time
report).
"""
from __future__ import annotations

import json
import sys
import time
from typing import List

from benchmarks.common import emit
from repro.configs.base import get_arch
from repro.core import golden
from repro.core.plan import DEFAULT_KERNEL_CONFIG, KernelConfig
from repro.core.schedule import DEFAULT_KERNEL_GRID
from repro.core.tuner import MistTuner, TuneSpec

SMOKE_CELL = ("megatron", "granite-3-8b")


def _spec(arch, **kw) -> TuneSpec:
    """The golden workload (core/golden.py) on a given arch object."""
    return TuneSpec(arch=arch, **{**golden._WORKLOAD, **kw})


def run_frozen_identity(cells) -> List[str]:
    """Kernel knobs frozen to defaults -> committed fixtures, byte for
    byte (fingerprint over the canonicalized tuner document)."""
    rows = []
    for space, arch in cells:
        path = golden.golden_path(space, arch)
        if not path.exists():
            rows.append(emit(f"kernel_tuning/frozen_identity/{space}_{arch}",
                             0.0, "skipped=no_fixture"))
            continue
        want = json.loads(path.read_text())["fingerprint"]
        t0 = time.perf_counter()
        doc = golden.compute_doc(space, arch)
        dt = time.perf_counter() - t0
        got = golden.fingerprint(doc)
        assert got == want, (
            f"frozen-default plan drifted from fixture for {space}/{arch}: "
            f"{got} != {want}")
        rows.append(emit(f"kernel_tuning/frozen_identity/{space}_{arch}",
                         dt * 1e6, f"seconds={dt:.2f} fingerprint_match=True"))
    return rows


def run_explicit_default_grid(arch) -> List[str]:
    """kernel_grid=DEFAULT_KERNEL_GRID is the same sweep as not
    mentioning kernels at all — byte-identical plan JSON."""
    r0 = MistTuner(_spec(arch)).tune()
    r1 = MistTuner(_spec(arch, kernel_grid=DEFAULT_KERNEL_GRID)).tune()
    assert r0.objective == r1.objective \
        and r0.plan.to_json() == r1.plan.to_json(), \
        "explicit default kernel grid changed the selected plan"
    return [emit("kernel_tuning/explicit_default_grid", 0.0,
                 f"identical_plans=True arch={arch.name}")]


def run_tuned_vs_default(arch, *, verify_seq: int = 512) -> List[str]:
    """Open the kernel dimension on one golden cell: tuned objective vs
    frozen default, selected tiles, roofline per-op times, and the
    verify-by-compile gate on whatever the tuner picked."""
    from repro.kernels.autotune import predict_times, verify_config
    t0 = time.perf_counter()
    base = MistTuner(_spec(arch)).tune()
    t_base = time.perf_counter() - t0
    t0 = time.perf_counter()
    tuned = MistTuner(_spec(arch, kernel_tune=True)).tune()
    t_tuned = time.perf_counter() - t0
    assert tuned.objective <= base.objective, \
        "kernel sweep worsened the objective (default rides in the grid)"
    imp = (base.objective - tuned.objective) / base.objective
    kc = tuned.plan.kernel
    seq = _spec(arch).seq_len
    st = tuned.plan.stages[0]
    pt_def = predict_times(arch, seq_len=seq, config=DEFAULT_KERNEL_CONFIG,
                           b=float(st.micro_batch), tp=float(st.tp))
    pt_sel = predict_times(arch, seq_len=seq, config=kc,
                           b=float(st.micro_batch), tp=float(st.tp))
    verify_config(arch, seq_len=verify_seq, config=kc)
    return [
        emit("kernel_tuning/objective_default", base.objective * 1e6,
             f"tune_seconds={t_base:.2f} arch={arch.name}"),
        emit("kernel_tuning/objective_tuned", tuned.objective * 1e6,
             f"tune_seconds={t_tuned:.2f} config={kc.astuple()}"),
        emit("kernel_tuning/objective_improvement", 0.0,
             f"{imp * 100:.2f}% (0% means the default tuple won)"),
        emit("kernel_tuning/roofline_default_us",
             pt_def["total"] * 1e6,
             " ".join(f"{k}={v * 1e6:.2f}us" for k, v in pt_def.items())),
        emit("kernel_tuning/roofline_tuned_us",
             pt_sel["total"] * 1e6,
             " ".join(f"{k}={v * 1e6:.2f}us" for k, v in pt_sel.items())),
        emit("kernel_tuning/verify_compile", 0.0,
             f"config={kc.astuple()} pallas_interpret_ok=True"),
    ]


def run_kernel_bench(arch, *, seq: int = 512, reps: int = 1) -> List[str]:
    """Measured per-op medians through the real kernels, default tiles vs
    the best non-default legal tuple (host interpret off-TPU)."""
    from repro.kernels.autotune import bench_config, legal_kernel_grid
    grid = legal_kernel_grid(arch, seq_len=seq)
    alt = next((t for t in grid if t != DEFAULT_KERNEL_CONFIG.astuple()),
               None)
    rows = []
    m_def = bench_config(arch, seq_len=seq, config=DEFAULT_KERNEL_CONFIG,
                         reps=reps)
    rows.append(emit("kernel_tuning/bench_default",
                     sum(m_def.values()) * 1e6,
                     " ".join(f"{k}={v * 1e6:.1f}us"
                              for k, v in sorted(m_def.items()))))
    if alt is not None:
        m_alt = bench_config(arch, seq_len=seq, config=KernelConfig(*alt),
                             reps=reps)
        rows.append(emit("kernel_tuning/bench_best_alt",
                         sum(m_alt.values()) * 1e6,
                         f"config={alt} " +
                         " ".join(f"{k}={v * 1e6:.1f}us"
                                  for k, v in sorted(m_alt.items()))))
    return rows


def run(smoke: bool = False) -> List[str]:
    if smoke:
        arch = get_arch("granite-3-8b").reduced()
        return (run_frozen_identity([SMOKE_CELL])
                + run_explicit_default_grid(arch)
                + run_tuned_vs_default(arch, verify_seq=512)
                + run_kernel_bench(arch, seq=512, reps=1))
    cells = [(s, a) for s in golden.GOLDEN_SPACES
             for a in golden.GOLDEN_ARCHS]
    arch = get_arch("granite-3-8b")
    return (run_frozen_identity(cells)
            + run_explicit_default_grid(get_arch("granite-3-8b").reduced())
            + run_tuned_vs_default(arch)
            + run_kernel_bench(arch, seq=2048, reps=3))


def rows_to_json(rows: List[str]) -> dict:
    out = []
    for r in rows:
        name, value, notes = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(value),
                    "notes": notes})
    return {"benchmark": "kernel_tuning", "rows": out}


if __name__ == "__main__":
    rows = run(smoke="--smoke" in sys.argv)
    if "--json" in sys.argv:
        path = sys.argv[sys.argv.index("--json") + 1]
        with open(path, "w") as f:
            json.dump(rows_to_json(rows), f, indent=2)
        print(f"wrote {path}")
