#!/usr/bin/env python
"""Calibrate ``CostParams.runtime_reserved`` against real allocator stats.

The cost model charges every plan a constant ``runtime_reserved`` term for
what the analytic terms cannot see: the XLA runtime's own allocations,
allocator fragmentation, and compiler-inserted scratch that is not
attributable to any modeled tensor.  Everything else in the memory model
is spec-exact (PR 5) and shared between the predictor and
``memory_report()`` — so this constant is the ONLY term whose value is an
estimate rather than a derivation, and the only reason
``MEMORY_REL_TOL`` is not literally zero against *measured* memory.

This tool pins the constant to evidence instead of folklore:

1. It compiles the REAL program for one or more golden cells (reduced
   configs by default, so a CPU container can run it): the train step
   and the decode step, exactly as ``launch/dryrun.py`` lowers them.
2. It reads the compiled executable's memory analysis
   (``argument + temp + output - alias``, per device) and — where the
   backend exposes one (TPU/GPU) — the live allocator's
   ``device.memory_stats()`` peak.
3. ``implied_reserved = measured - (modeled_peak - runtime_reserved)``:
   what the constant WOULD have to be for the model to match the
   measurement exactly on that cell.  The suggestion is the max over
   cells, rounded up to 64 MiB.

On CPU hosts the measurement is the compile-time analysis only (XLA:CPU
additionally f32-legalizes bf16 compute, inflating temp bytes — see the
caveat in ``launch/dryrun.py``), so the printed suggestion is an upper
bound sanity check, not a refit; re-run on a real accelerator host to
refit the default.  Run with ``--json`` to archive the evidence next to
the benchmark artifacts.

With ``--write-profile PATH|auto`` the suggestion is folded into the
platform's ``CalibrationProfile`` as a sparse ``runtime_reserved`` cost
override (merged over any constants ``tools/calibrate.py`` already
fitted — the two tools share one profile file).  ``auto`` resolves to
the platform's default cache location, which ``TuneSpec.profile=
load_profile()`` / ``StageCostModel(profile=...)`` pick up on the next
run.  On CPU-sourced measurements the write is refused unless
``--force`` is given, for the f32-legalization reason above.

Usage:
    PYTHONPATH=src python tools/calibrate_reserved.py [--arch granite-3-8b]
        [--full] [--json PATH] [--write-profile PATH|auto]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Any, Dict, List


def measure_cell(arch: str, kind: str, *, reduced: bool,
                 batch: int, seq_len: int) -> Dict[str, Any]:
    """Compile one golden cell's real step and compare the executable's
    measured bytes against the modeled terms."""
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs.base import ShapeConfig, get_arch
    from repro.core.costmodel import CostParams
    from repro.core.plan import single_stage_plan
    from repro.lowering import lower_plan
    from repro.models.zoo import build_model, input_specs
    from repro.training import optimizer as OPT
    from repro.training.step import make_serve_step, make_train_step

    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    n = len(jax.devices())
    cp = CostParams()

    shape = ShapeConfig("calib", seq_len, batch, kind)
    plan = single_stage_plan(
        cfg.num_layers, dp=n, tp=1, micro_batch=max(1, batch // n),
        grad_accum=max(1, batch // (n * max(1, batch // n)))
        if kind == "train" else 1,
        zero=0, ckpt_layers=0,
        **({} if kind == "train" else dict(remat_policy="none")))
    mesh = compat.make_mesh((n, 1), ("data", "model"))
    low = lower_plan(cfg, shape, plan, mesh)

    def attach(sds_tree, shardings):
        return jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            sds_tree, shardings)

    with compat.set_mesh(mesh):
        if kind == "train":
            step = make_train_step(model, plan, mesh, lowered=low)
            state_abs = OPT.init_state(low.params_sds, low.axes_table,
                                       plan.stages[0])
            state_sds = attach(state_abs, step.state_shardings)
            batch_abs = input_specs(cfg, shape)
            batch_sds = attach(batch_abs, low.batch_shardings(batch_abs))
            compiled = step.fn.lower(state_sds, batch_sds).compile()
        else:  # decode
            step = make_serve_step(model, plan, mesh, batch, seq_len,
                                   lowered=low)
            p_sds = attach(low.params_sds, low.param_shardings())
            spec = input_specs(cfg, shape)
            cache_sds = attach(spec["caches"], step.batch_shardings)
            compiled = step.fn.lower(p_sds, spec["tokens"],
                                     cache_sds).compile()

    ma = compiled.memory_analysis()
    measured = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)

    # real allocator stats, where the backend keeps them (TPU/GPU)
    dev = jax.devices()[0]
    stats = dev.memory_stats() if hasattr(dev, "memory_stats") else None
    allocator_peak = (stats or {}).get("peak_bytes_in_use")

    rep = low.memory_report()
    modeled_peak = rep.peak_bytes
    modeled_sans_reserved = modeled_peak - cp.runtime_reserved
    best = allocator_peak if allocator_peak is not None else measured
    implied = best - modeled_sans_reserved
    return {
        "arch": cfg.name, "kind": kind,
        "plan": f"dp{n}_tp1_z0",
        "measured_exec_bytes": int(measured),
        "allocator_peak_bytes": allocator_peak,
        "measurement_source": ("allocator" if allocator_peak is not None
                               else "memory_analysis"),
        "modeled_peak_bytes": float(modeled_peak),
        "modeled_sans_reserved_bytes": float(modeled_sans_reserved),
        "current_reserved_bytes": float(cp.runtime_reserved),
        "implied_reserved_bytes": float(implied),
    }


def suggest(cells: List[Dict[str, Any]]) -> float:
    """Max implied reserve over cells, rounded UP to 64 MiB (never
    suggest below zero: a negative implication means the analytic terms
    over-cover on that backend, which is safe)."""
    step = 64 * 2**20
    worst = max((c["implied_reserved_bytes"] for c in cells), default=0.0)
    return max(0.0, math.ceil(worst / step) * step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — needs a real host")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--json", metavar="PATH")
    ap.add_argument("--write-profile", metavar="PATH|auto", default=None,
                    help="fold the suggested runtime_reserved into the "
                         "platform CalibrationProfile (auto = default "
                         "cache location)")
    ap.add_argument("--force", action="store_true",
                    help="write the profile even from a CPU-only "
                         "measurement (upper bound, not a refit)")
    args = ap.parse_args(argv)

    cells = []
    for kind in ("train", "decode"):
        c = measure_cell(args.arch, kind, reduced=not args.full,
                         batch=args.batch, seq_len=args.seq_len)
        cells.append(c)
        print(f"{c['arch']:24s} {kind:7s} source={c['measurement_source']:15s}"
              f" measured={c['measured_exec_bytes'] / 2**20:9.1f} MiB"
              f" modeled-sans-reserved="
              f"{c['modeled_sans_reserved_bytes'] / 2**20:9.1f} MiB"
              f" implied-reserved="
              f"{c['implied_reserved_bytes'] / 2**20:9.1f} MiB")

    cur = cells[0]["current_reserved_bytes"]
    sug = suggest(cells)
    on_accel = any(c["measurement_source"] == "allocator" for c in cells)
    print(f"current CostParams.runtime_reserved: {cur / 2**20:.0f} MiB")
    print(f"suggested (max over cells, 64 MiB-aligned): "
          f"{sug / 2**20:.0f} MiB"
          + ("" if on_accel else
             "  [CPU memory_analysis only — upper-bound sanity check; "
             "refit on an accelerator host]"))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"cells": cells,
                       "current_reserved_bytes": cur,
                       "suggested_reserved_bytes": sug,
                       "accelerator_measurement": on_accel}, f, indent=2)
        print(f"wrote {args.json}")

    if args.write_profile:
        if not on_accel and not args.force:
            print("refusing --write-profile from a CPU-only measurement "
                  "(memory_analysis over-counts under f32 legalization); "
                  "re-run on an accelerator host or pass --force",
                  file=sys.stderr)
            return 1
        from repro.calibration.profile import (default_platform,
                                               load_profile, profile_path)
        platform = default_platform()
        path = (profile_path(platform) if args.write_profile == "auto"
                else args.write_profile)
        # merge over whatever tools/calibrate.py already fitted for this
        # platform; an absent file starts from the frozen defaults
        base = load_profile(platform=platform,
                            path=path if os.path.exists(str(path)) else None)
        prof = base.with_cost(runtime_reserved=sug)
        if prof.platform == "default":
            import dataclasses
            prof = dataclasses.replace(prof, platform=platform,
                                       source="calibrate_reserved")
        prof.save(path)
        print(f"wrote runtime_reserved={sug / 2**20:.0f} MiB into {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
