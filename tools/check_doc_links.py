#!/usr/bin/env python
"""Verify that relative markdown links/references in docs/*.md (plus the
top-level ROADMAP.md) point at files that exist, so the docs cross-links
stay valid as the tree moves.  External (http/https/mailto) links and
intra-page anchors are ignored.  Exit code 1 on any broken reference."""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick references like `src/repro/core/sweep.py` or `docs/foo.md`
TICK = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|yml|yaml|toml|txt))`")


def refs(md: pathlib.Path):
    text = md.read_text()
    for m in LINK.finditer(text):
        yield m.group(1), "link"
    for m in TICK.finditer(text):
        yield m.group(1), "ref"


def main() -> int:
    bad = []
    files = sorted(ROOT.glob("docs/*.md")) + [ROOT / "ROADMAP.md"]
    for md in files:
        if not md.exists():
            continue
        for target, kind in refs(md):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            cand = (md.parent / path).resolve()
            cand_root = (ROOT / path).resolve()
            if not cand.exists() and not cand_root.exists():
                bad.append(f"{md.relative_to(ROOT)}: broken {kind} -> "
                           f"{target}")
    for b in bad:
        print(b)
    if bad:
        print(f"{len(bad)} broken doc reference(s)")
        return 1
    print(f"doc links OK ({len(files)} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
