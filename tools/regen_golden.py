#!/usr/bin/env python
"""Regenerate the golden-plan regression fixtures under tests/golden/.

Run after an INTENTIONAL change to the cost model / schedule / tuner and
commit the rewritten fixtures together with that change:

    PYTHONPATH=src python tools/regen_golden.py            # all cells
    PYTHONPATH=src python tools/regen_golden.py --only mist:granite-3-8b

``tests/test_golden_plans.py`` fails with a field-level diff whenever a
recomputed plan drifts from these fixtures.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import golden  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="SPACE:ARCH",
                    help="regenerate a single cell, e.g. mist:granite-3-8b")
    args = ap.parse_args()
    only = None
    if args.only:
        space, _, arch = args.only.partition(":")
        if space not in golden.GOLDEN_SPACES or arch not in golden.GOLDEN_ARCHS:
            ap.error(f"unknown cell {args.only!r}; spaces="
                     f"{golden.GOLDEN_SPACES} archs={golden.GOLDEN_ARCHS}")
        only = (space, arch)
    written = golden.regen(only=only)
    for p in written:
        print(f"wrote {p.relative_to(Path.cwd())}"
              if p.is_relative_to(Path.cwd()) else f"wrote {p}")
    print(f"{len(written)} fixture(s) regenerated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
