#!/usr/bin/env python
"""Regenerate or verify the golden-plan regression fixtures under
tests/golden/.

Run after an INTENTIONAL change to the cost model / schedule / tuner and
commit the rewritten fixtures together with that change:

    PYTHONPATH=src python tools/regen_golden.py            # all cells
    PYTHONPATH=src python tools/regen_golden.py --only mist:granite-3-8b

``--check`` regenerates every cell in-memory only, diffs it against the
committed fixtures, and exits nonzero on drift — CI runs this so a
model/tuner change that forgot to regenerate fixtures fails fast with a
readable field-level diff instead of a cryptic sha mismatch:

    PYTHONPATH=src python tools/regen_golden.py --check

``tests/test_golden_plans.py`` fails with the same field-level diff
whenever a recomputed plan drifts from these fixtures.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import golden  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", metavar="SPACE:ARCH",
                    help="regenerate a single cell, e.g. mist:granite-3-8b")
    ap.add_argument("--check", action="store_true",
                    help="verify fixtures against an in-memory regen; "
                         "write nothing, exit 1 on drift")
    args = ap.parse_args()
    if args.check:
        if args.only:
            ap.error("--check verifies every cell; drop --only")
        problems = golden.check()
        if not problems:
            n = len(golden.GOLDEN_SPACES) * len(golden.GOLDEN_ARCHS)
            print(f"{n} golden fixture(s) up to date")
            return 0
        for (space, arch), diffs in sorted(problems.items()):
            print(f"STALE {space}:{arch}")
            for d in diffs[:20]:
                print(f"  {d}")
            if len(diffs) > 20:
                print(f"  ... {len(diffs) - 20} more")
        print(f"{len(problems)} golden fixture(s) out of date; rerun "
              f"'PYTHONPATH=src python tools/regen_golden.py' and commit "
              f"the diff with the change that caused it")
        return 1
    only = None
    if args.only:
        space, _, arch = args.only.partition(":")
        if space not in golden.GOLDEN_SPACES or arch not in golden.GOLDEN_ARCHS:
            ap.error(f"unknown cell {args.only!r}; spaces="
                     f"{golden.GOLDEN_SPACES} archs={golden.GOLDEN_ARCHS}")
        only = (space, arch)
    written = golden.regen(only=only)
    for p in written:
        print(f"wrote {p.relative_to(Path.cwd())}"
              if p.is_relative_to(Path.cwd()) else f"wrote {p}")
    print(f"{len(written)} fixture(s) regenerated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
