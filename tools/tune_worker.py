#!/usr/bin/env python
"""Run a sweep executor daemon (docs/distributed-sweep.md).

    PYTHONPATH=src python tools/tune_worker.py --port 7421 --workers 8

Point a tuner at it with `TuneSpec(hosts=("thathost:7421",))` (or
`tune(..., hosts=...)`); shards of the hypothesis sweep are shipped
over and the merged plan stays byte-identical to a serial tune.
"""
import sys

from repro.service.worker import main

if __name__ == "__main__":
    sys.exit(main())
