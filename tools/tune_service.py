#!/usr/bin/env python
"""Run the persistent tuning service (docs/distributed-sweep.md).

    PYTHONPATH=src python tools/tune_service.py \
        --memo-dir ~/.cache/repro/memo --workers 8

Clients call `repro.service.tune_remote(spec, "host:port")`; warm
queries answer from the on-disk report cache in milliseconds, cold
queries sweep (optionally fanning out to `tools/tune_worker.py` hosts
via --hosts) and persist their frontiers for future queries.
"""
import sys

from repro.service.tune_service import main

if __name__ == "__main__":
    sys.exit(main())
