#!/usr/bin/env python
"""Measurement-driven calibration of the cost model's time-side constants.

Closes the tune→execute→measure loop (docs/calibration.md): runs the
golden cells end-to-end through ``lower_plan`` → ``make_train_step`` on
the live devices, measures warmed median step times + allocator stats,
fits ``CostParams`` / ``InterferenceModel.factors`` (and, with
``--kernels``, the ``KernelCoeffs`` anchors via the Pallas bench cache),
and prints the predicted-vs-measured error table before and after
fitting.  ``--write-profile`` persists the fitted per-platform
``CalibrationProfile`` where ``StageCostModel`` / ``TuneSpec`` load it.

Usage:
    PYTHONPATH=src python tools/calibrate.py [--smoke] [--json PATH]
        [--write-profile PATH|auto] [--devices N] [--archs a,b]

Exit status is nonzero if fitting made the mean error WORSE than the
uncalibrated defaults (the keep-if-better guard makes that a bug, not a
bad-measurement outcome).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--archs", default="granite-3-8b,qwen2-moe-a2.7b",
                    help="comma-separated golden archs to measure")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices via XLA_FLAGS (must run "
                         "before jax initializes; >1 exercises the "
                         "collective items)")
    ap.add_argument("--platform", default=None,
                    help="profile platform key (default: jax backend)")
    ap.add_argument("--no-interference", action="store_true",
                    help="skip the InterferenceModel.factors refit")
    ap.add_argument("--kernels", action="store_true",
                    help="also anchor KernelCoeffs *_scale via the "
                         "kernels.autotune bench cache")
    ap.add_argument("--write-profile", default=None, metavar="PATH|auto",
                    help="persist the fitted profile (auto = the "
                         "platform's default cache location)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 2 host devices, 2 cells/arch, "
                         "3 timed steps")
    args = ap.parse_args(argv)

    if args.smoke:
        args.steps = min(args.steps, 3)
        args.warmup = min(args.warmup, 1)
        if args.devices is None:
            args.devices = 2
    if args.devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()

    # import only after XLA_FLAGS is set — jax reads it at first import
    from repro.calibration.driver import (format_table, run_calibration,
                                          write_report)

    report = run_calibration(
        archs=tuple(a for a in args.archs.split(",") if a),
        steps=args.steps, warmup=args.warmup, seq_len=args.seq_len,
        platform=args.platform, fit_interference=not args.no_interference,
        fit_kernels=args.kernels, write_profile=args.write_profile,
        max_cells_per_arch=2 if args.smoke else None)
    print(format_table(report))
    if args.json:
        write_report(report, args.json)
        print(f"wrote {args.json}")
    if report.get("error"):
        return 1
    worse = (report["mean_err_fitted"]
             > report["mean_err_uncalibrated"] + 1e-12)
    return 1 if worse else 0


if __name__ == "__main__":
    sys.exit(main())
