"""The Plan -> LoweredPlan pass.

Lowering is split in two strictly separated halves:

* **lower_plan** computes pure *metadata*: per-stage mesh-axis mapping,
  PartitionSpec tables for params / optimizer state / gradients, host
  offload split points, ExecConfigs (remat/offload segmentation, kernel
  and attention implementation selection), and — for S > 1 — the pipeline
  stage-block tables (stacked 'stage'-dim specs + the shard_map manual
  specs).  This half never touches devices, so it runs identically on
  concrete meshes and on :func:`repro.compat.abstract_mesh` shells (the
  dryrun / analysis path).

* **LoweredPlan methods** materialize that metadata into NamedShardings
  (including ``pinned_host`` memory kinds for offloaded slices, with the
  same graceful degradation as before via ``repro.compat``) on demand —
  only execution paths pay for it, and only they need real devices.

The spec *functions* (param_spec / grad_spec / opt_spec / cache_specs /
batch_specs) stay in ``repro.parallel.sharding``; this module is their
single runtime caller.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import Plan, StageConfig
from repro.models.common import Axes, ExecConfig, ShardRules
from repro.parallel import sharding as SH


def plan_mesh_axes(mesh, tp_size: int) -> SH.MeshAxes:
    """Plan-aware mesh-axis mapping: a tp=1 plan folds the 'model' axis
    into DP/FSDP (the production mesh shape is fixed; which axes mean what
    is the plan's decision — e.g. indivisible-head archs want tp=1 and
    pure-FSDP over all 256 chips)."""
    ma = SH.MeshAxes.from_mesh(mesh)
    if tp_size == 1 and ma.tp is not None:
        dp = ma.dp + (ma.tp,)
        return SH.MeshAxes(dp=dp, tp=None, fsdp=dp)
    return ma


def stage_exec_config(plan: Plan, stage: StageConfig) -> ExecConfig:
    """CKPT_i/AO_i -> remat segmentation + kernel/attention selection."""
    ck = min(stage.ckpt_layers, stage.layers)
    kc = plan.kernel
    return ExecConfig(
        ckpt_layers=ck,
        offload_layers=int(round(stage.ao * ck)),
        remat_policy=plan.remat_policy,
        attn_impl=plan.attn_impl,
        use_pallas=plan.use_pallas,
        sequence_parallel=plan.sequence_parallel,
        attn_q_block=kc.attn_q_block,
        attn_kv_block=kc.attn_kv_block,
        rmsnorm_block=kc.rmsnorm_block,
        ssd_chunk=kc.ssd_chunk,
    )


@dataclass(frozen=True)
class LoweredStage:
    """Everything one pipeline stage means, as pure metadata."""
    index: int
    stage: StageConfig
    mesh_axes: SH.MeshAxes
    exec_cfg: ExecConfig                  # train-mode segmentation
    ep_ok: bool
    param_specs: Dict[str, P]             # bf16 weights
    grad_specs: Dict[str, P]              # f32 grad accumulator
    opt_specs: Dict[str, P]               # f32 master / mu / nu
    master_split: Dict[str, int]          # WO_i: leading host slices
    opt_split: Dict[str, int]             # OO_i: leading host slices
    has_embed: bool = True
    has_head: bool = True
    # live microbatches at this stage's memory peak (1F1B: S - i)
    inflight: int = 1


@dataclass(frozen=True)
class LoweredPlan:
    """One plan, fully interpreted against one mesh.

    ``stages`` carry the per-stage metadata; the methods materialize
    NamedShardings (execution) or walk the spec tables (analysis).
    Pipeline-specific tables (``pipeline_*``) exist when S > 1.
    """
    cfg: ArchConfig
    shape: Optional[ShapeConfig]
    plan: Plan
    mesh: Any
    params_sds: Dict[str, Any]
    axes_table: Axes
    stages: Tuple[LoweredStage, ...]
    # S > 1: stacked-layer dim 0 -> 'stage' (sharding-as-stage-assignment)
    pipeline_param_specs: Optional[Dict[str, P]] = None
    # shard_map in_specs: mention ONLY the manual 'stage' axis
    pipeline_manual_specs: Optional[Dict[str, P]] = None

    # -- exec configs ---------------------------------------------------------

    @property
    def plan_exec_cfg(self) -> ExecConfig:
        """Plan-level knobs only (no per-stage remat clamp) — the pipeline
        embed/unembed path and other stage-agnostic compute."""
        kc = self.plan.kernel
        return ExecConfig(remat_policy=self.plan.remat_policy,
                          attn_impl=self.plan.attn_impl,
                          use_pallas=self.plan.use_pallas,
                          sequence_parallel=self.plan.sequence_parallel,
                          attn_q_block=kc.attn_q_block,
                          attn_kv_block=kc.attn_kv_block,
                          rmsnorm_block=kc.rmsnorm_block,
                          ssd_chunk=kc.ssd_chunk)

    @property
    def serve_exec_cfg(self) -> ExecConfig:
        """Inference never remats/offloads activations."""
        return self.stages[0].exec_cfg.replace(
            remat_policy="none", ckpt_layers=0, offload_layers=0)

    # -- spec-table materialization (single-stage SPMD) -----------------------

    def shard_rules(self, i: int = 0) -> ShardRules:
        return SH.make_shard_rules(self.mesh, self.stages[i].mesh_axes,
                                   self.plan.sequence_parallel)

    def param_shardings(self, i: int = 0) -> Dict[str, Any]:
        from jax.sharding import NamedSharding
        return {n: NamedSharding(self.mesh, sp)
                for n, sp in self.stages[i].param_specs.items()}

    def grad_shardings(self, i: int = 0) -> Dict[str, Any]:
        from jax.sharding import NamedSharding
        return {n: NamedSharding(self.mesh, sp)
                for n, sp in self.stages[i].grad_specs.items()}

    def state_shardings(self, i: int = 0) -> Dict[str, Any]:
        """NamedShardings mirroring the optimizer-state pytree: params by
        param_specs, master/mu/nu by opt_specs, WO/OO-split leaves as
        {"host", "dev"} pairs with the host part on ``pinned_host`` (or
        resident where the backend has no host memory space)."""
        st = self.stages[i]
        return self._opt_tree(st.param_specs, st.opt_specs,
                              st.master_split, st.opt_split)

    def _opt_tree(self, pspecs, ospecs, master_split, opt_split):
        from jax.sharding import NamedSharding
        hk = compat.host_memory_kind()

        def entry(split):
            out = {}
            for n, spec in ospecs.items():
                if split.get(n, 0):
                    host = (NamedSharding(self.mesh, spec, memory_kind=hk)
                            if hk else NamedSharding(self.mesh, spec))
                    out[n] = {"host": host,
                              "dev": NamedSharding(self.mesh, spec)}
                else:
                    out[n] = NamedSharding(self.mesh, spec)
            return out

        return {
            "step": NamedSharding(self.mesh, P()),
            "params": {n: NamedSharding(self.mesh, sp)
                       for n, sp in pspecs.items()},
            "master": entry(master_split),
            "mu": entry(opt_split),
            "nu": entry(opt_split),
        }

    # -- batch / cache (data-entry) shardings ---------------------------------

    def batch_shardings(self, batch, i: int = 0):
        return SH.batch_specs(batch, self.mesh, self.stages[i].mesh_axes)

    def cache_shardings(self, caches_sds, batch: int, i: int = 0
                        ) -> Tuple[Any, str]:
        """(cache NamedSharding pytree, cache-update mode) for serving."""
        ma = self.stages[i].mesh_axes
        sh = SH.cache_specs(caches_sds, self.mesh, ma, batch, lead_dims=1)
        return sh, SH.cache_update_mode(sh, ma)

    # -- pipeline materialization (S > 1) -------------------------------------

    def pipeline_param_shardings(self) -> Dict[str, Any]:
        from jax.sharding import NamedSharding
        assert self.pipeline_param_specs is not None, "single-stage plan"
        return {n: NamedSharding(self.mesh, sp)
                for n, sp in self.pipeline_param_specs.items()}

    def pipeline_state_shardings(self) -> Dict[str, Any]:
        """Optimizer-state shardings for the pipeline step: every entry
        follows the stacked param sharding (the 'stage' dim partitions
        optimizer state exactly like weights), with the stage-0 WO/OO
        ratios selecting host splits."""
        st0 = self.stages[0]
        specs = self.pipeline_param_specs
        assert specs is not None, "single-stage plan"
        return self._opt_tree(specs, specs, st0.master_split, st0.opt_split)

    # -- memory ---------------------------------------------------------------

    def memory_report(self, **kw):
        from repro.lowering.memory import memory_report
        return memory_report(self, **kw)

    def state_layout_terms(self, i: int = 0) -> Dict[str, float]:
        """Per-device state bytes of stage ``i`` by term — the shared
        state-layout derivation (`repro.lowering.state_layout`) evaluated
        concretely against this lowering's actual mesh degrees; the same
        derivation the tuner's cost model evaluates symbolically."""
        from repro.lowering.memory import stage_layout_terms
        return stage_layout_terms(self, i)


def check_plan_mesh(plan: Plan, mesh) -> None:
    """Reject lowering a plan onto a mesh whose axis sizes disagree with
    the plan's parallel degrees.

    The spec tables shard over the REAL mesh axes, so a mismatched pair
    silently produces a layout for different dp/tp than the plan (and
    its cost/memory predictions) assumed — the dryrun ``--view`` /
    ``--plan-json`` hole.  The intentional tp=1 fold (``plan_mesh_axes``
    folds 'model' into DP) stays legal: the folded dp is compared.
    """
    S = plan.num_stages
    has_stage = "stage" in getattr(mesh, "shape", {})
    if S > 1:
        if not has_stage:
            raise ValueError(
                f"plan/mesh mismatch: plan has {S} pipeline stages but the "
                f"mesh {dict(mesh.shape)} has no 'stage' axis")
        if mesh.shape["stage"] != S:
            raise ValueError(
                f"plan/mesh mismatch: plan has {S} pipeline stages but the "
                f"mesh 'stage' axis has size {mesh.shape['stage']}")
    elif has_stage and mesh.shape["stage"] != 1:
        raise ValueError(
            f"plan/mesh mismatch: single-stage plan on a mesh with a "
            f"'stage' axis of size {mesh.shape['stage']}")
    for i, st in enumerate(plan.stages):
        ma = (SH.MeshAxes.from_mesh(mesh) if S > 1
              else plan_mesh_axes(mesh, st.tp))
        dp_size = SH.axis_size(mesh, ma.dp)
        tp_size = SH.axis_size(mesh, ma.tp)
        if (dp_size, tp_size) != (st.dp, st.tp):
            raise ValueError(
                f"plan/mesh mismatch at stage {i}: plan wants (dp, tp) = "
                f"({st.dp}, {st.tp}) but mesh {dict(mesh.shape)} provides "
                f"(dp, tp) = ({dp_size}, {tp_size}); reshape the mesh view "
                f"to match the plan (or retune the plan for this mesh)")


def _split_table(params_sds, axes_table: Axes, ratio: float) -> Dict[str, int]:
    # lazy: repro.training re-exports its step builders (which import this
    # package) from its __init__, so a module-level import would be circular
    from repro.training.optimizer import split_k
    out = {}
    for name, sds in params_sds.items():
        k = split_k(name, sds.shape, axes_table, ratio)
        if k:
            out[name] = k
    return out


def lower_plan(cfg: ArchConfig, shape: Optional[ShapeConfig], plan: Plan,
               mesh) -> LoweredPlan:
    """THE plan-interpretation entry point (see module docstring).

    ``shape`` is the workload the plan was tuned for; it is carried for
    ``memory_report`` and may be None for pure-execution callers that
    never ask for one.  ``mesh`` may be a concrete mesh (execution) or an
    ``repro.compat.abstract_mesh`` shell (analysis).  Raises ValueError
    when the mesh axis sizes disagree with the plan's parallel degrees
    (``check_plan_mesh``).
    """
    from repro.models.zoo import abstract_params

    check_plan_mesh(plan, mesh)
    params_sds, axes_table = abstract_params(cfg)
    S = plan.num_stages
    pipeline = S > 1

    stages = []
    for i, st in enumerate(plan.stages):
        # pipeline stages live in one SPMD program whose 'data'/'model'
        # axes are fixed by the mesh; single-stage plans may fold a tp=1
        # 'model' axis into DP (plan_mesh_axes)
        ma = (SH.MeshAxes.from_mesh(mesh) if pipeline
              else plan_mesh_axes(mesh, st.tp))
        tp_size = SH.axis_size(mesh, ma.tp)
        ep_ok = cfg.num_experts > 0 and \
            cfg.num_experts % max(1, tp_size) == 0
        pspecs, gspecs, ospecs = {}, {}, {}
        for name, sds in params_sds.items():
            axes = axes_table[name]
            pspecs[name] = SH.param_spec(name, sds.shape, axes, mesh, ma,
                                         zero3=st.zero >= 3, ep_ok=ep_ok)
            gspecs[name] = SH.grad_spec(name, sds.shape, axes, mesh, ma,
                                        zero=st.zero, ep_ok=ep_ok)
            ospecs[name] = SH.opt_spec(name, sds.shape, axes, mesh, ma,
                                       zero=st.zero, ep_ok=ep_ok)
        stages.append(LoweredStage(
            index=i, stage=st, mesh_axes=ma,
            exec_cfg=stage_exec_config(plan, st),
            ep_ok=ep_ok, param_specs=pspecs, grad_specs=gspecs,
            opt_specs=ospecs,
            master_split=_split_table(params_sds, axes_table, st.wo),
            opt_split=_split_table(params_sds, axes_table, st.oo),
            has_embed=(i == 0), has_head=(i == S - 1),
            inflight=max(1, S - i),
        ))

    pipe_specs = manual_specs = None
    if pipeline:
        # stage-block assignment as sharding: stacked-layer dim 0 ->
        # 'stage', remaining dims via the stage-0 TP/ZeRO rules (dp/tp/
        # ZeRO must be uniform across stages inside one SPMD program)
        st0 = stages[0]
        pipe_specs, manual_specs = {}, {}
        for name, sds in params_sds.items():
            axes = axes_table[name]
            if axes and axes[0] == "layers":
                inner = SH.param_spec(name, sds.shape[1:], axes[1:], mesh,
                                      st0.mesh_axes,
                                      zero3=st0.stage.zero >= 3,
                                      ep_ok=st0.ep_ok)
                pipe_specs[name] = P("stage", *inner)
                manual_specs[name] = P("stage")
            else:
                pipe_specs[name] = st0.param_specs[name]
                manual_specs[name] = P()

    return LoweredPlan(cfg=cfg, shape=shape, plan=plan, mesh=mesh,
                       params_sds=params_sds, axes_table=axes_table,
                       stages=tuple(stages),
                       pipeline_param_specs=pipe_specs,
                       pipeline_manual_specs=manual_specs)
