"""Shared KV/state-cache layout: ONE derivation of per-device cache bytes,
evaluated both symbolically (the serve cost model's Expr tapes) and
concretely (``memory_report`` on serve shapes) — the cache-side twin of
:mod:`repro.lowering.state_layout` and the same two-evaluation contract
PR 5 established for training state.

``derive_cache_layout`` walks the abstract cache pytree the runtime
actually allocates (``jax.eval_shape`` over ``model.init_caches`` — the
exact tree ``make_serve_step`` shards) and records each leaf's key,
shape, dtype width, and by-value batch-dim location.  ``cache_bytes``
then reproduces the sharding cascade of
``repro.parallel.sharding.cache_specs`` leaf by leaf as indicator
arithmetic over a tiny Ops adapter:

* batch divisible by dp (and dp > 1)  ->  batch dim sharded over dp;
* otherwise, KV-sequence leaves shard their sequence dim over dp
  (flash-decoding-style sequence-parallel KV);
* tp lands on the canonical head/state/channel dim when divisible, with
  the same per-key fallback chain ``cache_specs`` implements (k/v fall
  back to the sequence dim only when dp did not take it, scales mirror
  k/v, ...).

Because the indicator cascades are exactly 0.0/1.0 and every quantity is
integer-exact in float64, the symbolic blend equals the concrete select
bitwise, and the raw spec-table walk in ``lowering/memory.py`` stays
available as the independent oracle (tests/test_cache_layout.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, TYPE_CHECKING

from repro.lowering.state_layout import (CONCRETE_OPS, SYMBOLIC_OPS)
from repro.core import symbolic as S

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.configs.base import ArchConfig

# Keys whose cache leaf carries the KV sequence at ``bdim + 1`` — MUST
# mirror ``repro.parallel.sharding._SEQ_LEAF_SEQ_DIM`` (asserted in
# tests/test_cache_layout.py; kept literal here so importing this module
# never pulls jax).
SEQ_CACHE_KEYS = ("k", "v", "latent", "k_rope", "k_scale", "v_scale")

# state-head keys (mamba2 / mLSTM) that shard dim bdim+1 over tp
_STATE_KEYS = ("ssm", "c", "n", "m")


@dataclass(frozen=True)
class CacheLeaf:
    """One abstract cache tensor, as the runtime allocates it."""
    key: str                       # trailing pytree key (cache_specs' view)
    shape: Tuple[int, ...]
    itemsize: int
    bdim: Optional[int]            # batch dim located BY VALUE (or None)
    # probe-established dims (batch / max_len perturbed separately under
    # eval_shape) — what the PAGED engine classifies by.  ``bdim`` must
    # stay by-value because it mirrors ``cache_specs``' runtime sharding;
    # the allocator cannot tolerate that hazard, so it gets its own view.
    pbdim: Optional[int] = None    # unique batch-varying dim (or None)
    sdims: Tuple[int, ...] = ()    # max_len-varying dims

    @property
    def nd(self) -> int:
        return len(self.shape)


@dataclass(frozen=True)
class CacheLayout:
    arch: str
    batch: int
    max_len: int
    kv_cache_dtype: str
    leaves: Tuple[CacheLeaf, ...]  # in jax.tree.leaves order


_LAYOUT_CACHE: Dict[Tuple[Any, int, int, str], CacheLayout] = {}


def derive_cache_layout(cfg: "ArchConfig", batch: int, max_len: int,
                        kv_cache_dtype: str = "bf16") -> CacheLayout:
    """Abstract-allocate the model's decode caches and record the layout.

    Lazy jax import (the same pattern as ``derive_state_layout``): the
    symbolic tuner only needs the recorded shapes, never real arrays."""
    key = (cfg, int(batch), int(max_len), kv_cache_dtype)
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    import jax
    import jax.numpy as jnp
    from repro.models.zoo import build_model

    model = build_model(cfg)
    cdt = jnp.int8 if kv_cache_dtype == "int8" else jnp.bfloat16
    caches = jax.eval_shape(
        lambda: model.init_caches(batch, max_len, cdt))
    # probe trees: batch and max_len perturbed separately, so a stacked
    # lead dim equal to the batch by value can never be mistaken for it
    bpro = jax.tree_util.tree_leaves(jax.eval_shape(
        lambda: model.init_caches(batch + 1, max_len, cdt)))
    spro = jax.tree_util.tree_leaves(jax.eval_shape(
        lambda: model.init_caches(batch, max_len + 1, cdt)))
    leaves = []
    for (path, sds), lb, ls in zip(
            jax.tree_util.tree_leaves_with_path(caches), bpro, spro):
        k = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = tuple(int(d) for d in sds.shape)
        bdim = next((i for i, d in enumerate(shape) if d == batch), None)
        bdims = [i for i, (a, b) in enumerate(zip(shape, lb.shape))
                 if a != b]
        sdims = tuple(i for i, (a, b) in enumerate(zip(shape, ls.shape))
                      if a != b)
        leaves.append(CacheLeaf(key=k, shape=shape,
                                itemsize=int(sds.dtype.itemsize),
                                bdim=bdim,
                                pbdim=bdims[0] if len(bdims) == 1 else None,
                                sdims=sdims))
    layout = CacheLayout(arch=cfg.name, batch=int(batch),
                         max_len=int(max_len),
                         kv_cache_dtype=kv_cache_dtype,
                         leaves=tuple(leaves))
    _LAYOUT_CACHE[key] = layout
    return layout


def _leaf_shards(leaf: CacheLeaf, batch: float, dp, tp, sb, ops) -> Any:
    """Device count one cache leaf divides over — the symbolic twin of
    ``_nshards(mesh, cache_specs(...)[leaf])``.

    ``sb`` is the tree-global shard-batch indicator (batch % dp == 0 and
    dp > 1).  Structural facts (key, rank, which dims exist) are concrete
    python at derivation time; only dp/tp (and the divisibilities they
    induce) flow through ``ops``.
    """
    if leaf.bdim is None:
        return 1.0
    nd, bdim, dims = leaf.nd, leaf.bdim, [float(d) for d in leaf.shape]
    seq_elig = leaf.key in SEQ_CACHE_KEYS and nd > bdim + 1

    # dp: batch dim when sb, else the KV sequence dim of eligible leaves
    # (cache_specs assigns dp there unconditionally; at dp == 1 both
    # reads are the identity, so the factor is simply dp).
    dp_f = dp if seq_elig else ops.where(sb, dp, 1.0)

    # tp cascade, per key — each chain reproduces cache_specs' elif
    # order.  At tp == 1 every divisibility holds and the factor is
    # tp == 1.0, matching the gated-off concrete branch exactly.
    tp_f = 1.0
    if leaf.key in ("k", "v"):
        head_ok = (ops.divisible(dims[nd - 2], tp)
                   if nd >= bdim + 3 else 0.0)
        if nd > bdim + 1:
            # sequence dim is free for tp iff dp took the batch dim
            seq_ok = sb * ops.divisible(dims[bdim + 1], tp)
            tp_f = ops.where(head_ok, tp, ops.where(seq_ok, tp, 1.0))
        else:                                        # pragma: no cover
            tp_f = ops.where(head_ok, tp, 1.0)
    elif leaf.key in _STATE_KEYS and nd > bdim + 1:
        tp_f = ops.where(ops.divisible(dims[bdim + 1], tp), tp, 1.0)
    elif leaf.key == "conv":
        tp_f = ops.where(ops.divisible(dims[nd - 1], tp), tp, 1.0)
    elif leaf.key in ("latent", "k_rope") and nd > bdim + 1:
        seq_ok = sb * ops.divisible(dims[bdim + 1], tp)
        tp_f = ops.where(seq_ok, tp, 1.0)
    elif leaf.key in ("k_scale", "v_scale"):
        last_ok = ops.divisible(dims[nd - 1], tp)
        seq_ok = (sb * ops.divisible(dims[bdim + 1], tp)
                  if nd > bdim + 1 else 0.0)
        tp_f = ops.where(last_ok, tp, ops.where(seq_ok, tp, 1.0))
    return dp_f * tp_f


def cache_bytes(layout: CacheLayout, *, dp, tp, ops=SYMBOLIC_OPS) -> Any:
    """Per-device cache bytes of the whole tree: sum over leaves of
    ``numel * itemsize / shards``, accumulated in tree-leaf order (the
    order the concrete report sums in)."""
    batch = float(layout.batch)
    sb = ops.divisible(batch, dp) * ops.gt(dp, 1.0)
    total = 0.0
    for leaf in layout.leaves:
        n = float(math.prod(leaf.shape))
        sh = _leaf_shards(leaf, batch, dp, tp, sb, ops)
        total = total + n * float(leaf.itemsize) / sh
    return total


def is_paged_leaf(leaf: CacheLeaf, max_len: int) -> bool:
    """A leaf the paged engine carves into pages: a KV-sequence leaf whose
    sequence extent IS the decode horizon (probe-established, matching
    ``repro.serving.pages.classify_cache_tree`` exactly).  Enc-dec cross
    k/v (sequence extent = encoder length) and SSM/conv state stay
    slot-resident."""
    return (leaf.key in SEQ_CACHE_KEYS and leaf.pbdim is not None
            and (leaf.pbdim + 1) in leaf.sdims)


def paged_cache_bytes(layout: CacheLayout, *, page_size: int, dp, tp,
                      ops=SYMBOLIC_OPS) -> Any:
    """Per-device bytes of the PAGED serve cache tree — the single
    derivation behind both the symbolic serve cost model and the concrete
    ``memory_report`` on paged shapes (same two-evaluation contract as
    ``cache_bytes``).

    The paged engine replaces every paged leaf (lead, B, S, tail) with a
    page pool (lead, B*npp + 1, page_size, tail) — npp = max_len //
    page_size pages per request plus one shared trash page duplicate
    writes land on — widens each ``pos`` leaf to a per-request vector,
    and always allocates one shared (B, npp) int32 block table (the
    paged step takes it even for pure-state families with no paged
    leaves).  Pools shard exactly like their contiguous counterparts
    (``_leaf_shards`` on the original leaf), so at dp == tp == 1 this is
    byte-exact against the engine's replicated allocation.
    """
    ps = int(page_size)
    if ps <= 0:
        return cache_bytes(layout, dp=dp, tp=tp, ops=ops)
    if layout.max_len % ps:
        raise ValueError(
            f"page_size {ps} must divide max_len {layout.max_len}")
    npp = layout.max_len // ps
    batch = float(layout.batch)
    sb = ops.divisible(batch, dp) * ops.gt(dp, 1.0)
    total = 0.0
    for leaf in layout.leaves:
        sh = _leaf_shards(leaf, batch, dp, tp, sb, ops)
        if is_paged_leaf(leaf, layout.max_len):
            lead = float(math.prod(leaf.shape[:leaf.pbdim]))
            tail = float(math.prod(leaf.shape[leaf.pbdim + 2:]))
            n = lead * (batch * float(npp) + 1.0) * float(ps) * tail
        elif leaf.key == "pos":
            n = float(math.prod(leaf.shape)) * batch  # widened to (.., B)
        else:
            n = float(math.prod(leaf.shape))
        total = total + n * float(leaf.itemsize) / sh
    return total + batch * float(npp) * 4.0  # shared int32 block table


def symbolic_paged_cache_bytes(cfg: "ArchConfig", batch: int, max_len: int,
                               page_size: int,
                               kv_cache_dtype: str = "bf16") -> S.Expr:
    """Serve-cost-model entry point for paged pools, over ``dp``/``tp``."""
    layout = derive_cache_layout(cfg, batch, max_len, kv_cache_dtype)
    return S.wrap(paged_cache_bytes(layout, page_size=page_size,
                                    dp=S.Sym("dp"), tp=S.Sym("tp"),
                                    ops=SYMBOLIC_OPS))


def concrete_paged_cache_bytes(cfg: "ArchConfig", batch: int, max_len: int,
                               page_size: int, kv_cache_dtype: str, *,
                               dp_size: int, tp_size: int) -> float:
    """Lowering entry point for paged pools (memory_report's concrete
    evaluation of the same derivation)."""
    layout = derive_cache_layout(cfg, batch, max_len, kv_cache_dtype)
    return paged_cache_bytes(layout, page_size=page_size, dp=float(dp_size),
                             tp=float(tp_size), ops=CONCRETE_OPS)


def symbolic_cache_bytes(cfg: "ArchConfig", batch: int, max_len: int,
                         kv_cache_dtype: str = "bf16") -> S.Expr:
    """Serve-cost-model entry point: cache bytes as an Expr over the
    tuner symbols ``dp`` / ``tp``."""
    layout = derive_cache_layout(cfg, batch, max_len, kv_cache_dtype)
    return S.wrap(cache_bytes(layout, dp=S.Sym("dp"), tp=S.Sym("tp"),
                              ops=SYMBOLIC_OPS))


def concrete_cache_bytes(cfg: "ArchConfig", batch: int, max_len: int,
                         kv_cache_dtype: str, *, dp_size: int,
                         tp_size: int) -> float:
    """Lowering entry point: exact bytes from the stage's ACTUAL mesh
    axis sizes (folded tp=1 meshes count the real mesh, exactly like
    ``stage_layout_terms``)."""
    layout = derive_cache_layout(cfg, batch, max_len, kv_cache_dtype)
    return cache_bytes(layout, dp=float(dp_size), tp=float(tp_size),
                       ops=CONCRETE_OPS)


# ---------------------------------------------------------------------------
# The serve-shape transient/total formulas, shared verbatim by the
# symbolic model and the concrete report so the two sides stay bitwise.
# ---------------------------------------------------------------------------


def prefill_transient_bytes(act_coef_full: float, d_model: float,
                            batch, seq_len, dp, tp) -> Any:
    """One-shot prefix cost envelope: a couple of layers' activations for
    the local token slab plus logits headroom (the dry-run's historical
    serve-path formula, now the single definition)."""
    tok_local = batch * seq_len / dp
    return (4.0 * act_coef_full * d_model * tok_local / tp) + float(2**30)


def serve_device_bytes(*, weight, cache, transient, reserved) -> Any:
    """Total per-device serve bytes, summed in the exact order
    ``StageMemory.device_bytes`` adds its (partly zero) terms — adding
    0.0 is the float identity for finite terms, so
    ``((weight + cache) + transient) + reserved`` is that sum."""
    return ((weight + cache) + transient) + reserved
