"""The ONE state-layout derivation shared by the tuner and the lowering.

Before this module, the semantics of "where does optimizer/weight/grad
state live" existed twice and disagreed:

* ``core/costmodel.py`` charged state as a uniform ``n / tp`` division
  with continuous offload ratios applied to *all* state, while
* ``lowering/memory.py`` counted real shard counts from the
  PartitionSpec tables (indivisible dims replicate!), integer WO/OO
  split points, and offload restricted to stacked-layer entries.

The gap was up to ~21% of predicted memory on indivisible-vocab archs
(granite-3-8b vocab 49155 at tp=8) — enough to pick wrong plans right at
the budget boundary where Mist's dual-objective constrained optimization
operates.  This module is the single source of truth both sides now
evaluate:

* **symbolically** — shard counts as :mod:`repro.core.symbolic` ``Expr``
  chains over the tuner's knob symbols (``tp``/``dp``/``z1..z3``/``wo``/
  ``oo``/``L``), so the compiled tapes, the G-collapsed sweep, and the
  knob-tuple caches keep working unchanged;
* **concretely** — exact per-device bytes for
  ``LoweredPlan.memory_report()``, which is now a thin evaluation of the
  same layout.

Both paths run the *same* formula code (``state_terms``) over the same
deterministic tensor grouping; only the tiny ``Ops`` adapter differs
(float select / ``%`` divisibility vs ``Expr`` blend / ``ceil``-chain
divisibility).  Every produced value is exact in float64 — shard counts
are small integers, indicators are 0/1, split points are ``rint`` of
exact products — so symbolic and concrete evaluation agree **bitwise**
(property-tested in ``tests/test_state_layout.py``).

The physical-dim choosers (``choose_tp_dim`` / ``choose_fsdp_dim``) live
here as well: they are pure shape/axes logic with no jax dependency, and
``repro.parallel.sharding`` (the PartitionSpec library) re-exports them —
one implementation decides both the runtime's specs and this module's
concrete shard counts, so the two cannot drift.

This module must stay importable without jax: the tuner degrades to
numpy-only containers, so only :func:`derive_state_layout` (which walks
abstract param shapes) imports the model zoo, lazily.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import symbolic as S

# logical axes eligible for tensor parallelism, in priority order
TP_PRIORITY = ("expert", "mlp", "heads", "inner2", "inner", "kv_heads",
               "vocab")
# leading stacked-scan dims — never sharded (scan slices them)
LAYER_AXES = ("layers", "layers1", "layers2")

_SHARED_PREFIXES = ("shared/", "shared_attn/")


# ---------------------------------------------------------------------------
# Physical-dim choosers (moved verbatim from repro.parallel.sharding, which
# re-exports them; PartitionSpec construction and this module's concrete
# shard counts share these single implementations)
# ---------------------------------------------------------------------------


def choose_tp_dim(axes: Sequence[Optional[str]], shape: Sequence[int],
                  tp_size: int, ep_ok: bool) -> Optional[int]:
    """Pick the dim to shard over the model axis (None -> replicate)."""
    if tp_size <= 1:
        return None
    best = None
    best_rank = len(TP_PRIORITY)
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if ax is None or ax in LAYER_AXES or ax not in TP_PRIORITY:
            continue
        if ax == "expert" and not ep_ok:
            continue
        if dim % tp_size != 0:
            continue
        rank = TP_PRIORITY.index(ax)
        if rank < best_rank:
            best, best_rank = i, rank
    return best


def choose_fsdp_dim(axes: Sequence[Optional[str]], shape: Sequence[int],
                    fsdp_size: int, taken: Optional[int]) -> Optional[int]:
    """Largest free dim divisible by the ZeRO axis size."""
    if fsdp_size <= 1:
        return None
    best, best_dim = None, 0
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if i == taken or ax in LAYER_AXES:
            continue
        if dim % fsdp_size != 0:
            continue
        if dim > best_dim:
            best, best_dim = i, dim
    return best


# ---------------------------------------------------------------------------
# Layout derivation: abstract params -> deterministic tensor groups
# ---------------------------------------------------------------------------


def param_class(name: str, axes: Sequence[Optional[str]]) -> str:
    """stacked (per-layer scan entries) | shared (Zamba2-style block,
    replicated to every stage) | embed (embedding/head/final norm,
    attributed to the first and last stage)."""
    if axes and axes[0] in LAYER_AXES:
        return "stacked"
    if name.startswith(_SHARED_PREFIXES):
        return "shared"
    return "embed"


@dataclass(frozen=True)
class TensorGroup:
    """Tensors indistinguishable to the layout: same class, shape, and
    logical axes shard identically, split identically, and carry the same
    stage fraction — so they are summed once (``n`` = members * prod)."""
    cls: str                             # stacked | shared | embed
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    n: float                             # total elements across members
    names: Tuple[str, ...]


@dataclass(frozen=True)
class StateLayout:
    arch: str
    total_layers: int
    num_experts: int
    groups: Tuple[TensorGroup, ...]


_LAYOUT_CACHE: Dict[ArchConfig, StateLayout] = {}


def derive_state_layout(cfg: ArchConfig) -> StateLayout:
    """Group the arch's abstract params by (class, shape, axes), in
    first-appearance order (deterministic: the zoo emits params in a
    fixed order)."""
    hit = _LAYOUT_CACHE.get(cfg)
    if hit is not None:
        return hit
    from repro.models.zoo import abstract_params     # lazy: pulls jax

    params_sds, axes_table = abstract_params(cfg)
    order: list = []
    acc: Dict[Tuple, Tuple[float, list]] = {}
    for name, sds in params_sds.items():
        axes = tuple(axes_table[name])
        shape = tuple(int(d) for d in sds.shape)
        cls = param_class(name, axes)
        key = (cls, shape, axes)
        if key not in acc:
            acc[key] = (0.0, [])
            order.append(key)
        n, names = acc[key]
        acc[key] = (n + float(np.prod(shape, dtype=np.float64)),
                    names + [name])
    groups = tuple(TensorGroup(cls=k[0], shape=k[1], axes=k[2],
                               n=acc[k][0], names=tuple(acc[k][1]))
                   for k in order)
    layout = StateLayout(arch=cfg.name, total_layers=int(cfg.num_layers),
                         num_experts=int(cfg.num_experts), groups=groups)
    _LAYOUT_CACHE[cfg] = layout
    return layout


# ---------------------------------------------------------------------------
# The two evaluation adapters.  ``state_terms`` below is written once
# against this tiny interface; floats and Exprs flow through the *same*
# arithmetic, in the same order, which is what makes the two modes agree
# bitwise (indicators are exactly 0.0/1.0, so the symbolic blend
# ``c*a + (1-c)*b`` equals the concrete select exactly).
# ---------------------------------------------------------------------------


class SymbolicOps:
    """Expr-producing adapter (the cost-model tapes)."""
    @staticmethod
    def where(c, a, b):
        return S.where(S.wrap(c), a, b)

    @staticmethod
    def rint(x):
        return S.rint(x)

    @staticmethod
    def divisible(dim: float, by) -> Any:
        # ceil(d/by)*by >= d always, with equality iff by divides d (for
        # the integer-valued, double-exact dims and axis sizes used here)
        return (S.ceil(S.wrap(dim) / by) * by) <= dim

    @staticmethod
    def gt(a, b):
        return S.wrap(a) > b


class ConcreteOps:
    """Float adapter (memory_report; the runtime's integer semantics)."""
    @staticmethod
    def where(c, a, b):
        return a if c else b

    @staticmethod
    def rint(x):
        return float(np.rint(x))     # == Python round(): half to even

    @staticmethod
    def divisible(dim: float, by) -> float:
        return 1.0 if int(dim) % max(1, int(by)) == 0 else 0.0

    @staticmethod
    def gt(a, b) -> float:
        return 1.0 if a > b else 0.0


SYMBOLIC_OPS = SymbolicOps()
CONCRETE_OPS = ConcreteOps()


def _group_shards(g: TensorGroup, num_experts: int, tp, dp, z1, z2, z3,
                  ops) -> Tuple[Any, Any, Any]:
    """(weight, grad, opt) shard counts of one group — the symbolic twin
    of ``choose_tp_dim`` / ``choose_fsdp_dim`` feeding ``param_spec`` /
    ``grad_spec`` / ``opt_spec``:

    * TP takes the first dim in (priority rank, index) order whose size
      the model-axis degree divides (the ``expert`` axis additionally
      requires ``num_experts % tp == 0``, mirroring ``ep_ok``);
    * the ZeRO/FSDP axis takes the largest remaining dim its degree
      divides — at ZeRO>=3 for weights, >=2 for grads, >=1 for
      master/mu/nu, exactly the spec-table thresholds.

    The chains are selection cascades over 0/1 indicators, so with
    concrete inputs they reproduce the choosers' picks identically."""
    dims = [float(d) for d in g.shape]
    tp_on = ops.gt(tp, 1.0)
    avail = tp_on
    tp_any = 0.0
    tp_take: Dict[int, Any] = {}
    tp_order = sorted((TP_PRIORITY.index(ax), i)
                      for i, ax in enumerate(g.axes)
                      if ax in TP_PRIORITY and ax not in LAYER_AXES)
    for _rank, i in tp_order:
        d = ops.divisible(dims[i], tp)
        if g.axes[i] == "expert":
            d = d * ops.divisible(float(num_experts), tp)
        take = avail * d
        tp_take[i] = take
        tp_any = tp_any + take
        avail = avail * (1.0 - d)
    fs_avail = ops.gt(dp, 1.0)
    fsdp_any = 0.0
    fs_order = sorted(range(len(dims)),
                      key=lambda j: (-dims[j], j))
    for j in fs_order:
        if g.axes[j] in LAYER_AXES:
            continue
        d = ops.divisible(dims[j], dp) * (1.0 - tp_take.get(j, 0.0))
        take = fs_avail * d
        fsdp_any = fsdp_any + take
        fs_avail = fs_avail * (1.0 - d)
    tp_sh = ops.where(tp_any, tp, 1.0)
    w_sh = tp_sh * ops.where(z3 * fsdp_any, dp, 1.0)
    g_sh = tp_sh * ops.where(z2 * fsdp_any, dp, 1.0)
    o_sh = tp_sh * ops.where(z1 * fsdp_any, dp, 1.0)
    return w_sh, g_sh, o_sh


def state_terms(layout: StateLayout, *, tp, dp, z1, z2, z3, wo, oo, L,
                total_layers: Optional[int] = None,
                has_embed: bool = True, has_head: bool = True,
                ops=SYMBOLIC_OPS) -> Dict[str, Any]:
    """Per-device state bytes of one stage, by term.

    Returns ``{"weight", "grad", "master", "opt", "host"}``: bf16
    weights, f32 grad accumulator, f32 master (device part), f32 mu+nu
    (device part), and the WO/OO slices living in host memory.  Stacked
    groups contribute their ``L / total_layers`` share; shared blocks
    replicate to every stage; embed/head groups charge the first and
    last stage in full (the cost model's attribution).  Host offload is
    the runtime's: integer leading-slice splits (``rint(ratio * lead)``,
    the exact ``optimizer.split_k`` count) on stacked entries only —
    non-stacked state cannot offload, and the grad accumulator never
    does (the runtime implements no grad offload).

    All inputs may be floats (``ConcreteOps``) or ``Expr``s
    (``SymbolicOps``); both take the same arithmetic path."""
    total = float(total_layers if total_layers is not None
                  else layout.total_layers)
    frac_stacked = L / total
    out: Dict[str, Any] = dict(weight=0.0, grad=0.0, master=0.0, opt=0.0,
                               host=0.0)
    for g in layout.groups:
        if g.cls == "stacked":
            frac = frac_stacked
        elif g.cls == "shared":
            frac = 1.0
        elif has_embed or has_head:
            frac = 1.0
        else:
            continue
        w_sh, g_sh, o_sh = _group_shards(g, layout.num_experts, tp, dp,
                                         z1, z2, z3, ops)
        n = g.n * frac
        if g.axes and g.axes[0] in LAYER_AXES:
            lead = float(g.shape[0])
            dev_m = (lead - ops.rint(wo * lead)) / lead
            dev_o = (lead - ops.rint(oo * lead)) / lead
        else:
            dev_m = dev_o = 1.0
        out["weight"] = out["weight"] + 2.0 * n / w_sh
        out["grad"] = out["grad"] + 4.0 * n / g_sh
        out["master"] = out["master"] + 4.0 * n * dev_m / o_sh
        out["opt"] = out["opt"] + 8.0 * n * dev_o / o_sh
        out["host"] = out["host"] + (4.0 * n * (1.0 - dev_m)
                                     + 8.0 * n * (1.0 - dev_o)) / o_sh
    return out


def symbolic_state_terms(cfg: ArchConfig, *, has_embed: bool,
                         has_head: bool) -> Dict[str, S.Expr]:
    """The cost-model entry point: terms as Exprs over the tuner symbols
    (``tp``, ``dp``, ``z1``/``z2``/``z3``, ``wo``, ``oo``, ``L``)."""
    terms = state_terms(
        derive_state_layout(cfg),
        tp=S.Sym("tp"), dp=S.Sym("dp"),
        z1=S.Sym("z1"), z2=S.Sym("z2"), z3=S.Sym("z3"),
        wo=S.Sym("wo"), oo=S.Sym("oo"), L=S.Sym("L"),
        has_embed=has_embed, has_head=has_head, ops=SYMBOLIC_OPS)
    return {k: S.wrap(v) for k, v in terms.items()}


def concrete_state_terms(cfg: ArchConfig, *, tp_size: int, fsdp_size: int,
                         zero: int, wo: float, oo: float, layers: int,
                         total_layers: int, has_embed: bool,
                         has_head: bool) -> Dict[str, float]:
    """The lowering entry point: exact bytes for one stage, from the
    plan's integer mesh degrees (``tp_size``/``fsdp_size`` are the
    *actual* axis sizes of the lowered stage's MeshAxes, so folded
    tp=1 meshes and production views evaluate correctly)."""
    z = float(zero)
    return state_terms(
        derive_state_layout(cfg),
        tp=float(tp_size), dp=float(fsdp_size),
        z1=1.0 if z >= 1 else 0.0, z2=1.0 if z >= 2 else 0.0,
        z3=1.0 if z >= 3 else 0.0,
        wo=float(wo), oo=float(oo), L=float(layers),
        total_layers=total_layers,
        has_embed=has_embed, has_head=has_head, ops=CONCRETE_OPS)
