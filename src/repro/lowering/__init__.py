"""Plan lowering: the single interpretation layer between a tuned
:class:`repro.core.plan.Plan` and everything that executes or analyzes it.

``lower_plan(cfg, shape, plan, mesh)`` is the ONE place where a plan's
per-stage knobs (L, b, DP, TP, ZeRO, CKPT, WO/GO/OO/AO) are mapped to mesh
axes, sharding-spec tables, remat/offload segmentation, kernel selection,
and pipeline stage-block assignment.  Every runtime entry point — dryrun,
single-stage train step, pipeline train step, prefill/serve — consumes the
resulting :class:`LoweredPlan`; ``repro.parallel.sharding`` stays a pure
spec library with this package as its only runtime caller.

``repro.lowering.state_layout`` is the shared state-layout derivation:
the symbolic cost model and ``LoweredPlan.memory_report()`` evaluate the
SAME per-tensor-group shard counts and host/device splits (symbolically
vs concretely), closing the tuner->runtime memory loop within
``MEMORY_REL_TOL`` (`docs/plan-lowering.md` documents the contract).

The re-exports below resolve lazily (PEP 562): ``state_layout`` and the
symbolic cost model that imports it must stay usable in numpy-only
containers, while ``lower``/``memory`` pull jax at import time.
"""
from typing import TYPE_CHECKING

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.lowering.lower import (LoweredPlan, LoweredStage,
                                      check_plan_mesh, lower_plan,
                                      plan_mesh_axes)
    from repro.lowering.memory import (MEMORY_REL_TOL, MemoryReport,
                                       StageMemory, memory_consistency)

_LOWER = ("LoweredPlan", "LoweredStage", "lower_plan", "plan_mesh_axes",
          "check_plan_mesh")
_MEMORY = ("MemoryReport", "StageMemory", "memory_consistency",
           "MEMORY_REL_TOL")

__all__ = list(_LOWER + _MEMORY)


def __getattr__(name: str):
    if name in _LOWER:
        from repro.lowering import lower
        return getattr(lower, name)
    if name in _MEMORY:
        from repro.lowering import memory
        return getattr(memory, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
