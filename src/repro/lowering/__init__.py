"""Plan lowering: the single interpretation layer between a tuned
:class:`repro.core.plan.Plan` and everything that executes or analyzes it.

``lower_plan(cfg, shape, plan, mesh)`` is the ONE place where a plan's
per-stage knobs (L, b, DP, TP, ZeRO, CKPT, WO/GO/OO/AO) are mapped to mesh
axes, sharding-spec tables, remat/offload segmentation, kernel selection,
and pipeline stage-block assignment.  Every runtime entry point — dryrun,
single-stage train step, pipeline train step, prefill/serve — consumes the
resulting :class:`LoweredPlan`; ``repro.parallel.sharding`` stays a pure
spec library with this package as its only runtime caller.

``LoweredPlan.memory_report()`` recomputes per-device state/activation
bytes from the lowered tables, closing the loop with the symbolic cost
model (`docs/plan-lowering.md` documents the contract and the
predicted-vs-lowered cross-check tolerance).
"""
from repro.lowering.lower import (LoweredPlan, LoweredStage, lower_plan,
                                  plan_mesh_axes)
from repro.lowering.memory import (MemoryReport, StageMemory,
                                   memory_consistency, MEMORY_REL_TOL)

__all__ = [
    "LoweredPlan", "LoweredStage", "lower_plan", "plan_mesh_axes",
    "MemoryReport", "StageMemory", "memory_consistency", "MEMORY_REL_TOL",
]
