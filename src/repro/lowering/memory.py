"""Per-device memory accounting from the *lowered* tables.

``memory_report(lowered)`` walks the actual PartitionSpec tables a
LoweredPlan carries — counting real shard counts per tensor, so
indivisible dims (MHA head counts, small norms) that replicate are
charged at full size — plus the ExecConfig's integer remat/offload
segmentation and the WO/OO host split points.  The activation / transient
/ logits terms reuse the cost model's analytic per-arch coefficients
(``arch_stats``), so the report and the symbolic predictor share one
activation model and differ only where the runtime genuinely differs
from the symbolic idealization:

* spec-exact state bytes vs the uniform ``n/tp`` division,
* integer layer counts (``round(ao*ckpt)`` offloaded layers) vs
  continuous ratios,
* host offload restricted to stacked-layer entries (the runtime cannot
  split non-stacked tensors) vs ratios applied to all state.

``memory_consistency`` quantifies exactly that gap against
``estimate_plan`` for a concrete (cfg, shape, plan); the golden-plan
configs must agree within ``MEMORY_REL_TOL`` (asserted in
tests/test_lowering.py, reported per config by
``benchmarks/tuning_time.py --json``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, TYPE_CHECKING

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.hardware import V5E, HardwareSpec
from repro.parallel.sharding import LAYER_AXES

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.lowering.lower import LoweredPlan, LoweredStage

# Stated tolerance of the predicted-vs-lowered cross-check.  The dominant
# divergence on the golden-plan configs is the first one in the module
# docstring: granite-3-8b's vocab (49155) is not divisible by the plan's
# tp=8, so the lowered specs replicate the embedding — and its grads,
# master, and (non-offloadable, non-stacked) mu/nu — where the symbolic
# model divides uniformly by tp and offloads by ratio (~3.0 GiB on a
# ~14.7 GiB prediction; observed rel error 0.207, see the
# predicted_vs_lowered_memory table in benchmarks/tuning_time.py --json).
# Tightening this requires teaching the cost model spec-exact state
# accounting, which would change tuner selections and is pinned by the
# golden fixtures — tracked as a ROADMAP open item.
MEMORY_REL_TOL = 0.25

_SHARED_PREFIXES = ("shared/", "shared_attn/")


def _nshards(mesh, spec) -> int:
    """Device count a PartitionSpec divides a tensor over."""
    k = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            k *= mesh.shape[a]
    return k


@dataclass(frozen=True)
class StageMemory:
    """Per-device bytes of one lowered stage (train kind unless noted)."""
    index: int
    weight_bytes: float = 0.0        # bf16 weights
    grad_bytes: float = 0.0          # f32 grad accumulator
    master_bytes: float = 0.0        # f32 master weights (device part)
    opt_bytes: float = 0.0           # f32 mu+nu (device part)
    host_state_bytes: float = 0.0    # WO/OO slices living in host memory
    act_bytes: float = 0.0           # saved activations at peak
    host_act_bytes: float = 0.0      # AO-offloaded activation bytes
    cache_bytes: float = 0.0         # KV/state caches (serving)
    transient_bytes: float = 0.0     # working set + recompute scratch
    logits_bytes: float = 0.0
    reserved_bytes: float = 0.0      # XLA runtime + fragmentation

    @property
    def state_bytes(self) -> float:
        return (self.weight_bytes + self.grad_bytes + self.master_bytes
                + self.opt_bytes)

    @property
    def device_bytes(self) -> float:
        return (self.state_bytes + self.act_bytes + self.cache_bytes
                + self.transient_bytes + self.logits_bytes
                + self.reserved_bytes)


@dataclass(frozen=True)
class MemoryReport:
    kind: str                        # train | prefill | decode
    stages: tuple
    budget_bytes: float

    @property
    def peak_bytes(self) -> float:
        return max(s.device_bytes for s in self.stages)

    @property
    def fits(self) -> bool:
        return self.peak_bytes <= self.budget_bytes

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "peak_bytes": self.peak_bytes,
            "budget_bytes": self.budget_bytes,
            "fits": self.fits,
            "per_stage": [{
                "stage": s.index,
                "state_bytes": s.state_bytes,
                "host_state_bytes": s.host_state_bytes,
                "act_bytes": s.act_bytes,
                "host_act_bytes": s.host_act_bytes,
                "cache_bytes": s.cache_bytes,
                "device_bytes": s.device_bytes,
            } for s in self.stages],
        }


def _param_class(name: str, axes) -> str:
    if axes and axes[0] in LAYER_AXES:
        return "stacked"
    if name.startswith(_SHARED_PREFIXES):
        return "shared"
    return "embed"


def _state_walk(lowered: "LoweredPlan", st: "LoweredStage",
                layer_frac: float) -> Dict[str, float]:
    """Spec-exact per-device state bytes of one stage.

    Stacked params contribute their ``layer_frac`` share (this stage's
    layers / total); shared-block params replicate to every stage;
    embed/head params follow the cost model's attribution (first and last
    stage).  WO/OO splits move leading stacked slices to host.
    """
    mesh = lowered.mesh
    out = dict(weight=0.0, grad=0.0, master=0.0, opt=0.0, host=0.0)
    for name, sds in lowered.params_sds.items():
        axes = lowered.axes_table[name]
        cls = _param_class(name, axes)
        if cls == "stacked":
            frac = layer_frac
        elif cls == "shared":
            frac = 1.0
        else:
            frac = 1.0 if (st.has_embed or st.has_head) else 0.0
        if frac == 0.0:
            continue
        n = math.prod(sds.shape) * frac
        lead = sds.shape[0] if sds.shape else 1
        k_m = st.master_split.get(name, 0)
        k_o = st.opt_split.get(name, 0)
        dev_m = (lead - k_m) / lead if k_m else 1.0
        dev_o = (lead - k_o) / lead if k_o else 1.0
        w = 2.0 * n / _nshards(mesh, st.param_specs[name])
        g = 4.0 * n / _nshards(mesh, st.grad_specs[name])
        o_sh = _nshards(mesh, st.opt_specs[name])
        out["weight"] += w
        out["grad"] += g
        out["master"] += 4.0 * n * dev_m / o_sh
        out["opt"] += 8.0 * n * dev_o / o_sh
        out["host"] += (4.0 * n * (1.0 - dev_m)
                        + 8.0 * n * (1.0 - dev_o)) / o_sh
    return out


def stage_state_bytes(lowered: "LoweredPlan", i: int = 0) -> float:
    """Device-resident model-state bytes (weights + grad accumulator +
    master + mu/nu) of one lowered stage — the exact spec walk, counting
    replicated indivisible dims at full size."""
    st = lowered.stages[i]
    frac = st.stage.layers / lowered.plan.total_layers
    s = _state_walk(lowered, st, frac)
    return s["weight"] + s["grad"] + s["master"] + s["opt"]


def memory_report(lowered: "LoweredPlan", *, hw: HardwareSpec = V5E,
                  cp=None) -> MemoryReport:
    """Actual per-device bytes from the lowered tables (module docstring)."""
    from repro.core.costmodel import CostParams, arch_stats
    cp = cp or CostParams()
    shape = lowered.shape
    if shape is None:
        raise ValueError("memory_report needs the workload shape; pass it "
                         "to lower_plan")
    cfg, plan = lowered.cfg, lowered.plan
    stt = arch_stats(cfg)
    budget = hw.hbm_bytes * cp.mem_headroom

    if shape.kind != "train":
        return _serve_report(lowered, stt, shape, budget, cp)

    total_layers = plan.total_layers
    stages: List[StageMemory] = []
    for st in lowered.stages:
        sc, ec = st.stage, st.exec_cfg
        state = _state_walk(lowered, st, sc.layers / total_layers)
        tok = sc.micro_batch * shape.seq_len
        sp_div = sc.tp if plan.sequence_parallel else 1
        act_full_l = 2.0 * stt.act_coef_full * stt.d_model * tok / sp_div
        act_ckpt_l = 2.0 * stt.act_coef_ckpt * stt.d_model * tok / sp_div
        ck, off = ec.ckpt_layers, ec.offload_layers
        act = st.inflight * ((ck - off) * act_ckpt_l
                             + (sc.layers - ck) * act_full_l)
        act_host = st.inflight * off * act_ckpt_l
        # transient working set, mirroring the symbolic model: one layer's
        # full intermediates during (re)compute, gathered ZeRO-3 params
        # for ~2 layers, bwd boundary grads, and the bwd recompute scratch
        trans = 2.0 * act_full_l + 2.0 * act_ckpt_l * st.inflight \
            + act_full_l
        if sc.zero >= 3:
            trans += 2.0 * (2.0 * stt.n_layer / sc.tp)
        logits = (2.0 * sc.micro_batch * min(512, shape.seq_len)
                  * stt.vocab * 4.0 / sc.tp) if st.has_head else 0.0
        stages.append(StageMemory(
            index=st.index, weight_bytes=state["weight"],
            grad_bytes=state["grad"], master_bytes=state["master"],
            opt_bytes=state["opt"], host_state_bytes=state["host"],
            act_bytes=act, host_act_bytes=act_host,
            transient_bytes=trans, logits_bytes=logits,
            reserved_bytes=cp.runtime_reserved))
    return MemoryReport(kind="train", stages=tuple(stages),
                        budget_bytes=budget)


def _serve_report(lowered: "LoweredPlan", stt, shape: ShapeConfig,
                  budget: float, cp) -> MemoryReport:
    """Serving: exact params-per-chip (+ exact cache-per-chip for decode)
    + the transient envelope the dry-run has always used."""
    st = lowered.stages[0]
    sc = st.stage
    mesh = lowered.mesh
    weight = 0.0
    for name, sds in lowered.params_sds.items():
        n = math.prod(sds.shape)
        weight += 2.0 * n / _nshards(mesh, st.param_specs[name])
    cache = 0.0
    if shape.kind == "decode":
        import jax
        import jax.numpy as jnp
        from repro.models import build_model
        from repro.parallel import sharding as SH
        model = build_model(lowered.cfg)
        cdt = (jnp.int8 if lowered.plan.kv_cache_dtype == "int8"
               else jnp.bfloat16)
        caches = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len,
                                      cdt))
        specs = SH.cache_specs(caches, mesh, st.mesh_axes,
                               shape.global_batch)
        for sds, sh in zip(jax.tree.leaves(caches), jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "spec"))):
            n = math.prod(sds.shape)
            cache += n * sds.dtype.itemsize / _nshards(mesh, sh.spec)
        trans = 0.3 * 2**30
    else:   # prefill: a couple of layers' activations + logits headroom
        tok_local = shape.global_batch * shape.seq_len / max(1, sc.dp)
        trans = (4.0 * stt.act_coef_full * stt.d_model * tok_local
                 / max(1, sc.tp)) + 2**30
    stage = StageMemory(index=0, weight_bytes=weight, cache_bytes=cache,
                        transient_bytes=trans,
                        reserved_bytes=0.75 * 2**30)
    return MemoryReport(kind=shape.kind, stages=(stage,),
                        budget_bytes=budget)


def memory_consistency(cfg: ArchConfig, shape: ShapeConfig, plan, *,
                       hw: HardwareSpec = V5E) -> Dict[str, Any]:
    """Predicted (symbolic estimate_plan) vs lowered (memory_report)
    per-device peak bytes for one concrete plan, on an abstract mesh
    shaped exactly like the plan.  This is the tuner->runtime consistency
    check: the cost model that *selected* the plan and the lowering that
    *executes* it must agree on what the plan costs."""
    from repro import compat
    from repro.core.costmodel import estimate_plan
    from repro.lowering.lower import lower_plan

    est = estimate_plan(cfg, shape, plan, hw=hw)
    st0 = plan.stages[0]
    if plan.num_stages > 1:
        mesh = compat.abstract_mesh(
            (plan.num_stages, st0.dp, st0.tp), ("stage", "data", "model"))
    else:
        mesh = compat.abstract_mesh((st0.dp, st0.tp), ("data", "model"))
    rep = lower_plan(cfg, shape, plan, mesh).memory_report(hw=hw)
    predicted = float(est["mem_peak_max"])
    lowered_b = float(rep.peak_bytes)
    rel = abs(lowered_b - predicted) / max(predicted, 1.0)
    return {
        "predicted_bytes": predicted,
        "lowered_bytes": lowered_b,
        "rel_error": rel,
        "within_tol": rel <= MEMORY_REL_TOL,
        "predicted_per_stage": [float(x) for x in est["mem_per_stage"]],
        "lowered_per_stage": [s.device_bytes for s in rep.stages],
    }
