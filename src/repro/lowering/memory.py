"""Per-device memory accounting of a lowered plan.

``memory_report(lowered)`` charges each train stage's model state by
evaluating the shared state-layout module
(:mod:`repro.lowering.state_layout`) **concretely** — the same per-group
shard counts, replication sets, and integer WO/OO host splits the
symbolic cost model evaluates over the tuner's knob symbols.  Activation
/ transient / logits terms reuse the cost model's analytic per-arch
coefficients (``arch_stats``) with the lowering's integer remat/offload
segmentation.  Since PR 5 the predictor and the report are two
evaluations of ONE derivation, so they agree bitwise wherever the plan
and the mesh agree (and ``MEMORY_REL_TOL`` is a tight guard, not an
apology for structural divergence).

``_state_walk`` — the exact walk over the lowered PartitionSpec tables —
is retained as the independent oracle: ``stage_state_bytes`` (dryrun)
uses it, and tests assert the layout evaluation reproduces it, which
pins the layout module to what ``param_spec``/``opt_spec`` actually
emit.

``memory_consistency`` quantifies the remaining predicted-vs-lowered gap
for one concrete (cfg, shape, plan), with a per-term breakdown (state /
act / transient / logits) so a future regression is attributable; the
golden-plan configs must agree within ``MEMORY_REL_TOL`` (asserted in
tests/test_lowering.py and, per config, by
``benchmarks/tuning_time.py --json``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, TYPE_CHECKING

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.hardware import V5E, HardwareSpec
from repro.lowering.state_layout import (concrete_state_terms, param_class
                                         as _param_class)
from repro.parallel import sharding as SH

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.lowering.lower import LoweredPlan, LoweredStage

# Tolerance of the predicted-vs-lowered cross-check.  With the shared
# state-layout derivation (spec-exact shard counts incl. indivisible-dim
# replication, integer WO/OO/AO splits) the two sides agree bitwise on
# matched plan/mesh pairs — granite-3-8b's indivisible vocab at tp=8,
# formerly a 0.207 rel error, is now exact, and the serve-side cache
# layout (``lowering/cache_layout.py``) extends the bitwise contract to
# decode/prefill shapes.  The ``runtime_reserved`` constant — once the
# stated reason for 3% headroom — is read from the same ``CostParams``
# field by both sides AND cross-checked against real compiled-executable
# bytes by ``tools/calibrate_reserved.py``, so the guard is now 1%:
# pure drift detection, not an apology for any known divergence.
MEMORY_REL_TOL = 0.01


def _nshards(mesh, spec) -> int:
    """Device count a PartitionSpec divides a tensor over."""
    k = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            k *= mesh.shape[a]
    return k


@dataclass(frozen=True)
class StageMemory:
    """Per-device bytes of one lowered stage (train kind unless noted)."""
    index: int
    weight_bytes: float = 0.0        # bf16 weights
    grad_bytes: float = 0.0          # f32 grad accumulator
    master_bytes: float = 0.0        # f32 master weights (device part)
    opt_bytes: float = 0.0           # f32 mu+nu (device part)
    host_state_bytes: float = 0.0    # WO/OO slices living in host memory
    act_bytes: float = 0.0           # saved activations at peak
    host_act_bytes: float = 0.0      # AO-offloaded activation bytes
    cache_bytes: float = 0.0         # KV/state caches (serving)
    transient_bytes: float = 0.0     # working set + recompute scratch
    logits_bytes: float = 0.0
    reserved_bytes: float = 0.0      # XLA runtime + fragmentation

    @property
    def state_bytes(self) -> float:
        return (self.weight_bytes + self.grad_bytes + self.master_bytes
                + self.opt_bytes)

    @property
    def device_bytes(self) -> float:
        return (self.state_bytes + self.act_bytes + self.cache_bytes
                + self.transient_bytes + self.logits_bytes
                + self.reserved_bytes)


@dataclass(frozen=True)
class MemoryReport:
    kind: str                        # train | prefill | decode
    stages: tuple
    budget_bytes: float

    @property
    def peak_bytes(self) -> float:
        return max(s.device_bytes for s in self.stages)

    @property
    def fits(self) -> bool:
        return self.peak_bytes <= self.budget_bytes

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "peak_bytes": self.peak_bytes,
            "budget_bytes": self.budget_bytes,
            "fits": self.fits,
            "per_stage": [{
                "stage": s.index,
                "state_bytes": s.state_bytes,
                "host_state_bytes": s.host_state_bytes,
                "act_bytes": s.act_bytes,
                "host_act_bytes": s.host_act_bytes,
                "cache_bytes": s.cache_bytes,
                "device_bytes": s.device_bytes,
            } for s in self.stages],
        }


def _state_walk(lowered: "LoweredPlan", st: "LoweredStage",
                layer_frac: float) -> Dict[str, float]:
    """Spec-exact per-device state bytes of one stage, walked from the
    ACTUAL PartitionSpec tables — the oracle the state-layout module is
    tested against (tests/test_state_layout.py).

    Stacked params contribute their ``layer_frac`` share (this stage's
    layers / total); shared-block params replicate to every stage;
    embed/head params follow the cost model's attribution (first and last
    stage).  WO/OO splits move leading stacked slices to host.
    """
    mesh = lowered.mesh
    out = dict(weight=0.0, grad=0.0, master=0.0, opt=0.0, host=0.0)
    for name, sds in lowered.params_sds.items():
        axes = lowered.axes_table[name]
        cls = _param_class(name, axes)
        if cls == "stacked":
            frac = layer_frac
        elif cls == "shared":
            frac = 1.0
        else:
            frac = 1.0 if (st.has_embed or st.has_head) else 0.0
        if frac == 0.0:
            continue
        n = math.prod(sds.shape) * frac
        lead = sds.shape[0] if sds.shape else 1
        k_m = st.master_split.get(name, 0)
        k_o = st.opt_split.get(name, 0)
        dev_m = (lead - k_m) / lead if k_m else 1.0
        dev_o = (lead - k_o) / lead if k_o else 1.0
        w = 2.0 * n / _nshards(mesh, st.param_specs[name])
        g = 4.0 * n / _nshards(mesh, st.grad_specs[name])
        o_sh = _nshards(mesh, st.opt_specs[name])
        out["weight"] += w
        out["grad"] += g
        out["master"] += 4.0 * n * dev_m / o_sh
        out["opt"] += 8.0 * n * dev_o / o_sh
        out["host"] += (4.0 * n * (1.0 - dev_m)
                        + 8.0 * n * (1.0 - dev_o)) / o_sh
    return out


def stage_state_bytes(lowered: "LoweredPlan", i: int = 0) -> float:
    """Device-resident model-state bytes (weights + grad accumulator +
    master + mu/nu) of one lowered stage — the exact spec walk, counting
    replicated indivisible dims at full size."""
    st = lowered.stages[i]
    frac = st.stage.layers / lowered.plan.total_layers
    s = _state_walk(lowered, st, frac)
    return s["weight"] + s["grad"] + s["master"] + s["opt"]


def stage_layout_terms(lowered: "LoweredPlan", i: int = 0
                       ) -> Dict[str, float]:
    """The shared state layout evaluated concretely for one lowered
    stage: tp/fsdp degrees come from the stage's ACTUAL MeshAxes (so
    folded tp=1 meshes and production views count the real mesh)."""
    st = lowered.stages[i]
    sc = st.stage
    return concrete_state_terms(
        lowered.cfg,
        tp_size=SH.axis_size(lowered.mesh, st.mesh_axes.tp),
        fsdp_size=SH.axis_size(lowered.mesh, st.mesh_axes.fsdp),
        zero=sc.zero, wo=sc.wo, oo=sc.oo, layers=sc.layers,
        total_layers=lowered.plan.total_layers,
        has_embed=st.has_embed, has_head=st.has_head)


def memory_report(lowered: "LoweredPlan", *, hw: HardwareSpec = V5E,
                  cp=None) -> MemoryReport:
    """Actual per-device bytes of the lowered plan (module docstring):
    state via the shared layout, activations/transients via the cost
    model's analytic coefficients + the ExecConfig's integer
    segmentation."""
    from repro.core.costmodel import CostParams, arch_stats
    cp = cp or CostParams()
    shape = lowered.shape
    if shape is None:
        raise ValueError("memory_report needs the workload shape; pass it "
                         "to lower_plan")
    cfg, plan = lowered.cfg, lowered.plan
    stt = arch_stats(cfg)
    budget = hw.hbm_bytes * cp.mem_headroom

    if shape.kind != "train":
        return _serve_report(lowered, stt, shape, budget, cp)

    stages: List[StageMemory] = []
    for st in lowered.stages:
        sc, ec = st.stage, st.exec_cfg
        state = stage_layout_terms(lowered, st.index)
        tok = sc.micro_batch * shape.seq_len
        sp_div = sc.tp if plan.sequence_parallel else 1
        act_full_l = 2.0 * stt.act_coef_full * stt.d_model * tok / sp_div
        act_ckpt_l = 2.0 * stt.act_coef_ckpt * stt.d_model * tok / sp_div
        ck, off = ec.ckpt_layers, ec.offload_layers
        act = st.inflight * ((ck - off) * act_ckpt_l
                             + (sc.layers - ck) * act_full_l)
        act_host = st.inflight * off * act_ckpt_l
        # transient working set, mirroring the symbolic model: one layer's
        # full intermediates during (re)compute, gathered ZeRO-3 params
        # for ~2 layers, bwd boundary grads, and the bwd recompute scratch
        trans = 2.0 * act_full_l + 2.0 * act_ckpt_l * st.inflight \
            + act_full_l
        if sc.zero >= 3:
            trans += 2.0 * (2.0 * stt.n_layer / sc.tp)
        logits = (2.0 * sc.micro_batch * min(512, shape.seq_len)
                  * stt.vocab * 4.0 / sc.tp) if st.has_head else 0.0
        stages.append(StageMemory(
            index=st.index, weight_bytes=state["weight"],
            grad_bytes=state["grad"], master_bytes=state["master"],
            opt_bytes=state["opt"], host_state_bytes=state["host"],
            act_bytes=act, host_act_bytes=act_host,
            transient_bytes=trans, logits_bytes=logits,
            reserved_bytes=cp.runtime_reserved))
    return MemoryReport(kind="train", stages=tuple(stages),
                        budget_bytes=budget)


def stage_cache_bytes(lowered: "LoweredPlan", shape: ShapeConfig) -> float:
    """Per-device decode-cache bytes, walked from the ACTUAL cache
    PartitionSpec tables — the independent oracle the shared cache
    layout (``lowering/cache_layout.py``) is tested against, exactly as
    ``_state_walk`` pins the state layout."""
    import jax
    import jax.numpy as jnp
    from repro.models import build_model
    st = lowered.stages[0]
    mesh = lowered.mesh
    model = build_model(lowered.cfg)
    cdt = (jnp.int8 if lowered.plan.kv_cache_dtype == "int8"
           else jnp.bfloat16)
    caches = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len, cdt))
    specs = SH.cache_specs(caches, mesh, st.mesh_axes, shape.global_batch)
    cache = 0.0
    for sds, sh in zip(jax.tree.leaves(caches), jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "spec"))):
        n = math.prod(sds.shape)
        cache += n * sds.dtype.itemsize / _nshards(mesh, sh.spec)
    return cache


def _serve_report(lowered: "LoweredPlan", stt, shape: ShapeConfig,
                  budget: float, cp) -> MemoryReport:
    """Serving: params-per-chip via the SHARED state-layout derivation
    and cache-per-chip via the SHARED cache layout (the same two
    evaluations the serve cost model runs over its Expr tapes — one
    derivation per term, not a private spec-table walk), plus the
    transient/reserved envelope from ``CostParams``."""
    from repro.lowering.cache_layout import (concrete_cache_bytes,
                                             concrete_paged_cache_bytes,
                                             prefill_transient_bytes)
    st = lowered.stages[0]
    sc = st.stage
    mesh = lowered.mesh
    weight = stage_layout_terms(lowered, 0)["weight"]
    cache = 0.0
    if shape.kind == "decode":
        page_size = int(getattr(lowered.plan, "page_size", 0))
        if page_size > 0:
            # paged serve plan: the continuous-batching engine's pool
            # layout (page pools + trash page + widened pos + block
            # table), same two-evaluation contract as the contiguous path
            cache = concrete_paged_cache_bytes(
                lowered.cfg, shape.global_batch, shape.seq_len, page_size,
                lowered.plan.kv_cache_dtype,
                dp_size=SH.axis_size(mesh, st.mesh_axes.dp),
                tp_size=SH.axis_size(mesh, st.mesh_axes.tp))
        else:
            cache = concrete_cache_bytes(
                lowered.cfg, shape.global_batch, shape.seq_len,
                lowered.plan.kv_cache_dtype,
                dp_size=SH.axis_size(mesh, st.mesh_axes.dp),
                tp_size=SH.axis_size(mesh, st.mesh_axes.tp))
        trans = cp.serve_decode_transient
    else:   # prefill: a couple of layers' activations + logits headroom
        trans = prefill_transient_bytes(
            stt.act_coef_full, stt.d_model, float(shape.global_batch),
            float(shape.seq_len), float(max(1, sc.dp)),
            float(max(1, sc.tp)))
    stage = StageMemory(index=0, weight_bytes=weight, cache_bytes=cache,
                        transient_bytes=trans,
                        reserved_bytes=cp.runtime_reserved)
    return MemoryReport(kind=shape.kind, stages=(stage,),
                        budget_bytes=budget)


def memory_consistency(cfg: ArchConfig, shape: ShapeConfig, plan, *,
                       hw: HardwareSpec = V5E) -> Dict[str, Any]:
    """Predicted (symbolic estimate_plan) vs lowered (memory_report)
    per-device peak bytes for one concrete plan, on an abstract mesh
    shaped exactly like the plan.  This is the tuner->runtime consistency
    check: the cost model that *selected* the plan and the lowering that
    *executes* it must agree on what the plan costs.

    ``terms`` breaks the gap down per memory term at the lowered peak
    stage.  Per-term rel errors are normalized by the predicted TOTAL
    (how much of the budget that term's disagreement is worth), so tiny
    terms cannot blow the ratio up."""
    from repro import compat
    from repro.core.costmodel import estimate_plan
    from repro.lowering.lower import lower_plan

    est = estimate_plan(cfg, shape, plan, hw=hw)
    st0 = plan.stages[0]
    if plan.num_stages > 1:
        mesh = compat.abstract_mesh(
            (plan.num_stages, st0.dp, st0.tp), ("stage", "data", "model"))
    else:
        mesh = compat.abstract_mesh((st0.dp, st0.tp), ("data", "model"))
    rep = lower_plan(cfg, shape, plan, mesh).memory_report(hw=hw)
    predicted = float(est["mem_peak_max"])
    lowered_b = float(rep.peak_bytes)
    rel = abs(lowered_b - predicted) / max(predicted, 1.0)

    peak_i = max(range(len(rep.stages)),
                 key=lambda i: rep.stages[i].device_bytes)
    ps = rep.stages[peak_i]
    pt = est["mem_terms_per_stage"][peak_i]
    lowered_terms = {"state": ps.state_bytes, "act": ps.act_bytes,
                     "transient": ps.transient_bytes,
                     "logits": ps.logits_bytes,
                     "host_state": ps.host_state_bytes,
                     "host_act": ps.host_act_bytes}
    terms = {k: {"predicted": float(pt[k]), "lowered": float(v),
                 "rel_error": abs(v - pt[k]) / max(predicted, 1.0)}
             for k, v in lowered_terms.items()}
    return {
        "predicted_bytes": predicted,
        "lowered_bytes": lowered_b,
        "rel_error": rel,
        "within_tol": rel <= MEMORY_REL_TOL,
        "terms": terms,
        "peak_stage": peak_i,
        "predicted_per_stage": [float(x) for x in est["mem_per_stage"]],
        "lowered_per_stage": [s.device_bytes for s in rep.stages],
    }
