"""Sweep executor daemon: the remote end of the multi-host fan-out.

One `SweepWorker` serves "sweep" RPCs (see `repro.core.remote` for the
wire contract): the payload is pickled (spec, knobs, plan, shards) —
exactly what `prefetch_frontiers` hands the local fork pool — and the
response is the pickled list of per-shard
(memo-shard, n_swept, hits, misses) tuples, computed by the *same*
`sweep._pool_task` body a local worker runs.  That sharing is the
determinism argument: a unit's frontier is a pure function of
(spec, knobs, unit) no matter which process on which host computes it,
so the client's merge is bitwise identical to a serial sweep.

``workers`` > 1 fans the received shards across the daemon's own local
fork pool (a host with many cores serves many shards concurrently);
``workers`` <= 1 runs them inline.  A PROCESS-global lock serializes
concurrent sweep execution: `_pool_task`'s worker-tuner cache, its tape
scratch buffers, and the fork pool are module globals, so two sweeps
interleaving in one process — e.g. two in-thread daemons in a test, or
two client connections hitting one daemon — would race on shared state
and corrupt results.  A per-instance lock would not cover the
two-daemons-one-process case.
"""
from __future__ import annotations

import pickle
import threading
from typing import List, Optional

from repro.core import sweep
from repro.core.remote import RpcServer

_SWEEP_LOCK = threading.Lock()


class SweepWorker:
    """Wrap an RpcServer with the sweep handler.  `addr` is bound
    immediately (port 0 picks an ephemeral port), so tests and parent
    processes can read it before serving starts."""

    def __init__(self, *, host: str = "127.0.0.1", port: int = 0,
                 workers: int = 1):
        self.workers = max(1, int(workers))
        self.n_requests = 0
        self.n_shards = 0
        self.server = RpcServer(
            {"sweep": self._sweep, "stats": self._stats},
            host=host, port=port)
        self.addr = self.server.addr

    def _stats(self):
        return {"requests": self.n_requests, "shards": self.n_shards,
                "workers": self.workers}

    def _sweep(self, payload: bytes) -> bytes:
        spec, knobs, plan, shards = pickle.loads(payload)
        with _SWEEP_LOCK:
            self.n_requests += 1
            self.n_shards += len(shards)
            payloads = [pickle.dumps((spec, knobs, plan, s),
                                     protocol=pickle.HIGHEST_PROTOCOL)
                        for s in shards]
            if self.workers > 1 and len(shards) > 1 \
                    and sweep._start_method() is not None:
                pool = sweep._get_pool(min(self.workers, len(shards)))
                outs = pool.map(sweep._pool_task, payloads)
            else:
                outs = [sweep._pool_task(p) for p in payloads]
        return pickle.dumps(outs, protocol=pickle.HIGHEST_PROTOCOL)

    def serve_forever(self):
        self.server.serve_forever()

    def start_in_thread(self):
        return self.server.start_in_thread()

    def shutdown(self):
        self.server.shutdown()


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="Mist sweep executor daemon (docs/distributed-sweep.md)")
    p.add_argument("--host", default="127.0.0.1",
                   help="interface to bind (default: loopback; bind "
                        "non-loopback interfaces on trusted networks only)")
    p.add_argument("--port", type=int, default=0,
                   help="TCP port (0 = ephemeral, printed on stdout)")
    p.add_argument("--workers", type=int, default=1,
                   help="local fork-pool size for received shards")
    args = p.parse_args(argv)
    w = SweepWorker(host=args.host, port=args.port, workers=args.workers)
    # parseable by parent processes that spawned us with --port 0
    print(f"tune-worker listening on {w.addr}", flush=True)
    try:
        w.serve_forever()
    except KeyboardInterrupt:
        pass
    return 0
