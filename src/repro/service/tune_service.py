"""Persistent tuning service: whole tune queries against a shared memo.

The always-on half of ROADMAP item 2.  A `TuneService` daemon holds one
`MemoStore` directory and serves "tune" RPCs: the payload is a pickled
`TuneSpec`; the reply is the pickled `TuneReport`.  Every query runs
with `memo_dir` pointed at the service's store, so

* a warm query — same (arch, shape, devices, space, knobs, profile)
  modulo execution-routing fields — is answered from the report cache
  in milliseconds (`TuneReport.from_memo=True`);
* a cold query sweeps, but any stage hypotheses previously solved for
  *other* queries (shared sub-grids across spaces, device counts, G
  sets) are preloaded from the unit store first, and its own frontiers
  are flushed back for future queries — the frontier memo as a
  cross-job cache.

Queries serialize through a lock: tune() already parallelizes inside
(`workers`/`hosts`), and concurrent tuners would fight over the fork
pool.  `tune_remote` is the client helper; it leaves the caller's spec
untouched (the service applies its own memo_dir/workers/hosts policy).
"""
from __future__ import annotations

import dataclasses
import pickle
import threading
from typing import List, Optional, Tuple

from repro.core.memo_store import MemoStore
from repro.core.remote import RpcServer, request


class TuneService:
    def __init__(self, memo_dir: str, *, host: str = "127.0.0.1",
                 port: int = 0, workers: int = 1,
                 hosts: Optional[Tuple[str, ...]] = None,
                 gc_max_bytes: Optional[int] = None):
        self.memo_dir = memo_dir
        self.workers = max(1, int(workers))
        self.hosts = tuple(hosts) if hosts else None
        self.store = MemoStore(memo_dir)
        self.gc_max_bytes = gc_max_bytes
        self.last_gc = None
        self._lock = threading.Lock()
        self.n_queries = 0
        if gc_max_bytes is not None:
            # bound a pre-existing store before serving the first query
            self.last_gc = self.store.gc(gc_max_bytes)
        self.server = RpcServer(
            {"tune": self._tune, "stats": self._stats},
            host=host, port=port)
        self.addr = self.server.addr

    def _stats(self):
        return {"queries": self.n_queries,
                "unit_hits": self.store.unit_hits,
                "unit_misses": self.store.unit_misses,
                "report_hits": self.store.report_hits,
                "memo_dir": self.memo_dir,
                "gc_max_bytes": self.gc_max_bytes,
                "last_gc": self.last_gc}

    def _tune(self, payload: bytes) -> bytes:
        from repro.core.tuner import MistTuner
        spec = pickle.loads(payload)
        # service policy overrides client routing: queries run against the
        # service's store with the service's execution resources
        spec = dataclasses.replace(spec, memo_dir=self.memo_dir,
                                   workers=self.workers, hosts=self.hosts)
        with self._lock:
            self.n_queries += 1
            tuner = MistTuner(spec)
            rep = tuner.tune()
            # fold the query's store counters into the service's totals
            # (each tuner builds its own MemoStore view over the same dir)
            qs = tuner._store()
            self.store.unit_hits += qs.unit_hits
            self.store.unit_misses += qs.unit_misses
            self.store.report_hits += qs.report_hits
            if self.gc_max_bytes is not None:
                # evict oldest-access entries the query pushed past the
                # cap — under the lock, so a gc never races a flush of
                # the same query's frontiers
                self.last_gc = self.store.gc(self.gc_max_bytes)
        return pickle.dumps(rep, protocol=pickle.HIGHEST_PROTOCOL)

    def serve_forever(self):
        self.server.serve_forever()

    def start_in_thread(self):
        return self.server.start_in_thread()

    def shutdown(self):
        self.server.shutdown()


def tune_remote(spec, addr: str, *, timeout: Optional[float] = None):
    """Tune through a running `tools/tune_service.py` daemon; returns the
    TuneReport exactly as a local `MistTuner(spec).tune()` would."""
    rep = request(addr, "tune",
                  pickle.dumps(spec, protocol=pickle.HIGHEST_PROTOCOL),
                  timeout=timeout)
    return pickle.loads(rep)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="Mist persistent tuning service "
                    "(docs/distributed-sweep.md)")
    p.add_argument("--memo-dir", required=True,
                   help="MemoStore directory (created if absent)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="TCP port (0 = ephemeral, printed on stdout)")
    p.add_argument("--workers", type=int, default=1,
                   help="sweep-executor fork-pool size per query")
    p.add_argument("--hosts", default=None,
                   help="comma-separated tune_worker host:port list to "
                        "fan sweeps out to")
    p.add_argument("--gc-max-bytes", type=int, default=None,
                   help="prune the memo store to this many bytes "
                        "(oldest-access entries first) at startup and "
                        "after every query")
    args = p.parse_args(argv)
    hosts = tuple(h for h in (args.hosts or "").split(",") if h) or None
    svc = TuneService(args.memo_dir, host=args.host, port=args.port,
                      workers=args.workers, hosts=hosts,
                      gc_max_bytes=args.gc_max_bytes)
    print(f"tune-service listening on {svc.addr} (memo: {args.memo_dir})",
          flush=True)
    try:
        svc.serve_forever()
    except KeyboardInterrupt:
        pass
    return 0
