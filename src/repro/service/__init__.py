"""Long-lived daemons for distributed tuning (docs/distributed-sweep.md).

`repro.service.worker` — a sweep executor daemon: receives
(spec, knobs, plan, shards) payloads from a remote `prefetch_frontiers`
and answers with frontier-memo shards (`tools/tune_worker.py`).

`repro.service.tune_service` — a persistent tuning service: answers
whole `TuneSpec` queries against an on-disk `MemoStore`, so warm
(arch, mesh, budget) queries return in milliseconds
(`tools/tune_service.py`).
"""
from repro.service.tune_service import TuneService, tune_remote
from repro.service.worker import SweepWorker

__all__ = ["SweepWorker", "TuneService", "tune_remote"]
