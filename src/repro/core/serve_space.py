"""The ``serve`` search-space preset: tune the plan for INFERENCE.

Serving is a different optimization problem from training (PAPER.md
motivates the regime split; docs/serving.md has the full writeup):
decode is memory-bound — every emitted token streams the local weights
plus the KV prefix — while prefill is compute-bound, and the binding
memory term is the KV cache, which grows with batch × max context.  The
knobs that matter are (dp, tp), ZeRO-3 weight sharding (memory for
collective time), and the KV-cache dtype.

The machinery is deliberately the training tuner's: candidates are
priced by a compiled Expr tape (``ServeCostModel``), the intra-stage
dual objective (t = per-token decode latency, d = one-shot prefill
latency) goes through the SAME ``pareto_front`` sampling, and the
selection reuses the inter-stage MILP with S = 1 and G reinterpreted as
the decode-steps-per-request hypothesis — paper Eq. 1 then reads
``G * t + d``: the latency of prefilling once and decoding G tokens.
``tokens/sec = batch * G / objective`` is the dual throughput reading
of the same objective.

int8 KV (``Plan.kv_cache_dtype``) halves the dominant decode store but
perturbs logits, so the sweep only falls back to it when no bf16
candidate fits the memory budget (and only for cache families the
quantized decode path supports); a plan that merely *could* be smaller
never silently changes numerics.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.core.inter_stage import StageCand, solve_milp
from repro.core.intra_stage import ParetoPoint, pareto_front
from repro.core.plan import Plan, single_stage_plan
from repro.core.schedule import Candidate, legal_dp_tp

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.configs.base import ArchConfig
    from repro.core.tuner import MistTuner, TuneReport

# weight placement: replicated vs ZeRO-3-sharded (inference has no
# optimizer state, so the intermediate levels are indistinguishable)
SERVE_ZEROS: Tuple[int, ...] = (0, 3)


def int8_kv_supported(cfg: "ArchConfig") -> bool:
    """The quantized decode path covers plain GQA/MHA self-attention KV
    caches (k/v + per-position scales).  MLA latent and enc-dec cross
    caches have no quantized read/write path."""
    return cfg.family in ("dense", "moe", "vlm", "hybrid") \
        and not cfg.kv_lora_rank


def serve_kv_grid(cfg: "ArchConfig") -> Tuple[str, ...]:
    return ("bf16", "int8") if int8_kv_supported(cfg) else ("bf16",)


def _sweep_kv(scm, cands: List[Tuple[int, int, int]], kv: str,
              budget: float, max_front: int) -> List[ParetoPoint]:
    """Price one kv-dtype's candidate grid on the compiled tape and
    Pareto-sample the feasible (t_decode, t_prefill) points."""
    arr = np.asarray(cands, np.float64)
    env = {"dp": arr[:, 0], "tp": arr[:, 1],
           "z1": (arr[:, 2] >= 1).astype(np.float64),
           "z2": (arr[:, 2] >= 2).astype(np.float64),
           "z3": (arr[:, 2] >= 3).astype(np.float64),
           "kv8": np.full(len(cands), 1.0 if kv == "int8" else 0.0)}
    r = scm.evaluate(env)
    mem = np.maximum(r["mem_decode"], r["mem_prefill"])
    batch = scm.batch
    pts = []
    for i, (dp, tp, zero) in enumerate(cands):
        if mem[i] > budget:
            continue
        cand = Candidate(b=max(1, batch // dp), dp=dp, tp=tp, zero=zero,
                         ckpt=0, wo=0.0, go=0.0, oo=0.0, ao=0.0)
        pts.append(ParetoPoint(t=float(r["t_decode"][i]),
                               d=float(r["t_prefill"][i]),
                               mem=float(mem[i]), cand=cand))
    return pareto_front(pts, max_points=max_front)


def serve_plan_from(cand: Candidate, num_layers: int,
                    kv_cache_dtype: str, page_size: int = 0) -> Plan:
    """Materialize the selected candidate: no remat, no offload, no
    accumulation — a pure serving plan ``lower_plan`` threads into
    ``make_prefill_step``/``make_serve_step`` unchanged."""
    return single_stage_plan(
        num_layers, dp=cand.dp, tp=cand.tp, micro_batch=cand.b,
        grad_accum=1, zero=cand.zero, ckpt_layers=0,
        remat_policy="none", kv_cache_dtype=kv_cache_dtype,
        page_size=page_size)


def serve_page_grid(spec) -> Tuple[int, ...]:
    """Page sizes to sweep: ``spec.page_grid`` validated against the
    decode horizon, or ``(0,)`` (contiguous only — the pre-paging
    tuner, byte-identical plans)."""
    if spec.page_grid is None:
        return (0,)
    grid = tuple(int(ps) for ps in spec.page_grid)
    for ps in grid:
        if ps < 0 or (ps and spec.seq_len % ps):
            raise ValueError(
                f"page_grid entry {ps} must be 0 or divide "
                f"seq_len {spec.seq_len}")
    return grid


def _tune_one_page_size(tuner, page_size: int):
    """Sweep kv dtypes x (dp, tp, zero) and run the G MILP for ONE page
    size.  Returns (best, per_sg, n_points, n_milp) where best is
    (objective, G, sol, kv) or None."""
    from repro.core.costmodel import ServeCostModel
    spec, cfg = tuner.spec, tuner.spec.arch
    scm = ServeCostModel(cfg, batch=spec.global_batch,
                         max_len=spec.seq_len, page_size=page_size,
                         hw=tuner.hw, cp=tuner.cp)
    budget = scm.memory_budget()
    grid = [(dp, tp, z)
            for dp, tp in legal_dp_tp(spec.n_devices, cfg,
                                      max_tp=spec.max_tp)
            for z in SERVE_ZEROS]
    n_points = 0
    front: List[ParetoPoint] = []
    chosen_kv = "bf16"
    for kv in serve_kv_grid(cfg):       # bf16 first; int8 only as the
        n_points += len(grid)           # memory-infeasibility fallback
        front = _sweep_kv(scm, grid, kv, budget, spec.max_front)
        if front:
            chosen_kv = kv
            break
    if not front:
        return None, [], n_points, 0
    # decode-steps hypotheses ride the G axis, so the MILP, Eq. 1, and
    # the (S, G) report fields all read identically to training
    best = None
    per_sg: List[Tuple[int, int, float]] = []
    n_milp = 0
    cands = [[StageCand(layers=cfg.num_layers, n_devices=spec.n_devices,
                        t=p.t, d=p.d, point=p) for p in front]]
    for G in tuner.grad_accums():
        sol = solve_milp(cands, total_layers=cfg.num_layers,
                         total_devices=spec.n_devices, G=G)
        n_milp += 1
        if sol is None:                              # pragma: no cover
            continue
        per_sg.append((1, G, sol.objective))
        if best is None or sol.objective < best[0]:
            best = (sol.objective, G, sol, chosen_kv)
    return best, per_sg, n_points, n_milp


def tune_serve(tuner: "MistTuner") -> "TuneReport":
    """`MistTuner.tune()` body for ``space == "serve"``.

    Outer loop: the paged-KV page-size grid (default ``(0,)`` —
    contiguous only).  Each page size gets its own occupancy-aware
    ``ServeCostModel``; the cross-page-size winner is chosen by an
    occupancy-DISCOUNTED score — a contiguous cache is charged
    ``objective / serve_page_fill`` because under a mixed-length trace
    it pins the full horizon per slot while only the fill fraction does
    work, whereas the paged objective already prices its own live
    stream.  The score is used ONLY for comparison: the reported
    objective stays the winner's raw Eq. 1 value, so the default grid
    reports exactly the pre-paging numbers."""
    from repro.core.tuner import TuneReport
    t0 = time.time()
    spec, cp = tuner.spec, tuner.cp
    cfg = spec.arch
    n_points = n_milp = 0
    winner = None  # (score, best-tuple, per_sg, page_size)
    for ps in serve_page_grid(spec):
        best, per_sg, pts, milps = _tune_one_page_size(tuner, ps)
        n_points += pts
        n_milp += milps
        if best is None:
            continue
        score = best[0] * (1.0 if ps else 1.0 / cp.serve_page_fill)
        if winner is None or score < winner[0]:
            winner = (score, best, per_sg, ps)
    dt = time.time() - t0
    if winner is None:
        return TuneReport(plan=None, objective=float("inf"),
                          throughput_samples=0.0, throughput_tokens=0.0,
                          space=spec.space, n_points=n_points,
                          n_milp=n_milp, tune_seconds=dt, infeasible=True,
                          n_swept=n_points)
    _, (obj, G, sol, chosen_kv), per_sg, page_size = winner
    plan = serve_plan_from(sol.selection[0].point.cand, cfg.num_layers,
                           chosen_kv, page_size=page_size)
    return TuneReport(
        plan=plan, objective=obj,
        throughput_samples=spec.global_batch / obj,
        throughput_tokens=spec.global_batch * G / obj,
        space=spec.space, n_points=n_points, n_milp=n_milp,
        tune_seconds=dt, best_S=1, best_G=G, per_sg=per_sg,
        n_swept=n_points)
