"""Symbolic expression engine with *batched* numpy evaluation.

Mist's key idea #2: derive runtime/memory as symbolic expressions over the
optimization variables once, then evaluate thousands of configurations by
vectorized value substitution instead of re-simulating each one (paper §5.2
reports >1e5 x speedup over per-config simulation; see
benchmarks/tuning_time.py for ours).

The engine is a small DAG (Const / Sym / BinOp / UnOp) with operator
overloading, hash-consing-free but id-memoized evaluation, and numpy
broadcasting so every symbol may be bound to an array of candidate values.
``sympy`` is deliberately avoided in the hot path (too slow at ~1e6-point
batched substitution).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Union

import numpy as np

Number = Union[int, float]


class Expr:
    # -- operator overloading -------------------------------------------------
    def __add__(self, o):
        return _bin("add", self, wrap(o))

    def __radd__(self, o):
        return _bin("add", wrap(o), self)

    def __sub__(self, o):
        return _bin("sub", self, wrap(o))

    def __rsub__(self, o):
        return _bin("sub", wrap(o), self)

    def __mul__(self, o):
        return _bin("mul", self, wrap(o))

    def __rmul__(self, o):
        return _bin("mul", wrap(o), self)

    def __truediv__(self, o):
        return _bin("div", self, wrap(o))

    def __rtruediv__(self, o):
        return _bin("div", wrap(o), self)

    def __pow__(self, o):
        return _bin("pow", self, wrap(o))

    def __neg__(self):
        return _bin("mul", Const(-1.0), self)

    # comparisons produce 0/1 indicator expressions
    def __ge__(self, o):
        return _bin("ge", self, wrap(o))

    def __le__(self, o):
        return _bin("le", self, wrap(o))

    def __gt__(self, o):
        return _bin("gt", self, wrap(o))

    def __lt__(self, o):
        return _bin("lt", self, wrap(o))

    def evaluate(self, env: Dict[str, Any], memo=None):
        raise NotImplementedError

    def __call__(self, **env):
        return self.evaluate(env)


class Const(Expr):
    __slots__ = ("v",)

    def __init__(self, v: Number):
        self.v = float(v)

    def evaluate(self, env, memo=None):
        return self.v

    def __repr__(self):
        return f"{self.v:g}"


class Sym(Expr):
    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def evaluate(self, env, memo=None):
        try:
            return env[self.name]
        except KeyError:
            raise KeyError(f"unbound symbol {self.name!r}; "
                           f"have {sorted(env)}") from None

    def __repr__(self):
        return self.name


_BIN_FNS: Dict[str, Callable] = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "div": np.divide, "pow": np.power,
    "max": np.maximum, "min": np.minimum,
    "ge": lambda a, b: (np.asarray(a) >= b).astype(np.float64),
    "le": lambda a, b: (np.asarray(a) <= b).astype(np.float64),
    "gt": lambda a, b: (np.asarray(a) > b).astype(np.float64),
    "lt": lambda a, b: (np.asarray(a) < b).astype(np.float64),
}

_UN_FNS: Dict[str, Callable] = {
    "ceil": np.ceil, "floor": np.floor, "sqrt": np.sqrt, "log2": np.log2,
    "abs": np.abs,
}


class BinOp(Expr):
    __slots__ = ("op", "a", "b")

    def __init__(self, op: str, a: Expr, b: Expr):
        self.op, self.a, self.b = op, a, b

    def evaluate(self, env, memo=None):
        memo = {} if memo is None else memo
        key = id(self)
        if key in memo:
            return memo[key]
        out = _BIN_FNS[self.op](self.a.evaluate(env, memo),
                                self.b.evaluate(env, memo))
        memo[key] = out
        return out

    def __repr__(self):
        return f"({self.a!r} {self.op} {self.b!r})"


class UnOp(Expr):
    __slots__ = ("op", "a")

    def __init__(self, op: str, a: Expr):
        self.op, self.a = op, a

    def evaluate(self, env, memo=None):
        memo = {} if memo is None else memo
        key = id(self)
        if key in memo:
            return memo[key]
        out = _UN_FNS[self.op](self.a.evaluate(env, memo))
        memo[key] = out
        return out

    def __repr__(self):
        return f"{self.op}({self.a!r})"


def wrap(x) -> Expr:
    return x if isinstance(x, Expr) else Const(x)


def _bin(op, a, b) -> Expr:
    # light constant folding
    if isinstance(a, Const) and isinstance(b, Const):
        return Const(_BIN_FNS[op](a.v, b.v))
    if op == "add":
        if isinstance(a, Const) and a.v == 0:
            return b
        if isinstance(b, Const) and b.v == 0:
            return a
    if op == "mul":
        if isinstance(a, Const) and a.v == 1:
            return b
        if isinstance(b, Const) and b.v == 1:
            return a
        if (isinstance(a, Const) and a.v == 0) or \
                (isinstance(b, Const) and b.v == 0):
            return Const(0.0)
    return BinOp(op, a, b)


def smax(a, b) -> Expr:
    return _bin("max", wrap(a), wrap(b))


def smin(a, b) -> Expr:
    return _bin("min", wrap(a), wrap(b))


def ceil(a) -> Expr:
    return UnOp("ceil", wrap(a))


def ceil_div(a, b) -> Expr:
    return ceil(wrap(a) / wrap(b))


def where(cond: Expr, a, b) -> Expr:
    """cond is a 0/1 indicator expression."""
    c = wrap(cond)
    return c * wrap(a) + (Const(1.0) - c) * wrap(b)


def sum_exprs(xs) -> Expr:
    out: Expr = Const(0.0)
    for x in xs:
        out = out + wrap(x)
    return out
