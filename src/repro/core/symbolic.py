"""Symbolic expression engine with *batched* numpy evaluation.

Mist's key idea #2: derive runtime/memory as symbolic expressions over the
optimization variables once, then evaluate thousands of configurations by
vectorized value substitution instead of re-simulating each one (paper §5.2
reports >1e5 x speedup over per-config simulation; see
benchmarks/tuning_time.py for ours).

The engine is a small DAG (Const / Sym / BinOp / UnOp) with operator
overloading and numpy broadcasting so every symbol may be bound to an array
of candidate values.  ``sympy`` is deliberately avoided in the hot path (too
slow at ~1e6-point batched substitution).

Three evaluation paths exist:

  * ``Expr.evaluate`` — the reference recursive walk with an id-keyed memo
    (kept for tests and as the legacy baseline in benchmarks).
  * ``compile_tape`` — compiles a set of output expressions into a ``Tape``:
    a flat, topologically sorted numpy instruction list that evaluates ALL
    outputs in a single pass.  Nodes are hash-consed (structurally interned)
    at construction, so common subexpressions across outputs are shared
    automatically and each unique node is computed exactly once.  Slots are
    reused once a value's last consumer has run, keeping the working set of
    live candidate-batch arrays small.
  * ``Tape.lower_jax`` — lowers the same instruction list to jax
    (``repro.compat`` gates availability, so numpy-only environments
    degrade cleanly).  Two flavors: the default *exact* mode executes the
    instructions as individual jax ops, which is bitwise identical to the
    numpy tape under ``jax_enable_x64`` (asserted by the differential
    suite in tests/test_tape_backends.py); ``fused=True`` compiles the
    whole tape into ONE ``jax.jit`` program — the fastest path on
    accelerators, but on CPU LLVM contracts mul+add chains into FMAs,
    which perturbs results by ~1-2 ulp (measured; documented in
    docs/tuning-engine.md), so it is opt-in and never used where the
    plan-identity guarantee matters.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Mapping, Tuple, Union

import numpy as np

Number = Union[int, float]


class Expr:
    # -- operator overloading -------------------------------------------------
    def __add__(self, o):
        return _bin("add", self, wrap(o))

    def __radd__(self, o):
        return _bin("add", wrap(o), self)

    def __sub__(self, o):
        return _bin("sub", self, wrap(o))

    def __rsub__(self, o):
        return _bin("sub", wrap(o), self)

    def __mul__(self, o):
        return _bin("mul", self, wrap(o))

    def __rmul__(self, o):
        return _bin("mul", wrap(o), self)

    def __truediv__(self, o):
        return _bin("div", self, wrap(o))

    def __rtruediv__(self, o):
        return _bin("div", wrap(o), self)

    def __pow__(self, o):
        return _bin("pow", self, wrap(o))

    def __neg__(self):
        return _bin("mul", Const(-1.0), self)

    # comparisons produce 0/1 indicator expressions
    def __ge__(self, o):
        return _bin("ge", self, wrap(o))

    def __le__(self, o):
        return _bin("le", self, wrap(o))

    def __gt__(self, o):
        return _bin("gt", self, wrap(o))

    def __lt__(self, o):
        return _bin("lt", self, wrap(o))

    def evaluate(self, env: Dict[str, Any], memo=None):
        raise NotImplementedError

    def __call__(self, **env):
        return self.evaluate(env)

    # Hash-consed nodes are constructed through ``__new__``-level intern
    # caches and carry ``__slots__``, so the default pickle protocol (which
    # calls ``__new__`` with no arguments) fails AND would break interning
    # on load.  Each concrete class defines ``__reduce__`` to re-enter its
    # constructor, so ``pickle.loads(pickle.dumps(e)) is e`` holds within a
    # process and spawn-based worker pools receive properly re-interned
    # DAGs (children unpickle first, so the op-cache keys match).


# ---------------------------------------------------------------------------
# Hash-consing: structurally identical nodes are the same object, so shared
# subexpressions across independently-built expressions dedupe (automatic
# CSE for the tape compiler) and id-keyed memos hit maximally.  The caches
# hold strong references, which also keeps id()-based intern keys stable.
# ---------------------------------------------------------------------------

_CONST_CACHE: Dict[Tuple[float, float], "Const"] = {}
_SYM_CACHE: Dict[str, "Sym"] = {}
_OP_CACHE: Dict[Tuple, Expr] = {}


def intern_cache_stats() -> Dict[str, int]:
    return {"const": len(_CONST_CACHE), "sym": len(_SYM_CACHE),
            "op": len(_OP_CACHE)}


def intern_cache_clear() -> None:
    """Drop the intern tables (they hold strong refs to every node built,
    so a long-running process sweeping many distinct models grows them
    monotonically).  Existing Expr objects and compiled Tapes stay fully
    usable — evaluation never consults the caches — only cross-model CSE
    restarts from scratch for nodes built afterwards."""
    _CONST_CACHE.clear()
    _SYM_CACHE.clear()
    _OP_CACHE.clear()


class Const(Expr):
    __slots__ = ("v",)

    def __new__(cls, v: Number):
        v = float(v)
        if v != v:                      # NaN: never interned (NaN != NaN)
            obj = super().__new__(cls)
            obj.v = v
            return obj
        key = (v, math.copysign(1.0, v))
        obj = _CONST_CACHE.get(key)
        if obj is None:
            obj = super().__new__(cls)
            obj.v = v
            _CONST_CACHE[key] = obj
        return obj

    def __init__(self, v: Number):
        pass                            # set in __new__

    def __reduce__(self):
        return (Const, (self.v,))

    def evaluate(self, env, memo=None):
        return self.v

    def __repr__(self):
        return f"{self.v:g}"


class Sym(Expr):
    __slots__ = ("name",)

    def __new__(cls, name: str):
        obj = _SYM_CACHE.get(name)
        if obj is None:
            obj = super().__new__(cls)
            obj.name = name
            _SYM_CACHE[name] = obj
        return obj

    def __init__(self, name: str):
        pass                            # set in __new__

    def __reduce__(self):
        return (Sym, (self.name,))

    def evaluate(self, env, memo=None):
        try:
            return env[self.name]
        except KeyError:
            raise KeyError(f"unbound symbol {self.name!r}; "
                           f"have {sorted(env)}") from None

    def __repr__(self):
        return self.name


_BIN_FNS: Dict[str, Callable] = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "div": np.divide, "pow": np.power,
    "max": np.maximum, "min": np.minimum,
    "ge": lambda a, b: (np.asarray(a) >= b).astype(np.float64),
    "le": lambda a, b: (np.asarray(a) <= b).astype(np.float64),
    "gt": lambda a, b: (np.asarray(a) > b).astype(np.float64),
    "lt": lambda a, b: (np.asarray(a) < b).astype(np.float64),
}

_UN_FNS: Dict[str, Callable] = {
    "ceil": np.ceil, "floor": np.floor, "sqrt": np.sqrt, "log2": np.log2,
    "abs": np.abs, "rint": np.rint,
}

# reverse fn-identity -> op-name map (instructions store the bound numpy
# callable for the hot loop; backends that need the *semantic* op — the
# jax lowering — recover the name through this)
_FN_NAMES: Dict[int, str] = {id(f): n
                             for d in (_BIN_FNS, _UN_FNS)
                             for n, f in d.items()}

# Ops whose numpy and jax implementations are the same IEEE-754
# correctly-rounded operation, so per-op (exact-mode) jax execution is
# bitwise identical to numpy under x64.  ``pow`` and ``log2`` are NOT:
# libm and XLA approximate them differently (measured last-ulp drift on
# CPU), so a tape containing them cannot claim the bitwise guarantee —
# ``Tape.jax_bitexact`` reports this and the cost-model backend refuses
# jax for such tapes rather than serving subtly different results.
BITEXACT_OPS = frozenset(
    {"add", "sub", "mul", "div", "max", "min",
     "ge", "le", "gt", "lt", "ceil", "floor", "sqrt", "abs", "rint"})


def _jax_fn_tables(jnp):
    """jax twins of ``_BIN_FNS`` / ``_UN_FNS``.

    Comparisons cast to ``jnp.result_type(float)`` (f64 under x64, f32
    otherwise) instead of hard-coding float64, so the lowering also works
    — at reduced precision — when the caller has not enabled x64."""
    def cmp(op):
        def f(a, b):
            return op(a, b).astype(jnp.result_type(float))
        return f
    jbin = {"add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
            "div": jnp.divide, "pow": jnp.power,
            "max": jnp.maximum, "min": jnp.minimum,
            "ge": cmp(jnp.greater_equal), "le": cmp(jnp.less_equal),
            "gt": cmp(jnp.greater), "lt": cmp(jnp.less)}
    jun = {"ceil": jnp.ceil, "floor": jnp.floor, "sqrt": jnp.sqrt,
           "log2": jnp.log2, "abs": jnp.abs, "rint": jnp.round}
    return jbin, jun


class BinOp(Expr):
    __slots__ = ("op", "a", "b")

    def __new__(cls, op: str, a: Expr, b: Expr):
        key = ("b", op, id(a), id(b), a, b)
        obj = _OP_CACHE.get(key)
        if obj is None:
            obj = super().__new__(cls)
            obj.op, obj.a, obj.b = op, a, b
            _OP_CACHE[key] = obj
        return obj

    def __init__(self, op: str, a: Expr, b: Expr):
        pass                            # set in __new__

    def __reduce__(self):
        return (BinOp, (self.op, self.a, self.b))

    def evaluate(self, env, memo=None):
        memo = {} if memo is None else memo
        key = id(self)
        if key in memo:
            return memo[key]
        out = _BIN_FNS[self.op](self.a.evaluate(env, memo),
                                self.b.evaluate(env, memo))
        memo[key] = out
        return out

    def __repr__(self):
        return f"({self.a!r} {self.op} {self.b!r})"


class UnOp(Expr):
    __slots__ = ("op", "a")

    def __new__(cls, op: str, a: Expr):
        key = ("u", op, id(a), a)
        obj = _OP_CACHE.get(key)
        if obj is None:
            obj = super().__new__(cls)
            obj.op, obj.a = op, a
            _OP_CACHE[key] = obj
        return obj

    def __init__(self, op: str, a: Expr):
        pass                            # set in __new__

    def __reduce__(self):
        return (UnOp, (self.op, self.a))

    def evaluate(self, env, memo=None):
        memo = {} if memo is None else memo
        key = id(self)
        if key in memo:
            return memo[key]
        out = _UN_FNS[self.op](self.a.evaluate(env, memo))
        memo[key] = out
        return out

    def __repr__(self):
        return f"{self.op}({self.a!r})"


def wrap(x) -> Expr:
    return x if isinstance(x, Expr) else Const(x)


def _bin(op, a, b) -> Expr:
    # light constant folding
    if isinstance(a, Const) and isinstance(b, Const):
        return Const(_BIN_FNS[op](a.v, b.v))
    if op == "add":
        if isinstance(a, Const) and a.v == 0:
            return b
        if isinstance(b, Const) and b.v == 0:
            return a
    if op == "mul":
        if isinstance(a, Const) and a.v == 1:
            return b
        if isinstance(b, Const) and b.v == 1:
            return a
        if (isinstance(a, Const) and a.v == 0) or \
                (isinstance(b, Const) and b.v == 0):
            return Const(0.0)
    return BinOp(op, a, b)


def smax(a, b) -> Expr:
    return _bin("max", wrap(a), wrap(b))


def smin(a, b) -> Expr:
    return _bin("min", wrap(a), wrap(b))


def ceil(a) -> Expr:
    return UnOp("ceil", wrap(a))


def ceil_div(a, b) -> Expr:
    return ceil(wrap(a) / wrap(b))


def rint(a) -> Expr:
    """Round half to even — the same correctly-rounded operation as
    Python ``round()`` / ``np.rint`` / ``jnp.round`` on float64, so the
    integer split points the runtime computes with ``round()`` are
    reproducible symbolically, bit for bit."""
    return UnOp("rint", wrap(a))


def where(cond: Expr, a, b) -> Expr:
    """cond is a 0/1 indicator expression."""
    c = wrap(cond)
    return c * wrap(a) + (Const(1.0) - c) * wrap(b)


def sum_exprs(xs) -> Expr:
    out: Expr = Const(0.0)
    for x in xs:
        out = out + wrap(x)
    return out


# ---------------------------------------------------------------------------
# Tape compilation: one topological sort of the shared output DAG into a flat
# instruction list; evaluation is a single linear pass with slot reuse.
# ---------------------------------------------------------------------------


class TapeScratch:
    """Reusable per-instruction output buffers for ``Tape.run``.

    The first run records each non-output instruction's result array as
    that instruction's buffer; later runs write into it via the ufunc
    ``out=`` argument, eliminating one allocation per instruction in the
    steady state of a big sweep.  When the batch shape changes, the ufunc
    rejects the stale buffer and a fresh result array is adopted instead
    (self-resizing).  Only safe because intermediate values never escape
    ``run`` — output slots always get fresh arrays."""

    __slots__ = ("bufs",)

    def __init__(self, tape: "Tape"):
        self.bufs: List[Any] = [None] * len(tape.instrs)


class Tape:
    """Compiled evaluation plan for a set of named output expressions.

    ``run(env)`` returns {name: value} where each value is whatever numpy
    broadcasting of the bound symbols yields (scalar or ndarray) — bitwise
    identical to ``Expr.evaluate`` on the same env, since each unique DAG
    node executes the same numpy op on the same inputs exactly once.
    ``run(env, scratch=tape.make_scratch())`` additionally reuses
    intermediate buffers across runs (ufunc ``out=``), which cuts
    allocation traffic in tight sweep loops; results stay bitwise
    identical (same ufunc, same operands, preallocated destination).
    """

    __slots__ = ("instrs", "n_slots", "sym_loads", "const_loads",
                 "out_slots", "ops", "jax_bitexact", "_reusable",
                 "_jax_cache")

    def __init__(self, instrs, n_slots, sym_loads, const_loads, out_slots):
        self.instrs = instrs            # [(fn, dst, a, b)]; b < 0 => unary
        self.n_slots = n_slots
        self.sym_loads = sym_loads      # [(name, slot)]
        self.const_loads = const_loads  # [(slot, value)]
        self.out_slots = out_slots      # {name: slot}
        self.ops = [_FN_NAMES[id(fn)] for fn, _, _, _ in instrs]
        # whether every instruction is correctly rounded in both numpy and
        # jax (BITEXACT_OPS), i.e. whether exact-mode jax execution under
        # x64 is bitwise identical to run(); False for pow/log2 tapes.
        # Precomputed: the backend dispatcher consults it per tape run.
        self.jax_bitexact = all(op in BITEXACT_OPS for op in self.ops)
        self._jax_cache: Dict[bool, Callable] = {}
        # instructions whose result may be buffer-reused: real ufuncs (the
        # comparison lambdas aren't) writing a non-output slot at this
        # program point (output values escape run() and must stay fresh).
        out_writers = set()
        final_writer: Dict[int, int] = {}
        for i, (_, dst, _, _) in enumerate(instrs):
            final_writer[dst] = i
        for s in out_slots.values():
            if s in final_writer:
                out_writers.add(final_writer[s])
        self._reusable = [
            isinstance(fn, np.ufunc) and i not in out_writers
            for i, (fn, _, _, _) in enumerate(instrs)]

    def __len__(self):
        return len(self.instrs)

    def make_scratch(self) -> TapeScratch:
        return TapeScratch(self)

    # -- jax lowering -------------------------------------------------------
    def lower_jax(self, *, fused: bool = False) -> Callable:
        """Lower the instruction list to jax; returns ``f(env) -> outputs``.

        ``fused=False`` (default, *exact* mode): the instructions execute
        as individual jax ops on device arrays.  For tapes whose ops are
        all correctly rounded (``jax_bitexact``; everything but
        ``pow``/``log2``), outputs under ``jax_enable_x64`` are bitwise
        identical to ``Tape.run`` — this is the mode the cost-model
        backend uses, because the tuner's plan-identity guarantee rests
        on it.

        ``fused=True``: the whole tape is compiled into ONE ``jax.jit``
        program evaluating every output in a single fused pass.  Fastest
        on accelerators (and free of per-op dispatch overhead), but on
        CPU LLVM contracts mul+add chains into FMAs, perturbing results
        by ~1-2 ulp, and each new batch shape retraces — use it where
        throughput beats last-ulp reproducibility.

        The compiled callable is cached per mode on the tape.  Output
        values are jax arrays (or numpy scalars for constant outputs);
        ``np.asarray`` them to re-enter numpy code.  Raises ImportError
        when jax is unavailable (``repro.compat.has_jax`` to probe).
        """
        fn = self._jax_cache.get(fused)
        if fn is None:
            fn = self._build_jax(fused)
            self._jax_cache[fused] = fn
        return fn

    def _build_jax(self, fused: bool) -> Callable:
        from repro.compat import require_jax
        jax, jnp = require_jax()
        jbin, jun = _jax_fn_tables(jnp)
        jinstrs = [(jun[op] if b < 0 else jbin[op], dst, a, b)
                   for op, (_, dst, a, b) in zip(self.ops, self.instrs)]
        consts = [(slot, np.float64(v)) for slot, v in self.const_loads]
        sym_loads, out_slots = self.sym_loads, self.out_slots
        n_slots = self.n_slots

        def _eval(args):
            slots: List[Any] = [None] * n_slots
            for slot, v in consts:
                slots[slot] = v
            for (_, slot), v in zip(sym_loads, args):
                slots[slot] = v
            for f, dst, a, b in jinstrs:
                slots[dst] = f(slots[a]) if b < 0 else f(slots[a], slots[b])
            return {name: slots[slot] for name, slot in out_slots.items()}

        body = jax.jit(_eval) if fused else _eval

        def run(env: Mapping[str, Any]) -> Dict[str, Any]:
            args = []
            for name, _slot in sym_loads:
                try:
                    v = env[name]
                except KeyError:
                    raise KeyError(f"unbound symbol {name!r}; "
                                   f"have {sorted(env)}") from None
                args.append(jnp.asarray(v))
            return body(tuple(args))

        return run

    def run(self, env: Mapping[str, Any],
            scratch: "TapeScratch" = None) -> Dict[str, Any]:
        slots: List[Any] = [None] * self.n_slots
        for slot, v in self.const_loads:
            slots[slot] = v
        for name, slot in self.sym_loads:
            try:
                slots[slot] = env[name]
            except KeyError:
                raise KeyError(f"unbound symbol {name!r}; "
                               f"have {sorted(env)}") from None
        if scratch is None:
            for fn, dst, a, b in self.instrs:
                slots[dst] = fn(slots[a]) if b < 0 else fn(slots[a], slots[b])
        else:
            bufs = scratch.bufs
            reusable = self._reusable
            nd = np.ndarray
            for i, (fn, dst, a, b) in enumerate(self.instrs):
                va = slots[a]
                buf = bufs[i]
                if b < 0:
                    # ``out=`` only when the result provably fills the
                    # buffer exactly — a scalar operand would silently
                    # broadcast into a stale larger buffer otherwise.
                    if buf is not None and type(va) is nd \
                            and va.shape == buf.shape:
                        r = fn(va, out=buf)
                    else:
                        if buf is not None:
                            bufs[i] = None      # batch shape changed
                        r = fn(va)
                else:
                    vb = slots[b]
                    if buf is not None:
                        sa = va.shape if type(va) is nd else ()
                        sb = vb.shape if type(vb) is nd else ()
                        if (sa == buf.shape and sb in ((), buf.shape)) \
                                or (sb == buf.shape and sa == ()):
                            r = fn(va, vb, out=buf)
                        else:
                            bufs[i] = None
                            r = fn(va, vb)
                    else:
                        r = fn(va, vb)
                if bufs[i] is None and reusable[i] \
                        and type(r) is nd and r.ndim:
                    bufs[i] = r
                slots[dst] = r
        return {name: slots[slot] for name, slot in self.out_slots.items()}


def _children(node: Expr) -> Tuple[Expr, ...]:
    if isinstance(node, BinOp):
        return (node.a, node.b)
    if isinstance(node, UnOp):
        return (node.a,)
    return ()


def compile_tape(outputs: Mapping[str, Expr]) -> Tape:
    """Compile named output expressions into a single shared Tape."""
    # -- one topological (post-) order over the union DAG, deduped by id ----
    order: List[Expr] = []
    visited: set = set()
    for root in outputs.values():
        if id(root) in visited:
            continue
        stack: List[Tuple[Expr, bool]] = [(root, False)]
        while stack:
            node, expanded = stack.pop()
            if expanded:
                order.append(node)
                continue
            if id(node) in visited:
                continue
            visited.add(id(node))
            stack.append((node, True))
            for ch in _children(node):
                if id(ch) not in visited:
                    stack.append((ch, False))

    # -- consumer counts for slot-liveness ----------------------------------
    uses: Dict[int, int] = {}
    for node in order:
        for ch in _children(node):
            uses[id(ch)] = uses.get(id(ch), 0) + 1
    pinned = {id(e) for e in outputs.values()}   # outputs live forever

    slot_of: Dict[int, int] = {}
    free: List[int] = []
    n_slots = 0

    def alloc() -> int:
        nonlocal n_slots
        if free:
            return free.pop()
        n_slots += 1
        return n_slots - 1

    def release(node: Expr):
        nid = id(node)
        uses[nid] -= 1
        if uses[nid] == 0 and nid not in pinned:
            free.append(slot_of[nid])

    instrs: List[Tuple[Callable, int, int, int]] = []
    sym_loads: List[Tuple[str, int]] = []
    const_loads: List[Tuple[int, Any]] = []
    # Leaves first: their loads are hoisted to the start of run(), so they
    # must never be placed into a slot freed mid-stream (an instruction
    # writing there earlier in the pass would clobber the hoisted load).
    # Dead leaf slots CAN later be reused as instruction destinations.
    for node in order:
        if isinstance(node, Const):
            s = alloc()
            const_loads.append((s, node.v))
            slot_of[id(node)] = s
        elif isinstance(node, Sym):
            s = alloc()
            sym_loads.append((node.name, s))
            slot_of[id(node)] = s
    for node in order:
        nid = id(node)
        if isinstance(node, (Const, Sym)):
            continue
        if isinstance(node, BinOp):
            a, b = slot_of[id(node.a)], slot_of[id(node.b)]
            release(node.a)
            release(node.b)
            s = alloc()                 # may legally reuse a child's slot:
            instrs.append((_BIN_FNS[node.op], s, a, b))  # read-before-write
        elif isinstance(node, UnOp):
            a = slot_of[id(node.a)]
            release(node.a)
            s = alloc()
            instrs.append((_UN_FNS[node.op], s, a, -1))
        else:
            raise TypeError(f"cannot compile node {node!r}")
        slot_of[nid] = s

    out_slots = {name: slot_of[id(e)] for name, e in outputs.items()}
    return Tape(instrs, n_slots, sym_loads, const_loads, out_slots)
