"""MistTuner: imbalance-aware hierarchical tuning (paper §5.3, Fig. 6).

Pipeline:  for each (S, G) hypothesis
             intra-stage batched sweep  ->  (t, d) Pareto frontier per stage
             inter-stage MILP over frontier samples (Eq. 2-3)
           pick the best (S, G) by Eq. 1.

Search-space presets reproduce the paper's baselines (Fig. 13 breakdown);
docs/search-spaces.md documents each preset's exact knob grid and which
baseline system it corresponds to:

    megatron   parallelism only, full CKPT, ZeRO-1       (Megatron-LM space)
    ckpt       + activation-checkpoint tuning            (Aceso/AdaPipe space)
    zero       + ZeRO level tuning                       (DeepSpeed space)
    offload    + offload-ratio tuning
    mist       everything co-tuned (+ imbalance awareness)
    uniform    mist knobs but one shared config for all stages
               (Yuan et al.-style heuristic)

The (S, G) loop itself is executed by the sweep executor in
core/sweep.py (`TuneSpec.workers`); docs/architecture.md has the full
dataflow of one tune() call.

Selected plans are memory-trustworthy: the stage model's Eq. 4
feasibility evaluates the same state-layout derivation the lowering
bills (`repro.lowering.state_layout`), so `memory_consistency` holds at
MEMORY_REL_TOL = 0.01 for every selected plan (golden fixtures pin the
selections; `tools/regen_golden.py --check` keeps them current).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

if TYPE_CHECKING:
    from repro.calibration.profile import CalibrationProfile

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.costmodel import BACKENDS, CostParams, StageCostModel
from repro.core.hardware import V5E, HardwareSpec
from repro.core.inter_stage import (InterStageSolution, StageCand,
                                    pipeline_objective, solve_milp)
from repro.core.intra_stage import IntraStageResult, ParetoPoint, tune_stage
from repro.core.plan import DEFAULT_KERNEL_CONFIG, Plan, StageConfig
from repro.core.schedule import (DEFAULT_KERNEL_GRID, RATIO_GRID,
                                 grad_accum_choices)

SPACES = ("none", "megatron", "ckpt", "zero", "offload", "mist", "uniform",
          "serve")


@dataclass(frozen=True)
class TuneSpec:
    arch: ArchConfig
    seq_len: int
    global_batch: int
    n_devices: int
    space: str = "mist"
    imbalance_aware: bool = True
    stage_counts: Optional[Sequence[int]] = None   # default: pow2 divisors
    grad_accums: Optional[Sequence[int]] = None
    layer_window: int = 2       # +- around uniform layers-per-stage
    max_front: int = 12
    max_tp: Optional[int] = None
    # "compiled": expression tape + struct-of-arrays grid + cross-(S, G)
    # frontier memoization.  "legacy": the pre-compilation interpreted path,
    # kept as the equivalence/speedup baseline (identical results).
    engine: str = "compiled"
    # tape evaluation backend ("numpy" | "jax" | "auto", see
    # StageCostModel): "jax" runs the compiled tapes on device arrays,
    # bitwise identical to numpy — enforced structurally: jax executes
    # only under jax_enable_x64 and only for correctly-rounded tapes,
    # degrading to numpy otherwise (or where jax is absent).  "auto"
    # additionally switches per tape run on grid size.  The selected
    # plan is therefore identical for every value (asserted in
    # tests/test_tape_backends.py).
    backend: str = "numpy"
    # (S, G) sweep execution (core/sweep.py; docs/architecture.md):
    #   0   plain in-loop sweeps (the PR-1 serial compiled engine, kept as
    #       the speedup baseline),
    #   1   G-collapsed executor in-process (default),
    #   >=2 G-collapsed executor across that many forked worker processes,
    #       frontier-memo shards merged at the join.
    # The selected plan is identical for every value (asserted in tests).
    workers: int = 1
    # Kernel-config dimension (core/plan.KernelConfig; docs/kernel-tuning.md):
    # False sweeps only the default (q_block=512, kv_block=512, rmsnorm=256,
    # ssd_chunk=256) tile tuple — the roofline delta term is exactly 0 there,
    # so plans are byte-identical to the pre-kernel-tuning tuner.  True
    # enlarges the grid with every legal tile tuple
    # (repro.kernels.autotune.legal_kernel_grid: MXU alignment, seq-len
    # divisibility, VMEM budget) as a joint per-candidate dimension.
    kernel_tune: bool = False
    # Explicit grid override ((q_block, kv_block, rmsnorm_block, ssd_chunk)
    # tuples) — takes precedence over kernel_tune; mainly for tests and
    # benchmarks that want a pinned, reproducible kernel sweep.
    kernel_grid: Optional[Tuple[Tuple[int, int, int, int], ...]] = None
    # Multi-host sweep fan-out (core/remote.py; docs/distributed-sweep.md):
    # "host:port" addresses of running `tools/tune_worker.py` daemons.
    # When set, the sweep executor shards units into len(hosts) x workers
    # lanes and ships each host its share over the stdlib socket RPC;
    # unreachable hosts degrade gracefully to the local pool.  The
    # selected plan is byte-identical at every (workers, hosts) setting
    # (asserted in tests/test_distributed.py).
    hosts: Optional[Tuple[str, ...]] = None
    # Persistent content-addressed memo store (core/memo_store.py):
    # directory where frontier-memo units and whole tune reports are
    # cached across processes.  Warm stage hypotheses are preloaded
    # before planning (plan_units drops them from the sweep) and a warm
    # whole-query report short-circuits tune() entirely
    # (TuneReport.from_memo).  Purely an execution accelerator: results
    # are byte-identical with or without it.
    memo_dir: Optional[str] = None
    # Paged-KV page-size grid for the serve space (core/serve_space.py;
    # docs/continuous-batching.md): token page sizes to sweep alongside
    # the kv grid, each priced by its own occupancy-aware ServeCostModel.
    # None sweeps only page_size = 0 (contiguous cache), whose exprs are
    # byte-identical to the pre-paging serve tuner — golden serve
    # fixtures stay stable.  Entries must divide seq_len; 0 may be
    # included to let the contiguous layout compete.
    page_grid: Optional[Tuple[int, ...]] = None
    # Measured calibration profile (repro.calibration; docs/calibration.md):
    # fitted per-platform CostParams / InterferenceModel overrides layered
    # over the tuner's cp.  Lives on the SPEC, not the tuner kwargs, because
    # sweep workers rebuild MistTuner from the pickled spec — a profile
    # passed only to the parent tuner would silently not propagate.  None
    # (and the no-override DEFAULT_PROFILE) keep today's constants exactly,
    # so golden plans are byte-identical.
    profile: Optional["CalibrationProfile"] = None


@dataclass
class TuneReport:
    plan: Optional[Plan]
    objective: float            # Eq. 1 step-time estimate (seconds)
    throughput_samples: float
    throughput_tokens: float
    space: str
    n_points: int               # candidate configurations considered
    n_milp: int
    tune_seconds: float
    best_S: int = 1
    best_G: int = 1
    per_sg: List[Tuple[int, int, float]] = field(default_factory=list)
    infeasible: bool = False
    n_swept: int = 0            # points actually swept (memo misses only)
    n_memo_hits: int = 0        # stage hypotheses served from the memo
    workers: int = 1            # sweep-executor worker processes used
    n_cache_hits: int = 0       # knob-tuple tape-cache hits (executor path)
    n_cache_misses: int = 0
    hosts_used: int = 0         # remote sweep daemons that served shards
    n_host_failures: int = 0    # shards that fell back to local execution
    n_store_hits: int = 0       # frontiers preloaded from the memo store
    from_memo: bool = False     # whole report served by the memo store


def _space_knobs(space: str, layers: int) -> Dict:
    """ckpt: "none" (no recompute), "full" (all layers, Megatron default),
    or "tune" (CKPT_i in the search space)."""
    full = dict(zeros=(0, 1, 2, 3), ratios=RATIO_GRID,
                ratio_dims=("oo", "ao"), ckpt="tune")
    if space == "none":      # parallelism only — Fig. 2(a)
        return dict(zeros=(0,), ratios=(0.0,), ratio_dims=(), ckpt="none")
    if space == "megatron":  # fixed FULL recompute + ZeRO-1
        return dict(zeros=(1,), ratios=(0.0,), ratio_dims=(), ckpt="full")
    if space == "ckpt":      # Aceso/AdaPipe: + CKPT tuning
        return dict(zeros=(1,), ratios=(0.0,), ratio_dims=(), ckpt="tune")
    if space == "zero":      # DeepSpeed: + ZeRO tuning (full recompute)
        return dict(zeros=(0, 1, 2, 3), ratios=(0.0,), ratio_dims=(),
                    ckpt="full")
    if space == "offload":   # + offload-ratio tuning
        return dict(zeros=(1,), ratios=RATIO_GRID, ratio_dims=("oo", "ao"),
                    ckpt="tune")
    if space in ("mist", "uniform"):
        return full
    raise ValueError(f"unknown space {space!r}; have {SPACES}")


class MistTuner:
    def __init__(self, spec: TuneSpec, *, hw: HardwareSpec = V5E,
                 cp: CostParams = CostParams()):
        if spec.backend not in BACKENDS:
            raise ValueError(f"unknown backend {spec.backend!r}; "
                             f"have {BACKENDS}")
        if spec.profile is not None:
            # fitted constants layered over cp; workers rebuilding from the
            # pickled spec apply the identical overrides (determinism)
            cp = spec.profile.cost_params(cp)
        self.spec, self.hw, self.cp = spec, hw, cp
        self._scm_cache: Dict[Tuple[bool, bool], StageCostModel] = {}
        # cross-(S, G) frontier memo: identical stage hypotheses (same
        # layers, devices, G, role, inflight, and search-space knobs) are
        # swept once and reused across the S/G double loop.
        self._frontier_memo: Dict[Tuple, IntraStageResult] = {}
        self._memo_hits = 0
        self._n_swept = 0
        self._kernel_grid: Optional[Tuple[Tuple[int, ...], ...]] = None

    # -- kernel-config grid (the tuned tile/block dimension) -----------------
    def kernel_grid(self) -> Tuple[Tuple[int, ...], ...]:
        """The (q_block, kv_block, rmsnorm_block, ssd_chunk) tuples swept
        jointly with every candidate.  Derived once per tuner from the spec
        (workers rebuild the tuner from the pickled spec, so every process
        computes the identical grid)."""
        if self._kernel_grid is None:
            if self.spec.kernel_grid is not None:
                self._kernel_grid = tuple(
                    tuple(int(x) for x in t) for t in self.spec.kernel_grid)
            elif self.spec.kernel_tune:
                from repro.kernels.autotune import legal_kernel_grid
                self._kernel_grid = legal_kernel_grid(
                    self.spec.arch, seq_len=self.spec.seq_len, hw=self.hw,
                    cp=self.cp)
            else:
                self._kernel_grid = DEFAULT_KERNEL_GRID
        return self._kernel_grid

    # -- stage cost model per role (L / inflight are symbols -> reusable) ---
    def scm(self, has_embed: bool, has_head: bool) -> StageCostModel:
        key = (has_embed, has_head)
        if key not in self._scm_cache:
            # self.cp already carries the profile's CostParams overrides
            # (applied in __init__, so kernel_grid()/sweep workers see them
            # too); passing the profile again is idempotent — the overrides
            # are absolute values — and additionally applies the profile's
            # interference table and jax_auto_threshold pin
            self._scm_cache[key] = StageCostModel(
                self.spec.arch, self.spec.seq_len, hw=self.hw, cp=self.cp,
                has_embed=has_embed, has_head=has_head,
                profile=self.spec.profile, backend=self.spec.backend)
        return self._scm_cache[key]

    def stage_counts(self) -> List[int]:
        if self.spec.stage_counts is not None:
            return list(self.spec.stage_counts)
        N, L = self.spec.n_devices, self.spec.arch.num_layers
        out = []
        s = 1
        while s <= min(N, L, 16):
            if N % s == 0:
                out.append(s)
            s *= 2
        return out

    def grad_accums(self) -> List[int]:
        if self.spec.grad_accums is not None:
            return list(self.spec.grad_accums)
        gs = grad_accum_choices(self.spec.global_batch, self.spec.n_devices)
        # keep the sweep tractable: log-spaced subset
        if len(gs) > 8:
            idx = np.unique(np.geomspace(1, len(gs), 8).astype(int) - 1)
            gs = [gs[i] for i in idx]
        return gs

    # -- per-(S, G) candidate construction -----------------------------------
    def _layer_options(self, S: int) -> List[int]:
        L = self.spec.arch.num_layers
        base = L // S
        w = self.spec.layer_window if S > 1 else 0
        opts = sorted({max(1, base + k) for k in range(-w, w + 2)})
        return [l for l in opts if l <= L]

    def _memo_key(self, *, layers: int, n_dev: int, G: int, role, inflight,
                  knobs) -> Tuple:
        """Frontier-memo key; also the sweep executor's shard/merge key."""
        return (layers, n_dev, G, role, float(inflight),
                tuple(knobs["zeros"]), tuple(knobs["ratios"]),
                tuple(knobs["ratio_dims"]), knobs["ckpt"],
                self.kernel_grid())

    def _frontier(self, *, layers: int, n_dev: int, G: int, role, inflight,
                  knobs) -> IntraStageResult:
        key = self._memo_key(layers=layers, n_dev=n_dev, G=G, role=role,
                             inflight=inflight, knobs=knobs)
        if self.spec.engine != "legacy":
            hit = self._frontier_memo.get(key)
            if hit is not None:
                self._memo_hits += 1
                return hit
        has_embed, has_head = role
        res = tune_stage(
            self.spec.arch, seq_len=self.spec.seq_len, layers=layers,
            n_devices=n_dev, global_batch_per_stage=self.spec.global_batch,
            grad_accum=G, has_embed=has_embed, has_head=has_head,
            inflight=inflight, hw=self.hw, cp=self.cp,
            zeros=knobs["zeros"], ratios=knobs["ratios"],
            ratio_dims=knobs["ratio_dims"],
            ckpt_values={"tune": None, "full": (layers,),
                         "none": (0,)}[knobs["ckpt"]],
            max_tp=self.spec.max_tp, max_front=self.spec.max_front,
            scm=self.scm(has_embed, has_head),
            refine=bool(knobs["ratio_dims"]),
            engine=self.spec.engine,
            kernel_grid=self.kernel_grid())
        self._n_swept += res.n_evaluated
        if self.spec.engine != "legacy":
            self._frontier_memo[key] = res
        return res

    def _cands_for(self, S: int, G: int, knobs) -> List[List[StageCand]]:
        N = self.spec.n_devices
        n_dev = N // S
        out: List[List[StageCand]] = []
        self._n_points = getattr(self, "_n_points", 0)
        for i in range(S):
            role = (i == 0, i == S - 1)
            inflight = float(S - i)
            cs: List[StageCand] = []
            for l in self._layer_options(S):
                res = self._frontier(layers=l, n_dev=n_dev, G=G, role=role,
                                     inflight=inflight, knobs=knobs)
                self._n_points += res.n_evaluated
                for p in res.frontier:
                    d = p.d
                    t = p.t
                    if not self.spec.imbalance_aware:
                        # ablation: average the delta into t (what prior
                        # systems do), losing the imbalance term
                        t = t + d / max(G, 1)
                        d = 0.0
                    cs.append(StageCand(layers=l, n_devices=n_dev, t=t, d=d,
                                        point=p))
            out.append(cs)
        return out

    def _cells(self) -> List[Tuple[int, int]]:
        """The (S, G) hypothesis cells `tune` visits, in loop order."""
        return [(S, G)
                for S in self.stage_counts()
                for G in self.grad_accums()
                if not self.spec.global_batch % G]

    # -- main ----------------------------------------------------------------
    def _store(self):
        """The persistent memo store, or None (spec.memo_dir unset)."""
        if self.spec.memo_dir is None:
            return None
        if getattr(self, "_memo_store", None) is None:
            from repro.core.memo_store import MemoStore
            self._memo_store = MemoStore(self.spec.memo_dir)
        return self._memo_store

    def tune(self) -> TuneReport:
        import dataclasses
        spec = self.spec
        t0 = time.time()
        store = self._store()
        if store is not None:
            # warm whole-query path: the report key ignores execution-
            # routing fields (engine/backend/workers/hosts), which never
            # change the answer, so any prior computation of this query
            # serves it — in milliseconds (docs/distributed-sweep.md)
            hit = store.load_report(self)
            if hit is not None:
                return dataclasses.replace(
                    hit, tune_seconds=time.time() - t0, from_memo=True)
        if spec.space == "serve":
            # inference regime: KV-cache memory + decode/prefill roofline
            # replace the training stage cost model entirely
            from repro.core.serve_space import tune_serve
            rep = tune_serve(self)
            if store is not None:
                store.save_report(self, rep)
            return rep
        knobs = _space_knobs(spec.space, spec.arch.num_layers)
        best: Optional[Tuple[float, int, int, InterStageSolution]] = None
        per_sg = []
        n_milp = 0
        self._n_points = 0
        self._memo_hits = 0
        self._n_swept = 0
        sweep_stats = None
        n_store_hits = 0
        if spec.engine != "legacy" and spec.workers >= 1:
            # (S, G) sweep executor: G-collapsed hypothesis sweeps, run in
            # process (workers=1), across forked workers, or fanned out to
            # remote hosts, filling the frontier memo up front; the loop
            # below then runs entirely from the memo.  Plan-identical to
            # the plain loop by construction (see core/sweep.py;
            # tests/test_sweep.py, tests/test_distributed.py).
            from repro.core.sweep import prefetch_frontiers
            cells = self._cells()
            if store is not None:
                # warm stage hypotheses load into the memo so plan_units
                # (inside prefetch_frontiers) drops them from the sweep
                n_store_hits = store.preload(self, cells, knobs)
            sweep_stats = prefetch_frontiers(self, cells, knobs,
                                             workers=spec.workers,
                                             hosts=spec.hosts)
            self._n_swept += sweep_stats.n_swept
            if store is not None:
                store.flush(self, cells, knobs)
        # gather each cell's candidate lists (all frontier-memo reads after
        # a prefetch), solve the independent per-cell MILPs — on the worker
        # pool when the executor is parallel — then reduce in loop order,
        # which keeps tie-breaking identical to the serial engine.
        sols: Dict[Tuple[int, int], Optional[InterStageSolution]] = {}
        milp_jobs: List[Tuple[int, int, List[List[StageCand]]]] = []
        cells = self._cells()
        for S, G in cells:
            if spec.space == "uniform" and S > 1:
                sols[(S, G)] = self._solve_uniform(S, G, knobs)
            else:
                cands = self._cands_for(S, G, knobs)
                if any(not cs for cs in cands):
                    continue
                milp_jobs.append((S, G, cands))
        if milp_jobs:
            n_milp += len(milp_jobs)
            if sweep_stats is not None and spec.workers > 1:
                from repro.core.sweep import solve_cells
                sols.update(solve_cells(
                    milp_jobs, total_layers=spec.arch.num_layers,
                    total_devices=spec.n_devices, workers=spec.workers))
            else:
                for S, G, cands in milp_jobs:
                    sols[(S, G)] = solve_milp(
                        cands, total_layers=spec.arch.num_layers,
                        total_devices=spec.n_devices, G=G)
        for S, G in cells:
            sol = sols.get((S, G))
            if sol is None:
                continue
            per_sg.append((S, G, sol.objective))
            if best is None or sol.objective < best[0]:
                best = (sol.objective, S, G, sol)
        dt = time.time() - t0
        workers_used = sweep_stats.workers_used if sweep_stats else 0
        c_hits = sweep_stats.cache_hits if sweep_stats else 0
        c_miss = sweep_stats.cache_misses if sweep_stats else 0
        hosts_used = sweep_stats.hosts_used if sweep_stats else 0
        host_fail = sweep_stats.n_host_failures if sweep_stats else 0
        if best is None:
            rep = TuneReport(plan=None, objective=float("inf"),
                             throughput_samples=0.0, throughput_tokens=0.0,
                             space=spec.space, n_points=self._n_points,
                             n_milp=n_milp, tune_seconds=dt,
                             infeasible=True, n_swept=self._n_swept,
                             n_memo_hits=self._memo_hits,
                             workers=workers_used, n_cache_hits=c_hits,
                             n_cache_misses=c_miss, hosts_used=hosts_used,
                             n_host_failures=host_fail,
                             n_store_hits=n_store_hits)
        else:
            obj, S, G, sol = best
            plan = self._to_plan(sol, G)
            rep = TuneReport(
                plan=plan, objective=obj,
                throughput_samples=spec.global_batch / obj,
                throughput_tokens=spec.global_batch * spec.seq_len / obj,
                space=spec.space, n_points=self._n_points, n_milp=n_milp,
                tune_seconds=dt, best_S=S, best_G=G, per_sg=per_sg,
                n_swept=self._n_swept, n_memo_hits=self._memo_hits,
                workers=workers_used, n_cache_hits=c_hits,
                n_cache_misses=c_miss, hosts_used=hosts_used,
                n_host_failures=host_fail, n_store_hits=n_store_hits)
        if store is not None:
            # an infeasible answer is still an answer: cache it too
            store.save_report(self, rep)
        return rep

    def _solve_uniform(self, S: int, G: int, knobs
                       ) -> Optional[InterStageSolution]:
        """Yuan et al.-style heuristic: identical config on every stage."""
        spec = self.spec
        L, N = spec.arch.num_layers, spec.n_devices
        if L % S or N % S:
            return None
        res = self._frontier(layers=L // S, n_dev=N // S, G=G,
                             role=(True, True), inflight=float(S),
                             knobs=knobs)
        self._n_points += res.n_evaluated
        if not res.frontier:
            return None
        best = None
        for p in res.frontier:
            sel = [StageCand(layers=L // S, n_devices=N // S, t=p.t, d=p.d,
                             point=p)] * S
            obj = pipeline_objective([p.t] * S, [p.d] * S, G)
            if best is None or obj < best.objective:
                best = InterStageSolution(objective=obj, selection=sel,
                                          status="uniform")
        return best

    def _to_plan(self, sol: InterStageSolution, G: int) -> Plan:
        stages = []
        for c in sol.selection:
            p = c.point
            assert p is not None
            stages.append(p.cand.to_stage(c.layers))
        plan = Plan(grad_accum=G, stages=tuple(stages),
                    sequence_parallel=True, remat_policy="full")
        # kernel dimension: the plan records stage 0's tile tuple (the
        # KernelConfig is plan-global; single-stage cells — the benchmarked
        # path — make this exact).  Emitted only when the sweep actually
        # moved off the default so frozen-default runs stay byte-identical;
        # a non-default choice switches execution onto the Pallas kernels
        # the tiles parameterize.
        kc = sol.selection[0].point.cand.kernel_config()
        if kc != DEFAULT_KERNEL_CONFIG:
            plan = plan.replace(kernel=kc, attn_impl="pallas",
                                use_pallas=True)
        return plan


# ---------------------------------------------------------------------------
# convenience
# ---------------------------------------------------------------------------


def tune(arch: ArchConfig, shape: ShapeConfig, n_devices: int,
         space: str = "mist", **kw) -> TuneReport:
    spec = TuneSpec(arch=arch, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, n_devices=n_devices,
                    space=space, **kw)
    return MistTuner(spec).tune()
