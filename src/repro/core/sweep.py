"""Parallel (S, G) sweep executor with a shared frontier cache.

`MistTuner.tune` enumerates (stage-count S, grad-accum G) hypotheses whose
intra-stage sweeps are embarrassingly parallel (paper §5.3; ROADMAP
"parallelize the (S, G) hypothesis loop").  This module turns that loop
into an explicit three-phase plan:

  1. **Plan**: enumerate every *stage hypothesis* the (S, G) double loop
     will ask for — `SweepUnit = (layers, n_dev, role, inflight)` plus the
     set of G values it is swept under — deduplicate, and drop whatever
     the tuner's frontier memo already holds.  This is the memo's key
     space, computed without sweeping anything.
  2. **Execute**: evaluate the units.  Each unit is G-collapsed
     (`tune_stage_multi_g`): one memory-feasibility pass over the union of
     its per-G grids, per-G runtime passes that share the cost model's
     knob-tuple tape cache, and one batched-across-G ratio refinement per
     descent iteration.  With `workers > 1`, units are sharded across a
     persistent pool of forked worker processes; the shard key groups
     same-(layers, n_dev, role) units so the knob-tuple cache (the time
     tape is inflight-independent) keeps hitting inside a worker.  Each
     worker returns its frontier-memo shard.  With `hosts`, shards
     additionally fan out over the RPC transport (`core/remote.py`) to
     `tools/tune_worker.py` daemons — same `_sweep_units` body, same
     shards, different processes — and unreachable hosts degrade to the
     local path (docs/distributed-sweep.md).
  3. **Join**: merge the shards into the tuner's `_frontier_memo`.  The
     (S, G) loop then runs unchanged in the parent — every `_frontier`
     call is a memo hit — followed by the per-cell MILPs and the exact
     same best-cell reduction as the serial engine.

Every unit is computed by the same code on the same inputs regardless of
which worker runs it, so the merged memo — and therefore the selected
plan — is bitwise identical to the serial compiled engine for any worker
count (asserted in tests/test_sweep.py).

The worker pool is created once (fork start method — see
`_start_method` for why fork and not forkserver/spawn) and reused
across `tune()` calls: forking a large scientific-Python process costs
hundreds of milliseconds on some hosts, which would otherwise swallow
the parallel speedup.  Workers receive self-contained
(spec, knobs, units) payloads and cache their tuner/cost-model state
between tasks, so nothing tape-sized ever crosses the process
boundary.  Without fork the executor transparently degrades to
in-process execution.
"""
from __future__ import annotations

import atexit
import multiprocessing as mp
import pickle
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.intra_stage import (IntraStageResult, pareto_front,
                                    refine_fronts_batched,
                                    tune_stage_multi_g)

# role = (has_embed, has_head)
SweepUnit = Tuple[int, int, Tuple[bool, bool], float]


@dataclass
class SweepStats:
    """Executor-side counters folded into TuneReport."""
    n_units: int = 0
    n_swept: int = 0            # candidate points evaluated across units
    cache_hits: int = 0         # knob-tuple tape-cache hits
    cache_misses: int = 0
    workers_used: int = 1
    memo_entries: int = 0
    hosts_used: int = 0         # remote daemons that served >= 1 shard
    n_host_failures: int = 0    # shards that fell back to local execution


@dataclass(frozen=True)
class SweepPlan:
    """Deduplicated stage hypotheses and the Gs each is swept under."""
    units: Tuple[SweepUnit, ...]
    gs_per_unit: Tuple[Tuple[int, ...], ...]

    def __len__(self) -> int:
        return len(self.units)


def plan_units(tuner, cells: Sequence[Tuple[int, int]], knobs) -> SweepPlan:
    """Mirror the (S, G) double loop's `_frontier` call sites without
    sweeping: which (layers, n_dev, role, inflight) hypotheses will be
    needed, under which G values.  Hypotheses already in the tuner's
    frontier memo (e.g. from a previous `tune()` on the same tuner) are
    skipped.  Order is deterministic (loop order)."""
    spec = tuner.spec
    L, N = spec.arch.num_layers, spec.n_devices
    units: Dict[SweepUnit, List[int]] = {}

    def need(key: SweepUnit, G: int):
        lyr, n_dev, role, inflight = key
        memo_key = tuner._memo_key(layers=lyr, n_dev=n_dev, G=G, role=role,
                                   inflight=inflight, knobs=knobs)
        if memo_key in tuner._frontier_memo:
            return
        units.setdefault(key, [])
        if G not in units[key]:
            units[key].append(G)

    for S, G in cells:
        if spec.space == "uniform" and S > 1:
            if L % S or N % S:
                continue
            need((L // S, N // S, (True, True), float(S)), G)
            continue
        n_dev = N // S
        for i in range(S):
            role = (i == 0, i == S - 1)
            inflight = float(S - i)
            for lyr in tuner._layer_options(S):
                need((lyr, n_dev, role, inflight), G)
    return SweepPlan(units=tuple(units),
                     gs_per_unit=tuple(tuple(g) for g in units.values()))


def _unit_cost(plan: SweepPlan, i: int) -> int:
    """Grid-row estimate for load balancing: the (zeros × ratios) block is
    a shared constant factor, so dp-divisor count × ckpt-grid size × G
    count tracks relative sweep cost well enough for greedy packing."""
    from repro.core.schedule import ckpt_choices, divisors
    lyr, n_dev, _role, _inflight = plan.units[i]
    return (len(divisors(n_dev))
            * len(ckpt_choices(lyr, max(1, lyr // 8)))
            * len(plan.gs_per_unit[i]))


def _shard_units(plan: SweepPlan, workers: int) -> List[List[int]]:
    """Assign unit indices to workers.  Units are grouped by
    (layers, n_dev, role) — the knob-tuple cache key prefix — so
    inflight-only variants land on the same worker and reuse each other's
    time-tape results; groups are then packed greedily by estimated grid
    rows.  Deterministic for a given plan."""
    groups: Dict[Tuple, List[int]] = {}
    for i, (lyr, n_dev, role, _inflight) in enumerate(plan.units):
        groups.setdefault((lyr, n_dev, role), []).append(i)
    order = sorted(groups.values(),
                   key=lambda idxs: (-sum(_unit_cost(plan, i)
                                          for i in idxs), idxs[0]))
    shards: List[List[int]] = [[] for _ in range(workers)]
    load = [0] * workers
    for idxs in order:
        w = min(range(workers), key=lambda j: (load[j], j))
        shards[w].extend(idxs)
        load[w] += sum(_unit_cost(plan, i) for i in idxs)
    return [s for s in shards if s]


def _sweep_units(tuner, plan: SweepPlan, knobs, unit_idxs: Sequence[int]
                 ) -> Tuple[List[Tuple[Tuple, IntraStageResult]], int]:
    """Compute the frontier-memo shard for the given units (pure function
    of (tuner spec, knobs, units) — identical on any worker).

    Sweeps run G-collapsed per unit; ratio refinement is batched one step
    further — across every (unit, G) frontier of a stage role — so each
    descent iteration is ONE tape + interference pass per role instead of
    one per hypothesis (`refine_fronts_batched`; results identical)."""
    spec = tuner.spec
    refine = bool(knobs["ratio_dims"])
    results: Dict[Tuple[int, int], IntraStageResult] = {}
    by_role: Dict[Tuple[bool, bool],
                  Tuple[Dict, Dict]] = {}   # role -> (fronts, meta)
    n_swept = 0
    for i in unit_idxs:
        layers, n_dev, role, inflight = plan.units[i]
        gs = plan.gs_per_unit[i]
        has_embed, has_head = role
        per_g = tune_stage_multi_g(
            spec.arch, seq_len=spec.seq_len, layers=layers, n_devices=n_dev,
            global_batch_per_stage=spec.global_batch, grad_accums=gs,
            has_embed=has_embed, has_head=has_head, inflight=inflight,
            hw=tuner.hw, cp=tuner.cp,
            zeros=knobs["zeros"], ratios=knobs["ratios"],
            ratio_dims=knobs["ratio_dims"],
            ckpt_values={"tune": None, "full": (layers,),
                         "none": (0,)}[knobs["ckpt"]],
            max_tp=spec.max_tp, max_front=spec.max_front,
            scm=tuner.scm(has_embed, has_head), refine=False,
            kernel_grid=tuner.kernel_grid())
        fronts, meta = by_role.setdefault(role, ({}, {}))
        for G, res in per_g.items():
            results[(i, G)] = res
            n_swept += res.n_evaluated
            if refine and res.frontier:
                fronts[(i, G)] = res.frontier
                meta[(i, G)] = (layers, inflight, G)
    if refine:
        for role, (fronts, meta) in by_role.items():
            if not fronts:
                continue
            scm = tuner.scm(*role)
            refined = refine_fronts_batched(
                fronts, meta, scm, budget=scm.memory_budget(),
                ratio_dims=knobs["ratio_dims"])
            for key, front in refined.items():
                results[key].frontier = pareto_front(
                    front, max_points=spec.max_front)
    shard: List[Tuple[Tuple, IntraStageResult]] = []
    for (i, G), res in results.items():
        layers, n_dev, role, inflight = plan.units[i]
        shard.append((tuner._memo_key(layers=layers, n_dev=n_dev, G=G,
                                      role=role, inflight=inflight,
                                      knobs=knobs), res))
    return shard, n_swept


# ---------------------------------------------------------------------------
# persistent worker pool
# ---------------------------------------------------------------------------

_POOL = None
_POOL_SIZE = 0
_CLEAR_BARRIER = None

# worker-process state: the tuner rebuilt from the last task's spec
# (compiled tapes and caches persist across tasks of the same spec)
_WORKER_TUNER = {"key": None, "tuner": None}


def _start_method():
    """Fork, or None (= run in-process).

    fork is deliberate: forkserver/spawn re-import ``__main__`` in every
    worker, which re-executes unguarded user scripts and breaks
    stdin/REPL sessions outright — far worse failure modes than fork's
    theoretical hazard (forking a parent whose XLA/BLAS threads hold an
    internal lock mid-fork).  That hazard is narrow here: workers never
    touch jax (the sweep path is numpy/scipy only), OpenBLAS and glibc
    malloc register fork handlers, and the full jax-initialized test
    suite exercises this pool without incident.  If a fork-related hang
    is ever suspected, ``workers=1`` (or 0) sidesteps the pool entirely
    with identical results."""
    return "fork" if "fork" in mp.get_all_start_methods() else None


def _get_pool(n: int):
    global _POOL, _POOL_SIZE, _CLEAR_BARRIER
    if _POOL is not None and _POOL_SIZE >= n:
        return _POOL
    if _POOL is not None:
        _POOL.terminate()
    ctx = mp.get_context(_start_method())
    # created BEFORE the pool so the forked workers inherit it; used by
    # clear_worker_caches to guarantee one clear task lands per worker
    _CLEAR_BARRIER = ctx.Barrier(n)
    _POOL = ctx.Pool(processes=n)
    _POOL_SIZE = n
    return _POOL


def shutdown_pool():
    """Terminate the persistent worker pool (atexit; also handy in tests)."""
    global _POOL, _POOL_SIZE, _CLEAR_BARRIER
    if _POOL is not None:
        _POOL.terminate()
        _POOL = None
        _POOL_SIZE = 0
        _CLEAR_BARRIER = None


atexit.register(shutdown_pool)


def _pool_task(payload: bytes):
    import dataclasses
    spec, knobs, plan, unit_idxs = pickle.loads(payload)
    # Forked workers always sweep on the numpy tape backend: calling into
    # an XLA runtime whose client the parent initialized before the fork
    # can deadlock (see _start_method), and every backend returns
    # bitwise-identical frontiers anyway (tests/test_tape_backends.py),
    # so the substitution is invisible in the merged memo.  Normalizing
    # the spec — including the execution-routing fields hosts/memo_dir/
    # workers, which never affect a unit's frontier — lets every spec
    # variant that differs only in routing share one worker tuner.
    spec = dataclasses.replace(spec, backend="numpy", hosts=None,
                               memo_dir=None, workers=1)
    key = pickle.dumps((spec, knobs))
    if _WORKER_TUNER["key"] != key:
        from repro.core.tuner import MistTuner
        _WORKER_TUNER["key"] = key
        _WORKER_TUNER["tuner"] = MistTuner(spec)
    tuner = _WORKER_TUNER["tuner"]
    base_h = sum(m.cache_hits for m in tuner._scm_cache.values())
    base_m = sum(m.cache_misses for m in tuner._scm_cache.values())
    shard, n_swept = _sweep_units(tuner, plan, knobs, unit_idxs)
    hits = sum(m.cache_hits for m in tuner._scm_cache.values()) - base_h
    misses = sum(m.cache_misses for m in tuner._scm_cache.values()) - base_m
    return shard, n_swept, hits, misses


def warm_pool(workers: int) -> bool:
    """Create the worker pool ahead of time (session setup): benchmarks
    call this + `clear_worker_caches()` before the timer so a cold-cache
    measurement includes neither the one-time fork cost nor stale result
    caches.  Returns False when no pool can be used."""
    if workers > 1 and _start_method() is not None:
        _get_pool(workers)
        return True
    return False


def _clear_task(_):
    """Drop this worker's knob-tuple result caches (compiled tapes and
    the cached tuner stay — they are session infrastructure, not per-tune
    results).  The barrier guarantees every pool worker executes exactly
    one of these before any returns: a bare Pool.map gives no
    per-process delivery guarantee, so without it a fast worker could
    absorb several clear tasks and leave another warm."""
    tuner = _WORKER_TUNER.get("tuner")
    if tuner is not None:
        for scm in tuner._scm_cache.values():
            scm._tape_cache.clear()
            scm.cache_hits = 0
            scm.cache_misses = 0
    try:
        _CLEAR_BARRIER.wait(timeout=60)
    except Exception:           # broken barrier: degrade, don't hang
        return False
    return True


def clear_worker_caches() -> bool:
    """Deterministically reset every pool worker's knob-tuple caches
    (benchmarks measure cold-cache parallel runs against this).  Returns
    True when every worker confirmed the clear; a broken barrier (e.g. a
    worker respawned mid-clear) is surfaced as a warning + False so a
    benchmark never silently reports warm runs as cold.  No-op (True)
    when no pool is live."""
    if _POOL is None:
        return True
    ok = all(_POOL.map(_clear_task, range(_POOL_SIZE), chunksize=1))
    if not ok:
        import warnings
        warnings.warn("clear_worker_caches: barrier broke; some workers "
                      "may still hold warm caches (pool restart gives a "
                      "guaranteed cold state)", RuntimeWarning)
    return ok


def _milp_task(payload: bytes):
    from repro.core.inter_stage import solve_milp
    cands, total_layers, total_devices, G = pickle.loads(payload)
    return solve_milp(cands, total_layers=total_layers,
                      total_devices=total_devices, G=G)


def solve_cells(jobs, *, total_layers: int, total_devices: int,
                workers: int = 1) -> Dict[Tuple[int, int], object]:
    """Solve the per-cell inter-stage MILPs (paper Eq. 2-3), optionally on
    the worker pool — each cell's MILP is independent and HiGHS is
    deterministic, so placement doesn't affect results.

    jobs: [(S, G, cands)] with cands the per-stage candidate lists."""
    n = min(max(1, int(workers)), len(jobs))
    if n > 1 and _start_method() is not None:
        pool = _get_pool(n)
        payloads = [pickle.dumps((cands, total_layers, total_devices, G))
                    for _S, G, cands in jobs]
        sols = pool.map(_milp_task, payloads)
        return {(S, G): sol for (S, G, _), sol in zip(jobs, sols)}
    from repro.core.inter_stage import solve_milp
    return {(S, G): solve_milp(cands, total_layers=total_layers,
                               total_devices=total_devices, G=G)
            for S, G, cands in jobs}


def _sweep_local(tuner, plan: SweepPlan, knobs,
                 unit_idxs: Sequence[int]) -> Tuple[list, int, int, int]:
    """In-process `_sweep_units` on the parent tuner, with the same
    (shard, n_swept, hits, misses) shape a pool/remote worker returns."""
    base_h = sum(m.cache_hits for m in tuner._scm_cache.values())
    base_m = sum(m.cache_misses for m in tuner._scm_cache.values())
    shard, n_swept = _sweep_units(tuner, plan, knobs, list(unit_idxs))
    hits = sum(m.cache_hits for m in tuner._scm_cache.values()) - base_h
    misses = sum(m.cache_misses for m in tuner._scm_cache.values()) - base_m
    return shard, n_swept, hits, misses


def _sweep_over_hosts(tuner, plan: SweepPlan, knobs, workers: int,
                      hosts: Sequence[str], stats: SweepStats) -> None:
    """Multi-host fan-out (docs/distributed-sweep.md): shard the plan into
    len(hosts) x workers lanes, ship each host its round-robin share of
    shards over the RPC transport, re-run any failed host's shards
    locally, and merge all shards in ascending shard-index order.

    Every unit lands in exactly one shard and every shard is computed by
    the same `_sweep_units` body wherever it runs, so the merged memo is
    bitwise identical to the serial engine's — host count, host failures
    and all (tests/test_distributed.py)."""
    from repro.core.remote import host_assignments, sweep_on_hosts
    n_lanes = max(1, len(hosts) * workers)
    shards = _shard_units(plan, n_lanes) if n_lanes > 1 \
        else [list(range(len(plan)))]
    outs, failed = sweep_on_hosts(tuner.spec, knobs, plan, shards, hosts)
    failed_set = set(failed)
    stats.hosts_used = sum(
        1 for _h, idxs in host_assignments(len(shards), hosts)
        if idxs and not failed_set.intersection(idxs))
    stats.n_host_failures = len(failed)
    if failed:
        # graceful degradation: unreachable hosts' shards re-run locally —
        # on the fork pool when one is usable, else in-process
        if workers > 1 and len(failed) > 1 and _start_method() is not None:
            pool = _get_pool(workers)
            payloads = [pickle.dumps((tuner.spec, knobs, plan, shards[i]))
                        for i in failed]
            for i, out in zip(failed, pool.map(_pool_task, payloads)):
                outs[i] = out
        else:
            for i in failed:
                outs[i] = _sweep_local(tuner, plan, knobs, shards[i])
    stats.workers_used = max(1, len(shards))
    for i in range(len(shards)):
        shard, n_swept, hits, misses = outs[i]
        tuner._frontier_memo.update(shard)
        stats.n_swept += n_swept
        stats.cache_hits += hits
        stats.cache_misses += misses


def prefetch_frontiers(tuner, cells: Sequence[Tuple[int, int]], knobs,
                       workers: int = 1,
                       hosts: Optional[Sequence[str]] = None) -> SweepStats:
    """Phases 1-3: plan units, execute (in-process, across the worker
    pool, or fanned out to remote `hosts` daemons), merge the
    frontier-memo shards into `tuner._frontier_memo`.

    After this returns, the tuner's (S, G) loop runs entirely from the
    memo; results are identical to the un-prefetched serial engine."""
    plan = plan_units(tuner, cells, knobs)
    stats = SweepStats(n_units=len(plan))
    if not len(plan):
        stats.memo_entries = len(tuner._frontier_memo)
        return stats
    workers = max(1, int(workers))
    if hosts:
        _sweep_over_hosts(tuner, plan, knobs, workers, tuple(hosts), stats)
        stats.memo_entries = len(tuner._frontier_memo)
        return stats
    shards = _shard_units(plan, workers) if workers > 1 else \
        [list(range(len(plan)))]
    use_pool = len(shards) > 1 and _start_method() is not None
    if use_pool:
        # size the pool at the requested worker count even when this
        # plan sharded smaller, so a later phase (solve_cells) never has
        # to recreate the pool and throw the warm worker caches away
        pool = _get_pool(workers)
        payloads = [pickle.dumps((tuner.spec, knobs, plan, s))
                    for s in shards]
        outs = pool.map(_pool_task, payloads)
        stats.workers_used = len(shards)
        for shard, n_swept, hits, misses in outs:
            tuner._frontier_memo.update(shard)
            stats.n_swept += n_swept
            stats.cache_hits += hits
            stats.cache_misses += misses
    else:
        base_h = sum(m.cache_hits for m in tuner._scm_cache.values())
        base_m = sum(m.cache_misses for m in tuner._scm_cache.values())
        shard, n_swept = _sweep_units(tuner, plan, knobs,
                                      list(range(len(plan))))
        tuner._frontier_memo.update(shard)
        stats.n_swept += n_swept
        stats.cache_hits = sum(m.cache_hits
                               for m in tuner._scm_cache.values()) - base_h
        stats.cache_misses = sum(
            m.cache_misses for m in tuner._scm_cache.values()) - base_m
        stats.workers_used = 1
    stats.memo_entries = len(tuner._frontier_memo)
    return stats
