"""Minimal length-prefixed socket RPC for the distributed sweep fan-out.

The sweep executor (core/sweep.py) ships self-contained
(spec, knobs, plan, unit-shard) payloads to remote executor daemons
(`tools/tune_worker.py`) and merges the returned frontier-memo shards at
the join.  This module is the transport: stdlib-only TCP framing, a
one-request-per-connection client with connect/data timeouts and
bounded retries, and a tiny threaded server both daemons
(`tools/tune_worker.py`, `tools/tune_service.py`) are built on.

Wire contract (docs/distributed-sweep.md):

  frame    = MAGIC (4 bytes, b"MST1") + len (8 bytes, big-endian) + body
  body     = pickle of a tuple
  request  = (op: str, *args)
  response = ("ok", result) | ("err", traceback_string)

One frame each way per TCP connection, then close — payloads are few and
large (unit shards, frontier memos), so connection setup is noise, and
the one-shot discipline makes failure semantics trivial: any socket
error, timeout, or short read is THE failure signal for that request; no
half-open protocol states exist.  Failures surface as ``RemoteError``
(server-side exceptions carry the remote traceback) or the underlying
``OSError``; `sweep_on_hosts` maps either to "this host's shards re-run
locally", preserving the byte-identical-plan guarantee.

Pickle is the serialization deliberately: payloads already cross the
local fork-pool boundary pickled (hash-consed Exprs re-intern through
``__reduce__``), and the daemons are trusted executors the user starts
on their own hosts — this is an intra-cluster tool, not an internet
service (bind daemons to trusted interfaces only).
"""
from __future__ import annotations

import os
import pickle
import socket
import socketserver
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

MAGIC = b"MST1"
MAX_FRAME = 1 << 31            # 2 GiB sanity bound on one frame

# Data-phase timeout covers remote sweep compute; connect is kept short so
# a dead host fails fast (both env-overridable for clusters with different
# latency envelopes, and monkeypatchable in tests).
CONNECT_TIMEOUT = float(os.environ.get("REPRO_RPC_CONNECT_TIMEOUT", "5"))
CALL_TIMEOUT = float(os.environ.get("REPRO_RPC_TIMEOUT", "600"))
RETRIES = int(os.environ.get("REPRO_RPC_RETRIES", "1"))
RETRY_BACKOFF_S = 0.2


class RemoteError(RuntimeError):
    """A daemon answered with ("err", traceback) — the remote traceback is
    the exception message."""


def parse_addr(addr: str) -> Tuple[str, int]:
    """"host:port" -> (host, port); bare ":port" means localhost."""
    host, _, port = addr.rpartition(":")
    if not port.isdigit():
        raise ValueError(f"malformed host address {addr!r}; want host:port")
    return host or "127.0.0.1", int(port)


def send_frame(sock: socket.socket, obj) -> None:
    body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame too large: {len(body)} bytes")
    sock.sendall(MAGIC + len(body).to_bytes(8, "big") + body)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket):
    head = _recv_exact(sock, len(MAGIC) + 8)
    if head[:len(MAGIC)] != MAGIC:
        raise ConnectionError(f"bad frame magic {head[:len(MAGIC)]!r}")
    n = int.from_bytes(head[len(MAGIC):], "big")
    if n > MAX_FRAME:
        raise ConnectionError(f"frame length {n} exceeds bound")
    return pickle.loads(_recv_exact(sock, n))


def request(addr: str, op: str, *args,
            timeout: Optional[float] = None,
            connect_timeout: Optional[float] = None,
            retries: Optional[int] = None):
    """One RPC round trip with bounded retries.

    Retries re-send the whole request — safe because every daemon op is
    either read-only (ping/stats) or a pure function of its payload
    (sweep/tune: recomputing a shard returns bitwise-identical results),
    so at-least-once delivery cannot corrupt state."""
    host, port = parse_addr(addr)
    attempts = (RETRIES if retries is None else retries) + 1
    last: Optional[Exception] = None
    for i in range(attempts):
        if i:
            time.sleep(RETRY_BACKOFF_S * i)
        try:
            with socket.create_connection(
                    (host, port),
                    timeout=(CONNECT_TIMEOUT if connect_timeout is None
                             else connect_timeout)) as sock:
                sock.settimeout(CALL_TIMEOUT if timeout is None else timeout)
                send_frame(sock, (op,) + args)
                status, payload = recv_frame(sock)
            if status == "err":
                raise RemoteError(f"{addr} {op}: {payload}")
            return payload
        except RemoteError:
            raise               # the handler ran and failed: not transient
        except (OSError, ConnectionError, EOFError,
                pickle.UnpicklingError) as exc:
            last = exc
    raise ConnectionError(
        f"no response from {addr} after {attempts} attempt(s): {last}")


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------


class RpcServer:
    """Threaded one-frame-per-connection RPC server.

    ``handlers`` maps op name -> callable(*args).  A "shutdown" op is
    built in (reply, then stop the serve loop) so tests and the CLI
    daemons can be torn down remotely; "ping" answers with a small info
    dict unless the caller installs its own."""

    def __init__(self, handlers: Dict[str, Callable], *,
                 host: str = "127.0.0.1", port: int = 0):
        outer = self

        class Handler(socketserver.BaseRequestHandler):
            def handle(self):
                try:
                    msg = recv_frame(self.request)
                except (ConnectionError, EOFError, pickle.UnpicklingError):
                    return          # port-scan / client died: nothing to say
                op, args = msg[0], msg[1:]
                if op == "shutdown":
                    send_frame(self.request, ("ok", "bye"))
                    threading.Thread(target=outer.server.shutdown,
                                     daemon=True).start()
                    return
                fn = outer.handlers.get(op)
                try:
                    if fn is None:
                        raise KeyError(f"unknown op {op!r}; "
                                       f"have {sorted(outer.handlers)}")
                    send_frame(self.request, ("ok", fn(*args)))
                except Exception:
                    try:
                        send_frame(self.request,
                                   ("err", traceback.format_exc()))
                    except OSError:
                        pass    # client gone: drop the error on the floor

        class Server(socketserver.ThreadingTCPServer):
            allow_reuse_address = True
            daemon_threads = True

        self.handlers = dict(handlers)
        self.handlers.setdefault("ping", lambda: {"pid": os.getpid()})
        self.server = Server((host, port), Handler)
        self.addr = "%s:%d" % self.server.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    def serve_forever(self):
        self.server.serve_forever(poll_interval=0.1)

    def start_in_thread(self) -> threading.Thread:
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        self._thread = t
        return t

    def shutdown(self):
        self.server.shutdown()
        self.server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)


# ---------------------------------------------------------------------------
# sweep fan-out client
# ---------------------------------------------------------------------------


def host_assignments(n_shards: int, hosts: Sequence[str]
                     ) -> List[Tuple[str, List[int]]]:
    """Round-robin shard indices over hosts, deterministically: host j
    serves shards j, j+len(hosts), ...  (shards are already packed by
    estimated cost, so round-robin keeps per-host load even)."""
    out = [(h, list(range(j, n_shards, len(hosts))))
           for j, h in enumerate(hosts)]
    return [(h, idxs) for h, idxs in out if idxs]


def sweep_on_hosts(spec, knobs, plan, shards: Sequence[Sequence[int]],
                   hosts: Sequence[str], *,
                   timeout: Optional[float] = None,
                   retries: Optional[int] = None
                   ) -> Tuple[Dict[int, tuple], List[int]]:
    """Fan the unit shards out to remote executor daemons.

    Returns ``(outs, failed)``: ``outs`` maps shard index -> the
    (memo-shard, n_swept, hits, misses) tuple the daemon computed —
    bitwise identical to a local worker's, because the daemon runs the
    same ``_sweep_units`` body on the numpy backend — and ``failed``
    lists shard indices whose host stayed unreachable after retries
    (the caller re-runs those locally: graceful degradation, identical
    results)."""
    import dataclasses
    from concurrent.futures import ThreadPoolExecutor

    # self-contained payload spec: execution-routing fields are stripped
    # so a daemon's worker-tuner cache key does not fracture across
    # clients that differ only in how they route the sweep
    spec = dataclasses.replace(spec, backend="numpy", hosts=None,
                               memo_dir=None, workers=1)
    assignments = host_assignments(len(shards), hosts)
    outs: Dict[int, tuple] = {}
    failed: List[int] = []

    def one(host: str, idxs: List[int]) -> List[tuple]:
        payload = pickle.dumps(
            (spec, knobs, plan, [list(shards[i]) for i in idxs]),
            protocol=pickle.HIGHEST_PROTOCOL)
        return pickle.loads(request(host, "sweep", payload,
                                    timeout=timeout, retries=retries))

    with ThreadPoolExecutor(max_workers=max(1, len(assignments))) as ex:
        futs = [(host, idxs, ex.submit(one, host, idxs))
                for host, idxs in assignments]
        for host, idxs, fut in futs:
            try:
                results = fut.result()
                if len(results) != len(idxs):
                    raise RemoteError(
                        f"{host}: {len(results)} shard results for "
                        f"{len(idxs)} shards")
                for i, res in zip(idxs, results):
                    outs[i] = res
            except (ConnectionError, OSError, RemoteError,
                    pickle.UnpicklingError, EOFError) as exc:
                import warnings
                warnings.warn(f"sweep host {host} failed ({exc}); its "
                              f"{len(idxs)} shard(s) fall back to the "
                              "local executor", RuntimeWarning)
                failed.extend(idxs)
    return outs, sorted(failed)
