"""Overlap-centric schedule template (paper §5.1, Figure 7).

The schedule is *described* here as data — which work runs on which of the
four hardware channels {C (MXU compute), G2G (ICI collectives), D2H, H2D}
during each phase of a training step — and *consumed* by the cost model
(`core/costmodel.py`), which feeds the per-phase channel times through the
interference model (paper Alg. 1) to get overlapped wall time.

Phases of one pipeline-stage step (G microbatches):

  first microbatch   : optimizer-state/master swap-in (H2D) + per-layer
                       decoupled optimizer step (C) + ZeRO param all-gather
                       (G2G) overlap the first forward.  (Mist's "optimizer
                       step decoupling and repositioning": each layer's update
                       runs right before its first forward use.)
  stable microbatches: fwd compute ∥ activation swap-out (D2H) ∥ param
                       all-gather for layer k+1 (G2G);
                       bwd compute ∥ grad reduce-scatter (G2G) ∥ activation
                       swap-in (H2D) ∥ grad-accum swap in/out (D2H/H2D).
  last microbatch    : bwd + the step-wise gradient sync (ZeRO<=1 all-reduce /
                       ZeRO>=2 final reduce-scatter) + optimizer-state/master
                       swap-out (D2H).

The legality rules for a configuration (divisibility, capacity sanity) also
live here so intra-stage enumeration and the runtime agree on what is a
valid point.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.plan import (DEFAULT_KERNEL_CONFIG, KernelConfig, Plan,
                             StageConfig)

# The four interference channels, in the order Alg. 1 consumes them.
CHANNELS = ("C", "G2G", "D2H", "H2D")

# offload ratios are searched on this grid (paper uses continuous ratios
# solved per-stage; a grid keeps the batched sweep dense and is refined by
# `intra_stage.refine_ratios` around the best grid point)
RATIO_GRID = (0.0, 0.25, 0.5, 0.75, 1.0)

# The kernel-config dimension of the grid: (q_block, kv_block, rmsnorm_block,
# ssd_chunk) tuples.  The single default tuple keeps the grid size and
# enumeration order byte-identical to the pre-kernel-tuning grid; the tuner
# swaps in `kernels.autotune.legal_kernel_grid(...)` when the kernel
# dimension is swept.
DEFAULT_KERNEL_GRID: Tuple[Tuple[int, int, int, int], ...] = (
    DEFAULT_KERNEL_CONFIG.astuple(),)


@dataclass(frozen=True)
class Candidate:
    """One intra-stage configuration point (the paper's per-stage knobs)."""
    b: int          # micro batch size
    dp: int
    tp: int
    zero: int       # 0..3
    ckpt: int       # recomputed layers (0..L)
    wo: float
    go: float
    oo: float
    ao: float
    # kernel-config knobs (tile/block sizes); the defaults reproduce the
    # pre-tuning fixed constants so legacy constructors are unchanged
    qb: int = 512   # flash-attention q_block
    kvb: int = 512  # flash-attention kv_block
    rnb: int = 256  # rmsnorm row-block
    sch: int = 256  # ssd_scan chunk

    def to_stage(self, layers: int) -> StageConfig:
        return StageConfig(layers=layers, micro_batch=self.b, dp=self.dp,
                           tp=self.tp, zero=self.zero, ckpt_layers=self.ckpt,
                           wo=self.wo, go=self.go, oo=self.oo, ao=self.ao)

    def kernel_config(self) -> KernelConfig:
        return KernelConfig(attn_q_block=self.qb, attn_kv_block=self.kvb,
                            rmsnorm_block=self.rnb, ssd_chunk=self.sch)


def divisors(n: int) -> List[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out


def legal_dp_tp(n_devices: int, cfg: ArchConfig,
                max_tp: Optional[int] = None) -> List[Tuple[int, int]]:
    """(dp, tp) splits of a stage's devices.

    TP must divide the head count (GQA: kv heads bound repartitioning of KV;
    we allow tp > kv_heads by replicating KV, matching the runtime's
    divisibility-aware sharding rules) and the MLP hidden dim.
    """
    pairs = []
    for tp in divisors(n_devices):
        if max_tp and tp > max_tp:
            continue
        if cfg.num_heads and cfg.num_heads % tp != 0:
            continue
        if cfg.d_ff and cfg.d_ff % tp and (cfg.moe_d_ff or cfg.d_ff) % tp:
            continue
        pairs.append((n_devices // tp, tp))
    return pairs


def microbatch_choices(global_batch: int, dp: int, grad_accum: int
                       ) -> List[int]:
    """b such that G * b * dp == global_batch for the given G."""
    if global_batch % (dp * grad_accum):
        return []
    return [global_batch // (dp * grad_accum)]


def grad_accum_choices(global_batch: int, n_devices: int,
                       cap: int = 512) -> List[int]:
    """G values the tuner sweeps (paper tunes G; the sweep is embarrassingly
    parallel over G)."""
    return [g for g in divisors(global_batch) if g <= cap]


def ckpt_choices(layers: int, granularity: int = 1) -> List[int]:
    """CKPT_i grid 0..L (paper: integer per stage)."""
    if layers <= 8 or granularity <= 1:
        return list(range(layers + 1))
    return sorted(set(list(range(0, layers + 1, granularity)) + [layers]))


def enumerate_candidates(cfg: ArchConfig, *, n_devices: int, layers: int,
                         global_batch: int, grad_accum: int,
                         zeros: Sequence[int] = (0, 1, 2, 3),
                         ratios: Sequence[float] = RATIO_GRID,
                         ratio_dims: Sequence[str] = ("oo", "ao"),
                         max_tp: Optional[int] = None,
                         ckpt_granularity: int = 1,
                         ckpt_values: Optional[Sequence[int]] = None,
                         kernel_grid: Sequence[Tuple[int, int, int, int]]
                         = DEFAULT_KERNEL_GRID
                         ) -> Iterator[Candidate]:
    """The intra-stage grid.  `ratio_dims` limits which offload knobs are
    swept (`intra_stage.refine_ratios` then descends on those same dims
    around the grid winners; the rest stay pinned at 0 so refinement never
    leaves the declared space).  `ckpt_values` pins the CKPT grid (e.g.
    (layers,) for the Megatron-style fixed-full-recompute baseline space)."""
    cks = (list(ckpt_values) if ckpt_values is not None
           else None)
    for dp, tp in legal_dp_tp(n_devices, cfg, max_tp=max_tp):
        for b in microbatch_choices(global_batch, dp, grad_accum):
            for zero in zeros:
                for ck in (cks if cks is not None
                           else ckpt_choices(layers, ckpt_granularity)):
                    ratio_space = [ratios if d in ratio_dims else (0.0,)
                                   for d in ("wo", "go", "oo", "ao")]
                    for wo, go, oo, ao in itertools.product(*ratio_space):
                        for qb, kvb, rnb, sch in kernel_grid:
                            yield Candidate(b=b, dp=dp, tp=tp, zero=zero,
                                            ckpt=ck, wo=wo, go=go, oo=oo,
                                            ao=ao, qb=qb, kvb=kvb, rnb=rnb,
                                            sch=sch)


# ---------------------------------------------------------------------------
# Struct-of-arrays candidate grid — the compiled-sweep counterpart of
# `enumerate_candidates`.  The (b, dp, tp, zero, ckpt, wo, go, oo, ao)
# cross-product is built directly as flat numpy columns (legality applied as
# vectorized masks over the divisor grid), in exactly the same order the
# nested-loop enumeration yields, so downstream Pareto selection breaks ties
# identically.  `Candidate` views are materialized lazily, only for the few
# frontier survivors.
# ---------------------------------------------------------------------------


GRID_FIELDS = ("b", "dp", "tp", "zero", "ckpt", "wo", "go", "oo", "ao",
               "qb", "kvb", "rnb", "sch")


@dataclass(frozen=True)
class CandidateGrid:
    """Columnar intra-stage candidate set; one float64 array per knob."""
    b: np.ndarray
    dp: np.ndarray
    tp: np.ndarray
    zero: np.ndarray
    ckpt: np.ndarray
    wo: np.ndarray
    go: np.ndarray
    oo: np.ndarray
    ao: np.ndarray
    qb: np.ndarray
    kvb: np.ndarray
    rnb: np.ndarray
    sch: np.ndarray

    def __len__(self) -> int:
        return int(self.b.shape[0])

    def candidate(self, i: int) -> Candidate:
        """Materialize row `i` as a Candidate (lazy view construction)."""
        return Candidate(b=int(self.b[i]), dp=int(self.dp[i]),
                         tp=int(self.tp[i]), zero=int(self.zero[i]),
                         ckpt=int(self.ckpt[i]),
                         wo=float(self.wo[i]), go=float(self.go[i]),
                         oo=float(self.oo[i]), ao=float(self.ao[i]),
                         qb=int(self.qb[i]), kvb=int(self.kvb[i]),
                         rnb=int(self.rnb[i]), sch=int(self.sch[i]))

    def take(self, idx) -> "CandidateGrid":
        return CandidateGrid(**{f: getattr(self, f)[idx]
                                for f in GRID_FIELDS})

    def env(self, *, layers: int, grad_accum: int, inflight: float = 1.0
            ) -> Dict[str, np.ndarray]:
        """Cost-model environment binding every symbol to a column —
        replaces per-object attribute gathering (`env_from_candidates`)."""
        return {
            "b": self.b, "dp": self.dp, "tp": self.tp, "zero": self.zero,
            "ckpt": np.minimum(self.ckpt, float(layers)),
            "wo": self.wo, "go": self.go, "oo": self.oo, "ao": self.ao,
            "qb": self.qb, "kvb": self.kvb, "rnb": self.rnb, "sch": self.sch,
            "L": float(layers), "G": float(grad_accum),
            "inflight": float(inflight),
        }


def legal_dp_tp_mask(n_devices: int, cfg: ArchConfig,
                     max_tp: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized `legal_dp_tp`: (dp, tp) columns over the divisor grid."""
    tp = np.asarray(divisors(n_devices), np.int64)
    ok = np.ones(tp.shape, bool)
    if max_tp:
        ok &= tp <= max_tp
    if cfg.num_heads:
        ok &= (cfg.num_heads % tp) == 0
    if cfg.d_ff:
        ok &= ((cfg.d_ff % tp) == 0) | (((cfg.moe_d_ff or cfg.d_ff) % tp)
                                        == 0)
    tp = tp[ok]
    return n_devices // tp, tp


def candidate_grid(cfg: ArchConfig, *, n_devices: int, layers: int,
                   global_batch: int, grad_accum: int,
                   zeros: Sequence[int] = (0, 1, 2, 3),
                   ratios: Sequence[float] = RATIO_GRID,
                   ratio_dims: Sequence[str] = ("oo", "ao"),
                   max_tp: Optional[int] = None,
                   ckpt_granularity: int = 1,
                   ckpt_values: Optional[Sequence[int]] = None,
                   kernel_grid: Sequence[Tuple[int, int, int, int]]
                   = DEFAULT_KERNEL_GRID
                   ) -> CandidateGrid:
    """Build the same grid as `enumerate_candidates`, as numpy columns."""
    dps, tps = legal_dp_tp_mask(n_devices, cfg, max_tp=max_tp)
    # b is unique per (dp, G): G * b * dp == global_batch, when divisible
    denom = dps * grad_accum
    feasible = (global_batch % denom) == 0
    dps, tps = dps[feasible], tps[feasible]
    bs = global_batch // (dps * grad_accum)
    cks = np.asarray(list(ckpt_values) if ckpt_values is not None
                     else ckpt_choices(layers, ckpt_granularity), np.float64)
    zs = np.asarray(list(zeros), np.float64)
    ratio_space = [np.asarray(ratios if d in ratio_dims else (0.0,),
                              np.float64) for d in ("wo", "go", "oo", "ao")]
    # kernel tuples are a joint dimension (not a cross product of their
    # fields); index them so the meshgrid stays rectangular.  With the
    # single default tuple the extra size-1 axis leaves the raveled order —
    # and therefore Pareto tie-breaking — byte-identical to the old grid.
    kcols = np.asarray(list(kernel_grid), np.float64)
    kidx = np.arange(kcols.shape[0], dtype=np.float64)
    # inner block in nested-loop order: zero (slowest), ckpt, wo, go, oo,
    # ao, kernel (fastest)
    mesh = np.meshgrid(zs, cks, *ratio_space, kidx, indexing="ij")
    zero_i, ck_i, wo_i, go_i, oo_i, ao_i, k_i = (m.ravel() for m in mesh)
    k_i = k_i.astype(np.int64)
    n_in, n_out = zero_i.size, dps.size
    return CandidateGrid(
        b=np.repeat(bs.astype(np.float64), n_in),
        dp=np.repeat(dps.astype(np.float64), n_in),
        tp=np.repeat(tps.astype(np.float64), n_in),
        zero=np.tile(zero_i, n_out), ckpt=np.tile(ck_i, n_out),
        wo=np.tile(wo_i, n_out), go=np.tile(go_i, n_out),
        oo=np.tile(oo_i, n_out), ao=np.tile(ao_i, n_out),
        qb=np.tile(kcols[k_i, 0], n_out), kvb=np.tile(kcols[k_i, 1], n_out),
        rnb=np.tile(kcols[k_i, 2], n_out), sch=np.tile(kcols[k_i, 3], n_out),
    )


# ---------------------------------------------------------------------------
# Legality / sanity of a full Plan (used by tests and the executor)
# ---------------------------------------------------------------------------


def validate_plan(plan: Plan, cfg: ArchConfig, n_devices: int,
                  global_batch: int) -> List[str]:
    """Returns a list of violations (empty = legal)."""
    errs = []
    if plan.total_layers != cfg.num_layers:
        errs.append(f"layers {plan.total_layers} != {cfg.num_layers}")
    if plan.devices != n_devices:
        errs.append(f"devices {plan.devices} != {n_devices}")
    s0 = plan.stages[0]
    if plan.grad_accum * s0.micro_batch * s0.dp != global_batch:
        errs.append(f"G*b*dp = {plan.grad_accum * s0.micro_batch * s0.dp}"
                    f" != global batch {global_batch}")
    for i, st in enumerate(plan.stages):
        if st.micro_batch * st.dp != s0.micro_batch * s0.dp:
            errs.append(f"stage {i}: b*dp mismatch with stage 0")
        if not (0 <= st.zero <= 3):
            errs.append(f"stage {i}: zero={st.zero}")
        if st.ckpt_layers < 0:
            errs.append(f"stage {i}: ckpt<0")
        for r in ("wo", "go", "oo", "ao"):
            v = getattr(st, r)
            if not (0.0 <= v <= 1.0):
                errs.append(f"stage {i}: {r}={v}")
        if cfg.num_heads and cfg.num_heads % st.tp:
            errs.append(f"stage {i}: tp={st.tp} !| heads={cfg.num_heads}")
    kc = plan.kernel
    for f in ("attn_q_block", "attn_kv_block", "rmsnorm_block", "ssd_chunk"):
        v = getattr(kc, f)
        if v < 8 or v & (v - 1):
            errs.append(f"kernel.{f}={v} (want a power of two >= 8)")
    return errs


# ---------------------------------------------------------------------------
# Schedule description (which phase puts which traffic on which channel).
# The cost model reads these flags; tests assert the overlap semantics.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PhaseTraffic:
    """Per-phase channel loads, as symbolic-expression factories resolved by
    the cost model.  This class only fixes *placement* (what overlaps with
    what); magnitudes come from the cost model."""
    name: str                    # "first" | "stable" | "last"
    compute: Tuple[str, ...]     # compute items on channel C
    g2g: Tuple[str, ...]         # ICI collective items
    d2h: Tuple[str, ...]
    h2d: Tuple[str, ...]


# Mist's Figure-7 schedule, transcribed: which cost items land on which
# channel in each phase.  Cost-item names are resolved by costmodel.py.
OVERLAP_SCHEDULE: Tuple[PhaseTraffic, ...] = (
    PhaseTraffic(
        name="first",
        compute=("fwd", "bwd", "recompute", "opt_step"),
        g2g=("tp_fwd", "tp_bwd", "zero3_allgather_fwd", "zero3_allgather_bwd",
             "zero2_reduce_scatter"),
        d2h=("act_offload_out", "grad_offload_out"),
        h2d=("act_offload_in", "grad_offload_in",
             "opt_swap_in", "master_swap_in"),
    ),
    PhaseTraffic(
        name="stable",
        compute=("fwd", "bwd", "recompute"),
        g2g=("tp_fwd", "tp_bwd", "zero3_allgather_fwd", "zero3_allgather_bwd",
             "zero2_reduce_scatter"),
        d2h=("act_offload_out", "grad_offload_out"),
        h2d=("act_offload_in", "grad_offload_in"),
    ),
    PhaseTraffic(
        name="last",
        compute=("fwd", "bwd", "recompute"),
        g2g=("tp_fwd", "tp_bwd", "zero3_allgather_fwd", "zero3_allgather_bwd",
             "zero2_reduce_scatter", "dp_grad_sync"),
        d2h=("act_offload_out", "grad_offload_out",
             "opt_swap_out", "master_swap_out"),
        h2d=("act_offload_in", "grad_offload_in"),
    ),
)


def phase(name: str) -> PhaseTraffic:
    for p in OVERLAP_SCHEDULE:
        if p.name == name:
            return p
    raise KeyError(name)
