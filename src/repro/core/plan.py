"""Training plan: the tuner's output and the runtime's input.

Mirrors Mist's schedule template (paper Table 2): per pipeline stage i the
knobs are (L_i, b_i, DP_i, TP_i, ZeRO_i, CKPT_i, WO_i, GO_i, OO_i, AO_i),
plus global gradient-accumulation steps G and the number of stages S.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class KernelConfig:
    """Per-op kernel implementation choices: which kernel runs and its
    tile/block sizes.  A tuned plan dimension — the tuner prices these with
    a roofline delta term and verifies survivors by instantiating the real
    Pallas kernels.  The defaults reproduce the pre-tuning behaviour
    bit-for-bit (the roofline delta term is exactly 0 at the defaults)."""
    attn_q_block: int = 512     # flash-attention query tile (rows)
    attn_kv_block: int = 512    # flash-attention key/value tile (cols)
    rmsnorm_block: int = 256    # rmsnorm row-block
    ssd_chunk: int = 256        # ssd_scan intra-chunk length

    def replace(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)

    def astuple(self) -> Tuple[int, int, int, int]:
        return (self.attn_q_block, self.attn_kv_block, self.rmsnorm_block,
                self.ssd_chunk)


DEFAULT_KERNEL_CONFIG = KernelConfig()


@dataclass(frozen=True)
class StageConfig:
    layers: int                 # L_i
    micro_batch: int            # b_i (per data-parallel replica)
    dp: int                     # DP_i
    tp: int                     # TP_i
    zero: int = 1               # ZeRO_i in {0,1,2,3}
    ckpt_layers: int = 10**9    # CKPT_i (clamped to L_i)
    wo: float = 0.0             # weight (master) offload ratio
    go: float = 0.0             # gradient-accumulator offload ratio
    oo: float = 0.0             # optimizer-state offload ratio
    ao: float = 0.0             # activation offload ratio

    @property
    def devices(self) -> int:
        return self.dp * self.tp

    def replace(self, **kw) -> "StageConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Plan:
    grad_accum: int             # G
    stages: Tuple[StageConfig, ...]
    sequence_parallel: bool = True
    remat_policy: str = "full"  # full | dots
    attn_impl: str = "naive"    # naive | blocked | pallas (FlashAttention)
    use_pallas: bool = False
    grad_compression: bool = False  # int8 + error feedback on DP reduce
    kv_cache_dtype: str = "bf16"    # bf16 | int8 (serving; dynamic scales)
    kernel: KernelConfig = DEFAULT_KERNEL_CONFIG  # tile/block choices
    page_size: int = 0  # paged-KV page size in tokens; 0 = contiguous cache

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def devices(self) -> int:
        return sum(s.devices for s in self.stages)

    @property
    def total_layers(self) -> int:
        return sum(s.layers for s in self.stages)

    def global_batch(self) -> int:
        # all stages see the same data stream: gbs = G * b_0 * DP_0
        s0 = self.stages[0]
        return self.grad_accum * s0.micro_batch * s0.dp

    def replace(self, **kw) -> "Plan":
        return dataclasses.replace(self, **kw)

    # -- (de)serialization ----------------------------------------------------
    def to_json(self) -> str:
        doc = {
            "grad_accum": self.grad_accum,
            "sequence_parallel": self.sequence_parallel,
            "remat_policy": self.remat_policy,
            "attn_impl": self.attn_impl,
            "use_pallas": self.use_pallas,
            "grad_compression": self.grad_compression,
            "kv_cache_dtype": self.kv_cache_dtype,
        }
        # emitted only when tuned away from the defaults so plans from the
        # frozen-default kernel dimension serialize byte-identically to
        # pre-kernel-tuning plans (golden fixtures stay stable)
        if self.kernel != DEFAULT_KERNEL_CONFIG:
            doc["kernel"] = dataclasses.asdict(self.kernel)
        # same omission rule: page_size = 0 (contiguous) serializes
        # byte-identically to pre-paging plans
        if self.page_size:
            doc["page_size"] = self.page_size
        doc["stages"] = [dataclasses.asdict(s) for s in self.stages]
        return json.dumps(doc, indent=2)

    @staticmethod
    def from_json(text: str) -> "Plan":
        d = json.loads(text)
        stages = tuple(StageConfig(**s) for s in d.pop("stages"))
        kernel = KernelConfig(**d.pop("kernel")) if "kernel" in d \
            else DEFAULT_KERNEL_CONFIG
        return Plan(stages=stages, kernel=kernel, **d)


def single_stage_plan(num_layers: int, *, dp: int, tp: int, micro_batch: int,
                      grad_accum: int, zero: int = 1,
                      ckpt_layers: Optional[int] = None, wo=0.0, go=0.0,
                      oo=0.0, ao=0.0, **plan_kw) -> Plan:
    """Convenience: the no-pipeline plan (S=1)."""
    st = StageConfig(layers=num_layers, micro_batch=micro_batch, dp=dp, tp=tp,
                     zero=zero,
                     ckpt_layers=num_layers if ckpt_layers is None
                     else ckpt_layers,
                     wo=wo, go=go, oo=oo, ao=ao)
    return Plan(grad_accum=grad_accum, stages=(st,), **plan_kw)


def megatron_baseline_plan(num_layers: int, n_devices: int, global_batch: int,
                           *, tp: int = 16, zero: int = 1) -> Plan:
    """Paper-faithful baseline search-space point: fixed full activation
    checkpointing, TP over the model axis, DP elsewhere, ZeRO-1."""
    dp = n_devices // tp
    micro = max(1, global_batch // dp)
    # shrink micro-batch to 1 and use accumulation (Megatron default style)
    grad_accum = micro
    return single_stage_plan(num_layers, dp=dp, tp=tp, micro_batch=1,
                             grad_accum=grad_accum, zero=zero,
                             ckpt_layers=num_layers)
