"""Content-addressed on-disk store for frontier memos and tune reports.

The cross-job half of ROADMAP item 2: the in-process frontier memo
(`MistTuner._frontier_memo`) becomes a persistent cache shared across
`tune()` calls, processes, and daemons.  Two entry kinds live under one
directory (`TuneSpec.memo_dir` / `launch/train.py --memo-dir` /
`tools/tune_service.py --memo-dir`):

  units/<hh>/<hash>.pkl    one IntraStageResult — a stage-hypothesis
                           frontier, keyed by unit_key()
  reports/<hh>/<hash>.pkl  one TuneReport — a whole solved query,
                           keyed by report_key()

Keys are sha256 digests of canonical JSON (tuples→lists, dataclasses→
sorted dicts, floats via repr for bit-exactness), so equality is
structural — no pickle-bytes fragility — and any semantic input change
moves the address:

* ``unit_key`` covers the tuner fingerprint (arch config, workload
  shape, hardware spec, post-profile CostParams **including kernel
  coeffs**, the profile document itself, max_tp/max_front) plus the
  tuner's ``_memo_key`` (layers, n_dev, G, role, inflight, knob grids,
  kernel grid).  Changing a calibration profile, the knob grid, or the
  kernel grid therefore *invalidates* — old entries are simply never
  addressed again (tests/test_distributed.py pins this).
* ``report_key`` covers the whole TuneSpec **minus** the
  execution-routing fields (engine, backend, workers, hosts, memo_dir):
  those provably do not change the selected plan (the PR-2/3 bitwise
  guarantee, extended over hosts by this PR), so a report computed with
  any routing serves every routing.  scipy's version is folded in
  because HiGHS tie-breaking is part of the answer.

Schema changes bump MEMO_SCHEMA_VERSION, which is folded into every
digest — old trees are abandoned in place, never misread.

Concurrency/corruption: writes go to a same-directory temp file then
``os.replace`` (atomic on POSIX), so readers never observe partial
entries; a corrupt or truncated entry is treated as a miss (and
unlinked) rather than an error.  Multiple writers racing on one key
write identical bytes, so last-writer-wins is harmless.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
from typing import Dict, Optional, Tuple

MEMO_SCHEMA_VERSION = 1


def _canonical(obj):
    """Reduce to JSON-able structure with deterministic ordering.  Floats
    go through repr(): round-trip exact, so 0.75*2**30 and 805306368.0
    hash identically iff they are the same double."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dc__": type(obj).__name__,
                **{f.name: _canonical(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)}}
    if isinstance(obj, dict):
        return {"__map__": sorted((json.dumps(_canonical(k), sort_keys=True),
                                   _canonical(v)) for k, v in obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_canonical(x) for x in obj]
    if isinstance(obj, float):
        return {"__f__": repr(obj)}
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    # anything exotic (e.g. numpy scalar) — stringify rather than crash;
    # worst case is a needless cache miss, never a false hit
    return {"__repr__": type(obj).__name__ + ":" + repr(obj)}


def digest(obj) -> str:
    doc = json.dumps(_canonical(obj), sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(doc.encode()).hexdigest()


def _scipy_version() -> Optional[str]:
    try:
        import scipy
        return scipy.__version__
    except Exception:
        return None


def tuner_fingerprint(tuner) -> Dict:
    """Everything besides the memo key that determines a unit's frontier.
    ``tuner.cp`` is post-profile (MistTuner applies overrides in
    __init__), and the profile document is folded in anyway so
    interference/jax_auto_threshold overrides also move the address."""
    spec = tuner.spec
    return {
        "schema": MEMO_SCHEMA_VERSION,
        "arch": tuner.spec.arch,
        "seq_len": spec.seq_len,
        "global_batch": spec.global_batch,
        "max_tp": spec.max_tp,
        "max_front": spec.max_front,
        "imbalance_aware": spec.imbalance_aware,
        "hw": tuner.hw,
        "cp": tuner.cp,
        "profile": spec.profile.to_doc() if spec.profile is not None else None,
    }


def unit_key(fingerprint: Dict, memo_key: Tuple) -> str:
    return digest({"fp": fingerprint, "memo_key": memo_key})


# TuneSpec fields that route execution without affecting the answer —
# excluded from report_key so a report computed under any (engine,
# backend, workers, hosts) combination serves all of them.
_EXEC_FIELDS = ("engine", "backend", "workers", "hosts", "memo_dir")


def report_key(tuner) -> str:
    spec = tuner.spec
    doc = {f.name: getattr(spec, f.name)
           for f in dataclasses.fields(spec) if f.name not in _EXEC_FIELDS}
    doc["profile"] = (spec.profile.to_doc()
                      if spec.profile is not None else None)
    return digest({"schema": MEMO_SCHEMA_VERSION, "spec": doc,
                   "hw": tuner.hw, "cp": tuner.cp,
                   "scipy": _scipy_version()})


class MemoStore:
    """Directory-backed content-addressed store; all methods are safe to
    call concurrently from multiple processes."""

    def __init__(self, root: str):
        self.root = os.path.abspath(os.path.expanduser(root))
        self.unit_hits = 0
        self.unit_misses = 0
        self.report_hits = 0

    # -- raw entry IO --------------------------------------------------------
    def _path(self, kind: str, key: str) -> str:
        return os.path.join(self.root, kind, key[:2], key + ".pkl")

    def _get(self, kind: str, key: str):
        path = self._path(kind, key)
        try:
            with open(path, "rb") as f:
                value = pickle.load(f)
            # refresh the entry's timestamp on every hit so gc() evicts
            # by last ACCESS, not write order (atime is unreliable under
            # noatime mounts; mtime is ours to repurpose)
            try:
                os.utime(path)
            except OSError:
                pass
            return value
        except FileNotFoundError:
            return None
        except Exception:
            # truncated/corrupt entry: treat cold and clear the slot so the
            # refreshed write below isn't racing a poisoned file forever
            try:
                os.unlink(path)
            except OSError:
                pass
            return None

    def _put(self, kind: str, key: str, value) -> None:
        path = self._path(kind, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(value, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def count(self, kind: str = "units") -> int:
        n = 0
        base = os.path.join(self.root, kind)
        for dirpath, _dirs, files in os.walk(base):
            n += sum(f.endswith(".pkl") for f in files)
        return n

    def gc(self, max_bytes: int) -> Dict[str, int]:
        """Prune the store down to ``max_bytes``, oldest-ACCESS entries
        first (``_get`` refreshes an entry's timestamp on every hit).

        Each eviction is one atomic ``unlink``: a reader racing a gc sees
        either the whole entry or a miss, never a partial file.  Entries
        that vanish mid-scan (another gc, a writer's ``os.replace``) are
        skipped.  Returns {scanned, removed, bytes_before, bytes_after}.
        """
        if max_bytes < 0:
            raise ValueError("max_bytes must be >= 0")
        entries = []          # (mtime, size, path)
        for kind in ("units", "reports"):
            base = os.path.join(self.root, kind)
            for dirpath, _dirs, files in os.walk(base):
                for f in files:
                    if not f.endswith(".pkl"):
                        continue
                    path = os.path.join(dirpath, f)
                    try:
                        st = os.stat(path)
                    except OSError:
                        continue
                    entries.append((st.st_mtime, st.st_size, path))
        total = sum(e[1] for e in entries)
        stats = {"scanned": len(entries), "removed": 0,
                 "bytes_before": total, "bytes_after": total}
        entries.sort()
        for _mtime, size, path in entries:
            if stats["bytes_after"] <= max_bytes:
                break
            try:
                os.unlink(path)
            except OSError:
                continue               # already gone: someone else's gc
            stats["removed"] += 1
            stats["bytes_after"] -= size
        return stats

    # -- frontier-memo units -------------------------------------------------
    def preload(self, tuner, cells, knobs) -> int:
        """Load warm stage-hypothesis frontiers into the tuner's in-memory
        memo so `plan_units` drops them from the sweep.  Enumerates
        exactly the keys the (S, G) loop will need (via `plan_units` on a
        scratch view) and returns the number of entries loaded."""
        from repro.core.sweep import plan_units
        fp = tuner_fingerprint(tuner)
        plan = plan_units(tuner, cells, knobs)
        loaded = 0
        for i, unit in enumerate(plan.units):
            layers, n_dev, role, inflight = unit
            for G in plan.gs_per_unit[i]:
                memo_key = tuner._memo_key(
                    layers=layers, n_dev=n_dev, G=G, role=role,
                    inflight=inflight, knobs=knobs)
                res = self._get("units", unit_key(fp, memo_key))
                if res is not None:
                    tuner._frontier_memo[memo_key] = res
                    loaded += 1
                    self.unit_hits += 1
                else:
                    self.unit_misses += 1
        return loaded

    def flush(self, tuner, cells, knobs) -> int:
        """Persist the frontiers this tune populated for the given cells.
        Re-derives the needed memo keys the same way preload did (the
        in-memory memo may also hold entries for other knob grids from
        earlier tune() calls on the same tuner; those were flushed by
        their own tune).  Returns the number of entries written."""
        from repro.core.sweep import SweepPlan, plan_units  # noqa: F401
        fp = tuner_fingerprint(tuner)
        spec = tuner.spec
        L, N = spec.arch.num_layers, spec.n_devices
        written = 0
        seen = set()

        def flush_one(layers, n_dev, role, inflight, G):
            nonlocal written
            memo_key = tuner._memo_key(layers=layers, n_dev=n_dev, G=G,
                                       role=role, inflight=inflight,
                                       knobs=knobs)
            if memo_key in seen:
                return
            seen.add(memo_key)
            res = tuner._frontier_memo.get(memo_key)
            if res is None:
                return
            key = unit_key(fp, memo_key)
            if self._get("units", key) is None:
                self._put("units", key, res)
                written += 1

        for S, G in cells:
            if spec.space == "uniform" and S > 1:
                if L % S or N % S:
                    continue
                flush_one(L // S, N // S, (True, True), float(S), G)
                continue
            for i in range(S):
                role = (i == 0, i == S - 1)
                inflight = float(S - i)
                for lyr in tuner._layer_options(S):
                    flush_one(lyr, N // S, role, inflight, G)
        return written

    # -- whole-report cache (the millisecond warm path) ----------------------
    def load_report(self, tuner):
        rep = self._get("reports", report_key(tuner))
        if rep is not None:
            self.report_hits += 1
        return rep

    def save_report(self, tuner, report) -> None:
        self._put("reports", report_key(tuner), report)
