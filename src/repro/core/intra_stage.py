"""Intra-stage tuning: Dual-Objective Constrained Optimization (paper §5.3).

Given a stage (its layer count, device count, grad-accum G, memory budget),
find, over the grid of (b, DP, TP, ZeRO, CKPT, WO, GO, OO, AO):

    min_{p,z,o}  alpha * G * t_{p,z,o} + (1 - alpha) * d_{p,z,o}
    s.t.  max(Mem_fwd, Mem_bwd) <= Mem_budget                     (Eq. 4)

for a uniform sample of alpha in [0,1] — the winners over alpha form the
(t, d) Pareto frontier handed to the inter-stage MILP (Eq. 2-3).

The full grid is evaluated in ONE batched substitution into the symbolic
cost model (no per-config simulation), which is the paper's key tuning-speed
idea.  A local ratio-refinement pass then descends on the four offload
ratios around each frontier point (the paper treats them as continuous).

The Eq. 4 feasibility mask is SPEC-EXACT since PR 5: the memory tape
charges state through the shared state-layout derivation
(`repro.lowering.state_layout`), so a candidate whose indivisible dims
replicate (e.g. an odd vocab at tp=8) is charged what the lowered
program will actually hold — plans selected at the budget boundary are
trustworthy, the regime this dual-objective optimization lives in.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.costmodel import CostParams, StageCostModel
from repro.core.hardware import V5E, HardwareSpec
from repro.core.schedule import (DEFAULT_KERNEL_GRID, RATIO_GRID, Candidate,
                                 CandidateGrid, candidate_grid,
                                 enumerate_candidates)

ALL_RATIO_DIMS = ("wo", "go", "oo", "ao")


@dataclass(frozen=True)
class ParetoPoint:
    t: float                  # stable microbatch time (Eq. 5)
    d: float                  # first/last delta (Eq. 6)
    mem: float                # peak bytes
    cand: Candidate

    def dominates(self, o: "ParetoPoint") -> bool:
        return (self.t <= o.t and self.d <= o.d
                and (self.t < o.t or self.d < o.d))


@dataclass
class IntraStageResult:
    """Pareto frontier for one (layers, devices, G) stage hypothesis."""
    layers: int
    n_devices: int
    grad_accum: int
    frontier: List[ParetoPoint]      # sorted by t ascending / d descending
    n_evaluated: int = 0
    n_feasible: int = 0

    def best(self, weight_t: float) -> Optional[ParetoPoint]:
        """argmin over the frontier of weight_t * t + d."""
        if not self.frontier:
            return None
        return min(self.frontier, key=lambda p: weight_t * p.t + p.d)


def pareto_front(pts: Sequence[ParetoPoint], max_points: int = 16
                 ) -> List[ParetoPoint]:
    """Non-dominated (t, d) points, decimated to <= max_points (uniform in
    t-order — the paper's 'Pareto frontier sampling')."""
    if not pts:
        return []
    pts = sorted(pts, key=lambda p: (p.t, p.d))
    front: List[ParetoPoint] = []
    best_d = float("inf")
    for p in pts:
        if p.d < best_d - 1e-12:
            front.append(p)
            best_d = p.d
    if len(front) > max_points:
        idx = np.linspace(0, len(front) - 1, max_points).round().astype(int)
        front = [front[i] for i in sorted(set(idx.tolist()))]
    return front


def pareto_front_indices(t: np.ndarray, d: np.ndarray, max_points: int = 16
                         ) -> np.ndarray:
    """Vectorized `pareto_front` over columnar (t, d): returns the indices
    of the surviving frontier, in ascending-t order.  Selects the identical
    point set (same stable (t, d) sort, same 1e-12 tolerance chain, same
    decimation), so no per-candidate Python objects are needed upstream.
    """
    if t.size == 0:
        return np.empty(0, np.intp)
    order = np.lexsort((d, t))           # stable: by t, then d, then index
    ds = d[order]
    # strict running-min prefilter is a provable superset of the kept chain
    # (any point kept by the tolerance rule lies strictly below the min of
    # everything before it); the exact tolerance scan then runs on the few
    # survivors only.
    prev_min = np.concatenate(([np.inf], np.minimum.accumulate(ds)[:-1]))
    chain = np.nonzero(ds < prev_min)[0]
    keep: List[int] = []
    best_d = float("inf")
    for j in chain.tolist():
        v = float(ds[j])
        if v < best_d - 1e-12:
            keep.append(j)
            best_d = v
    if len(keep) > max_points:
        idx = np.linspace(0, len(keep) - 1, max_points).round().astype(int)
        keep = [keep[i] for i in sorted(set(idx.tolist()))]
    return order[np.asarray(keep, np.intp)]


def tune_stage(cfg: ArchConfig, *, seq_len: int, layers: int, n_devices: int,
               global_batch_per_stage: int, grad_accum: int,
               has_embed: bool = True, has_head: bool = True,
               inflight: float = 1.0,
               hw: HardwareSpec = V5E, cp: CostParams = CostParams(),
               zeros: Sequence[int] = (0, 1, 2, 3),
               ratios: Sequence[float] = RATIO_GRID,
               ratio_dims: Sequence[str] = ("oo", "ao"),
               ckpt_granularity: int = 0,
               ckpt_values: Optional[Sequence[int]] = None,
               max_tp: Optional[int] = None,
               max_front: int = 16,
               scm: Optional[StageCostModel] = None,
               refine: bool = True,
               engine: str = "compiled",
               backend: Optional[str] = None,
               kernel_grid: Sequence[Tuple[int, int, int, int]]
               = DEFAULT_KERNEL_GRID) -> IntraStageResult:
    """Batched sweep -> feasible set -> Pareto frontier -> ratio refinement.

    engine="compiled" (default) runs the struct-of-arrays grid through the
    cost model's compiled expression tape and selects the frontier on the
    columnar results; Candidate objects exist only for frontier survivors.
    engine="legacy" is the pre-compilation path (per-object candidate list,
    recursive expression walks, Python Pareto scan) kept as the equivalence
    and speedup baseline — both must return identical frontiers.

    ``backend`` selects the tape evaluation backend ("numpy"|"jax"|"auto",
    see StageCostModel) when this call constructs the cost model; a
    caller-supplied ``scm`` brings its own backend and wins.  Every
    backend returns identical frontiers (tests/test_tape_backends.py).
    """
    if ckpt_granularity <= 0:
        ckpt_granularity = max(1, layers // 8)
    if engine == "legacy":
        return _tune_stage_legacy(
            cfg, seq_len=seq_len, layers=layers, n_devices=n_devices,
            global_batch_per_stage=global_batch_per_stage,
            grad_accum=grad_accum, has_embed=has_embed, has_head=has_head,
            inflight=inflight, hw=hw, cp=cp, zeros=zeros, ratios=ratios,
            ratio_dims=ratio_dims, ckpt_granularity=ckpt_granularity,
            ckpt_values=ckpt_values, max_tp=max_tp, max_front=max_front,
            scm=scm, refine=refine, kernel_grid=kernel_grid)
    if engine != "compiled":
        raise ValueError(f"unknown engine {engine!r}")
    grid = candidate_grid(
        cfg, n_devices=n_devices, layers=layers,
        global_batch=global_batch_per_stage, grad_accum=grad_accum,
        zeros=zeros, ratios=ratios, ratio_dims=ratio_dims, max_tp=max_tp,
        ckpt_granularity=ckpt_granularity, ckpt_values=ckpt_values,
        kernel_grid=kernel_grid)
    res = IntraStageResult(layers=layers, n_devices=n_devices,
                           grad_accum=grad_accum, frontier=[],
                           n_evaluated=len(grid))
    if not len(grid):
        return res
    scm = scm or StageCostModel(cfg, seq_len, hw=hw, cp=cp,
                                has_embed=has_embed, has_head=has_head,
                                backend=backend or "numpy")
    # memory feasibility (Eq. 4) on the full grid first; runtime + the
    # interference model run only on the feasible survivors.  The kernel
    # VMEM legality (tile working set vs on-core memory) rides on the same
    # pass; the budget is floored at the default config's working set, so
    # with the default kernel grid the mask is identical to the HBM-only one.
    memout = scm.evaluate_memory(grid.env(layers=layers,
                                          grad_accum=grad_accum,
                                          inflight=inflight))
    mem = memout["mem_peak"]
    budget = scm.memory_budget()
    ok = (mem <= budget) & (memout["vmem_peak"] <= scm.vmem_budget_bytes)
    res.n_feasible = int(ok.sum())
    if not ok.any():
        return res
    feas = np.nonzero(ok)[0]
    sub = grid.take(feas)
    times = scm.evaluate_times(sub.env(layers=layers, grad_accum=grad_accum,
                                       inflight=inflight))
    t, d = times["t_stable"], times["d_delta"]
    sel = pareto_front_indices(t, d, max_points=max_front)
    front = [ParetoPoint(t=float(t[j]), d=float(d[j]),
                         mem=float(mem[feas[j]]),
                         cand=grid.candidate(int(feas[j])))
             for j in sel]
    if refine:
        front = pareto_front(
            refine_frontier(front, scm, layers=layers,
                            grad_accum=grad_accum, inflight=inflight,
                            budget=budget, ratio_dims=ratio_dims),
            max_points=max_front)
    res.frontier = front
    return res


def tune_stage_multi_g(cfg: ArchConfig, *, seq_len: int, layers: int,
                       n_devices: int, global_batch_per_stage: int,
                       grad_accums: Sequence[int],
                       has_embed: bool = True, has_head: bool = True,
                       inflight: float = 1.0,
                       hw: HardwareSpec = V5E, cp: CostParams = CostParams(),
                       zeros: Sequence[int] = (0, 1, 2, 3),
                       ratios: Sequence[float] = RATIO_GRID,
                       ratio_dims: Sequence[str] = ("oo", "ao"),
                       ckpt_granularity: int = 0,
                       ckpt_values: Optional[Sequence[int]] = None,
                       max_tp: Optional[int] = None,
                       max_front: int = 16,
                       scm: Optional[StageCostModel] = None,
                       refine: bool = True,
                       cached: bool = True,
                       backend: Optional[str] = None,
                       kernel_grid: Sequence[Tuple[int, int, int, int]]
                       = DEFAULT_KERNEL_GRID
                       ) -> Dict[int, "IntraStageResult"]:
    """G-collapsed `tune_stage`: sweep one stage hypothesis for ALL grad
    accumulation choices in a single pass (ROADMAP "collapse the G loop").

    The cost-model time tape is structurally G-independent (it never loads
    the G symbol — only b = batch/(dp*G) differs between the per-G grids),
    and the memory tape likewise, so the per-G grids are concatenated and
    evaluated in ONE substitution: one memory pass over the union, one
    runtime+interference pass over the feasible union rows, then per-G
    Pareto selection and one *batched-across-G* ratio refinement per
    descent iteration.  Every per-row computation is elementwise, so each
    G's slice is bitwise identical to what a standalone `tune_stage` call
    returns — asserted in tests/test_sweep.py.

    ``cached=True`` additionally consults the cost model's knob-tuple
    result cache, which collapses repeated identical sub-sweeps (e.g. the
    same-role middle stages of a deep pipeline differ only in ``inflight``,
    which the time tape never reads).  The cache is backend-agnostic: the
    jax backend's exact mode is bitwise identical to numpy, so cached
    rows are interchangeable regardless of which backend produced them.

    ``backend`` selects the tape backend when this call constructs the
    cost model (a caller-supplied ``scm`` brings its own); the memory
    union pass and the per-G runtime passes all run through it.
    """
    if ckpt_granularity <= 0:
        ckpt_granularity = max(1, layers // 8)
    scm = scm or StageCostModel(cfg, seq_len, hw=hw, cp=cp,
                                has_embed=has_embed, has_head=has_head,
                                backend=backend or "numpy")
    grids = {}
    results: Dict[int, IntraStageResult] = {}
    for G in grad_accums:
        grid = candidate_grid(
            cfg, n_devices=n_devices, layers=layers,
            global_batch=global_batch_per_stage, grad_accum=G,
            zeros=zeros, ratios=ratios, ratio_dims=ratio_dims, max_tp=max_tp,
            ckpt_granularity=ckpt_granularity, ckpt_values=ckpt_values,
            kernel_grid=kernel_grid)
        grids[G] = grid
        results[G] = IntraStageResult(layers=layers, n_devices=n_devices,
                                      grad_accum=G, frontier=[],
                                      n_evaluated=len(grid))
    live = [G for G in grad_accums if len(grids[G])]
    if not live:
        return results
    # structural cache-key prefix: these arguments determine every grid
    # column exactly (plus the feasible mask for the time envs below), so
    # no content hashing is needed for the knob-tuple cache
    skey = (cfg.name, layers, n_devices, global_batch_per_stage,
            tuple(zeros), tuple(ratios), tuple(ratio_dims),
            tuple(ckpt_values) if ckpt_values is not None else
            ("gran", ckpt_granularity), max_tp, tuple(kernel_grid))

    # ---- one memory pass over the union grid ------------------------------
    envs = {G: grids[G].env(layers=layers, grad_accum=G, inflight=inflight)
            for G in live}
    union = {}
    for k in envs[live[0]]:
        vals = [envs[G][k] for G in live]
        if all(np.ndim(v) == 0 for v in vals) and \
                len({float(v) for v in vals}) == 1:
            union[k] = vals[0]
        else:
            union[k] = np.concatenate(
                [np.broadcast_to(np.asarray(v, np.float64),
                                 (len(grids[G]),)) for v, G in
                 zip(vals, live)])
    offs = np.cumsum([0] + [len(grids[G]) for G in live])
    memout = scm.evaluate_memory(
        union, cache_key=(skey + (tuple(live), float(inflight))
                          if cached else None))
    mem = memout["mem_peak"]
    budget = scm.memory_budget()
    ok = (mem <= budget) & (memout["vmem_peak"] <= scm.vmem_budget_bytes)

    # ---- runtime on the feasible rows, per G (time tape results hit the
    # knob-tuple cache across same-role hypotheses differing only in
    # inflight — the time tape never reads it) ------------------------------
    feas_per_g = {}
    for j, G in enumerate(live):
        sl = slice(offs[j], offs[j + 1])
        ok_g = ok[sl]
        results[G].n_feasible = int(ok_g.sum())
        feas_per_g[G] = np.nonzero(ok_g)[0]
    live_t = [G for G in live if feas_per_g[G].size]
    if not live_t:
        return results

    # ---- per-G Pareto selection ------------------------------------------
    fronts: Dict[int, List[ParetoPoint]] = {}
    for G in live_t:
        feas = feas_per_g[G]
        base = offs[live.index(G)]
        sub = grids[G].take(feas)
        tkey = None
        if cached:
            fd = hashlib.blake2b(np.ascontiguousarray(feas).tobytes(),
                                 digest_size=16).digest()
            # the time tape reads neither G nor inflight: the key carries G
            # only through the b column's G-dependence (b = batch/(dp*G))
            tkey = skey + (G, fd)
        times = scm.evaluate_times(
            sub.env(layers=layers, grad_accum=G, inflight=inflight),
            cache_key=tkey)
        t, d = times["t_stable"], times["d_delta"]
        sel = pareto_front_indices(t, d, max_points=max_front)
        fronts[G] = [ParetoPoint(t=float(t[i]), d=float(d[i]),
                                 mem=float(mem[base + feas[i]]),
                                 cand=grids[G].candidate(int(feas[i])))
                     for i in sel]

    # ---- one batched-across-G refinement ----------------------------------
    if refine and ratio_dims:
        fronts = refine_frontier_grouped(
            fronts, scm, layers=layers, inflight=inflight, budget=budget,
            ratio_dims=ratio_dims)
        for G in fronts:
            fronts[G] = pareto_front(fronts[G], max_points=max_front)
    for G, front in fronts.items():
        results[G].frontier = front
    return results


def _tune_stage_legacy(cfg: ArchConfig, *, seq_len, layers, n_devices,
                       global_batch_per_stage, grad_accum, has_embed,
                       has_head, inflight, hw, cp, zeros, ratios, ratio_dims,
                       ckpt_granularity, ckpt_values, max_tp, max_front, scm,
                       refine, kernel_grid=DEFAULT_KERNEL_GRID
                       ) -> IntraStageResult:
    cands = list(enumerate_candidates(
        cfg, n_devices=n_devices, layers=layers,
        global_batch=global_batch_per_stage, grad_accum=grad_accum,
        zeros=zeros, ratios=ratios, ratio_dims=ratio_dims, max_tp=max_tp,
        ckpt_granularity=ckpt_granularity, ckpt_values=ckpt_values,
        kernel_grid=kernel_grid))
    res = IntraStageResult(layers=layers, n_devices=n_devices,
                           grad_accum=grad_accum, frontier=[],
                           n_evaluated=len(cands))
    if not cands:
        return res
    scm = scm or StageCostModel(cfg, seq_len, hw=hw, cp=cp,
                                has_embed=has_embed, has_head=has_head)
    env = scm.env_from_candidates(cands, layers=layers,
                                  grad_accum=grad_accum, inflight=inflight)
    out = scm.evaluate_recursive(env)
    budget = scm.memory_budget()
    # same recursive-walk discipline for the VMEM legality term
    vmem = np.asarray(scm.vmem_peak.evaluate(scm._env(env), {}), np.float64)
    ok = (out["mem_peak"] <= budget) \
        & (np.broadcast_to(vmem, out["mem_peak"].shape)
           <= scm.vmem_budget_bytes)
    res.n_feasible = int(ok.sum())
    if not ok.any():
        return res
    idx = np.nonzero(ok)[0]
    pts = [ParetoPoint(t=float(out["t_stable"][i]),
                       d=float(out["d_delta"][i]),
                       mem=float(out["mem_peak"][i]), cand=cands[i])
           for i in idx]
    front = pareto_front(pts, max_points=max_front)
    if refine:
        front = pareto_front(
            [refine_ratios(p, scm, layers=layers, grad_accum=grad_accum,
                           inflight=inflight, budget=budget,
                           ratio_dims=ratio_dims,
                           evaluate=scm.evaluate_recursive)
             for p in front],
            max_points=max_front)
    res.frontier = front
    return res


def refine_ratios(p: ParetoPoint, scm: StageCostModel, *, layers: int,
                  grad_accum: int, inflight: float, budget: float,
                  iters: int = 2,
                  ratio_dims: Sequence[str] = ALL_RATIO_DIMS,
                  evaluate: Optional[Callable] = None) -> ParetoPoint:
    """Coordinate descent on the offload ratios around a grid winner — the
    paper treats them as continuous floats (Table 2).  Only the dims the
    active search space actually sweeps (`ratio_dims`) are descended;
    descending the rest would silently escape the declared space (e.g. the
    `offload`/`mist` presets sweep only oo/ao)."""
    best = p
    step = (RATIO_GRID[1] - RATIO_GRID[0]) / 2.0
    evaluate = evaluate or scm.evaluate
    for _ in range(iters):
        cands = []
        for dim in ratio_dims:
            v = getattr(best.cand, dim)
            for nv in (v - step, v + step):
                if 0.0 <= nv <= 1.0:
                    cands.append(dataclasses.replace(best.cand, **{dim: nv}))
        if not cands:
            break
        env = scm.env_from_candidates(cands, layers=layers,
                                      grad_accum=grad_accum,
                                      inflight=inflight)
        out = evaluate(env)
        for i, c in enumerate(cands):
            if out["mem_peak"][i] > budget:
                continue
            q = ParetoPoint(t=float(out["t_stable"][i]),
                            d=float(out["d_delta"][i]),
                            mem=float(out["mem_peak"][i]), cand=c)
            # keep the step-time scalarization improving
            if (grad_accum * q.t + q.d) < (grad_accum * best.t + best.d):
                best = q
        step /= 2.0
    return best


def refine_frontier(front: Sequence[ParetoPoint], scm: StageCostModel, *,
                    layers: int, grad_accum: int, inflight: float,
                    budget: float, ratio_dims: Sequence[str],
                    iters: int = 2) -> List[ParetoPoint]:
    """Batched `refine_ratios` over a whole frontier: per descent iteration
    all points' neighbor candidates are evaluated in ONE substitution
    instead of one call per point.  The per-point greedy updates (same
    neighbor order, same strict-improvement rule) are preserved exactly, so
    the result matches the sequential refinement point for point."""
    best = list(front)
    if not best or not ratio_dims:
        return best
    step = (RATIO_GRID[1] - RATIO_GRID[0]) / 2.0
    for _ in range(iters):
        cands: List[Candidate] = []
        owner: List[int] = []
        for pi, p in enumerate(best):
            for dim in ratio_dims:
                v = getattr(p.cand, dim)
                for nv in (v - step, v + step):
                    if 0.0 <= nv <= 1.0:
                        cands.append(
                            dataclasses.replace(p.cand, **{dim: nv}))
                        owner.append(pi)
        if not cands:
            break
        env = scm.env_from_candidates(cands, layers=layers,
                                      grad_accum=grad_accum,
                                      inflight=inflight)
        out = scm.evaluate(env)
        for i, c in enumerate(cands):
            if out["mem_peak"][i] > budget:
                continue
            pi = owner[i]
            q = ParetoPoint(t=float(out["t_stable"][i]),
                            d=float(out["d_delta"][i]),
                            mem=float(out["mem_peak"][i]), cand=c)
            if (grad_accum * q.t + q.d) < (grad_accum * best[pi].t
                                           + best[pi].d):
                best[pi] = q
        step /= 2.0
    return best


def refine_fronts_batched(fronts: Dict, meta: Dict, scm: StageCostModel, *,
                          budget: float, ratio_dims: Sequence[str],
                          iters: int = 2) -> Dict:
    """`refine_frontier` batched across MANY stage hypotheses at once.

    ``fronts`` maps an arbitrary hashable key -> frontier points;
    ``meta`` maps the same keys -> (layers, inflight, G).  All hypotheses
    must share one cost model (same arch/seq/role) — L and inflight are
    bound as per-row columns, which the tapes broadcast exactly like the
    scalar binding, so every row's result is bitwise identical to the
    per-hypothesis `refine_frontier` call.  One tape + interference pass
    per descent iteration replaces one per (hypothesis, G).
    """
    best = {k: list(ps) for k, ps in fronts.items()}
    keys = [k for k in best if best[k]]
    if not keys or not ratio_dims:
        return best
    step = (RATIO_GRID[1] - RATIO_GRID[0]) / 2.0
    for _ in range(iters):
        cands: List[Candidate] = []
        owner: List[Tuple] = []
        lcol: List[float] = []
        icol: List[float] = []
        for k in keys:
            layers, inflight, _G = meta[k]
            for pi, p in enumerate(best[k]):
                for dim in ratio_dims:
                    v = getattr(p.cand, dim)
                    for nv in (v - step, v + step):
                        if 0.0 <= nv <= 1.0:
                            cands.append(
                                dataclasses.replace(p.cand, **{dim: nv}))
                            owner.append((k, pi))
                            lcol.append(float(layers))
                            icol.append(float(inflight))
        if not cands:
            break
        env = scm.env_from_candidates(cands, layers=0, grad_accum=0)
        L = np.asarray(lcol, np.float64)
        env["L"] = L
        env["inflight"] = np.asarray(icol, np.float64)
        env["ckpt"] = np.minimum(
            np.asarray([c.ckpt for c in cands], np.float64), L)
        out = scm.evaluate(env)
        for i, c in enumerate(cands):
            if out["mem_peak"][i] > budget:
                continue
            k, pi = owner[i]
            G = meta[k][2]
            q = ParetoPoint(t=float(out["t_stable"][i]),
                            d=float(out["d_delta"][i]),
                            mem=float(out["mem_peak"][i]), cand=c)
            if (G * q.t + q.d) < (G * best[k][pi].t + best[k][pi].d):
                best[k][pi] = q
        step /= 2.0
    return best


def refine_frontier_grouped(fronts: Dict[int, List[ParetoPoint]],
                            scm: StageCostModel, *, layers: int,
                            inflight: float, budget: float,
                            ratio_dims: Sequence[str],
                            iters: int = 2) -> Dict[int, List[ParetoPoint]]:
    """`refine_frontier` batched across the G axis of one hypothesis —
    the (layers, inflight)-constant specialization of
    `refine_fronts_batched` (per-row L/inflight binding is bitwise
    identical to the scalar binding, so delegating keeps each G's refined
    frontier identical to a standalone `refine_frontier` call)."""
    meta = {G: (layers, inflight, G) for G in fronts}
    return refine_fronts_batched(fronts, meta, scm, budget=budget,
                                 ratio_dims=ratio_dims, iters=iters)


def alpha_winners(result: IntraStageResult, n_alpha: int = 8
                  ) -> List[ParetoPoint]:
    """Paper Eq. 4: winners of  alpha*G*t + (1-alpha)*d  for uniform alpha
    samples — equivalently a re-sampling of the frontier; exposed for the
    breakdown benchmark."""
    G = result.grad_accum
    out = []
    for a in np.linspace(0.0, 1.0, n_alpha):
        best = min(result.frontier,
                   key=lambda p: a * G * p.t + (1 - a) * p.d,
                   default=None)
        if best is not None and best not in out:
            out.append(best)
    return out
