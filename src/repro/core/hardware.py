"""TPU hardware model (v5e-class target; the container is CPU-only, so these
constants drive the cost model and the roofline denominators)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    hbm_bytes: float = 16 * 2**30        # capacity per chip
    ici_bw: float = 50e9                 # bytes/s per link (spec-given)
    ici_links: int = 2                   # usable links per chip (conservative)
    dci_bw: float = 6.25e9               # inter-pod (pod axis) per chip
    host_bw: float = 25e9                # host<->HBM per chip (offload path)
    mxu_min_dim: int = 128               # MXU tile alignment
    vmem_bytes: float = 16 * 2**20       # on-core vector memory (per core)

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw * self.ici_links


V5E = HardwareSpec()
