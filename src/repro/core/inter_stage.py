"""Inter-stage tuning: imbalance-aware MILP over Pareto-sampled stage
candidates (paper §5.3, Eq. 1-3).

Objective (Eq. 1), for S stages with stable microbatch times t_i and
first/last-microbatch deltas d_i:

    min  (G - 1) * max_i t_i  +  sum_i t_i  +  max_i (d_i - sum_{j<i} t_j)

 - term 1: pipeline steady state is paced by the bottleneck stage;
 - term 2: pipeline fill/drain (inter-stage imbalance);
 - term 3: inter-MICROBATCH imbalance — the extra work of the first/last
   microbatch counts only where it cannot hide inside the fill bubble
   (sum_{j<i} t_j is stage i's fill slack), Mist's key modeling insight.

Linearization: one-hot x[i,c] over per-stage candidates (layers l_c,
devices n_c, Pareto point (t_c, d_c)); epigraph variables T >= t_i and
D >= d_i - sum_{j<i} t_j make the max terms linear.  Solved with
scipy.optimize.milp (HiGHS; the paper uses CBC).  `solve_exact` is a
brute-force cross-check used by the property tests, and
`simulate_pipeline` is an event-driven 1F1B-style simulator validating the
objective itself.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.intra_stage import ParetoPoint


@dataclass(frozen=True)
class StageCand:
    """One admissible (layers, devices, Pareto point) tuple for a stage."""
    layers: int
    n_devices: int
    t: float
    d: float
    point: Optional[ParetoPoint] = None


def pipeline_objective(ts: Sequence[float], ds: Sequence[float], G: int
                       ) -> float:
    """Paper Eq. 1."""
    ts, ds = list(ts), list(ds)
    fill = [sum(ts[:i]) for i in range(len(ts))]
    return ((G - 1) * max(ts) + sum(ts)
            + max(d - f for d, f in zip(ds, fill)))


def simulate_pipeline(ts: Sequence[float], ds: Sequence[float], G: int,
                      ) -> float:
    """Event-driven GPipe-style makespan with the first/last-microbatch
    extra work attached to each stage (validates Eq. 1; property-tested).

    Each microbatch occupies stage i for t_i (stable) except the first and
    last, which take t_i + first_i / t_i + last_i; we split d_i evenly
    between them (the schedule overlaps both ends symmetrically).
    """
    S = len(ts)
    ready = [0.0] * S      # stage free time
    done = [0.0] * G       # microbatch m leaves stage i
    for i in range(S):
        for m in range(G):
            dur = ts[i]
            if m == 0 or m == G - 1:
                dur = dur + ds[i] / (2.0 if G > 1 else 1.0)
                if G == 1 and m == 0:
                    dur = ts[i] + ds[i]
            start = max(ready[i], done[m])
            ready[i] = start + dur
            done[m] = start + dur
    return max(done)


# ---------------------------------------------------------------------------
# MILP
# ---------------------------------------------------------------------------


@dataclass
class InterStageSolution:
    objective: float
    selection: List[StageCand]       # one per stage
    status: str = "optimal"

    @property
    def ts(self) -> List[float]:
        return [c.t for c in self.selection]

    @property
    def ds(self) -> List[float]:
        return [c.d for c in self.selection]


def solve_milp(cands: Sequence[Sequence[StageCand]], *, total_layers: int,
               total_devices: int, G: int,
               time_limit: float = 30.0) -> Optional[InterStageSolution]:
    """cands[i] = admissible candidates for stage i (from the intra-stage
    Pareto frontiers).  Returns None if infeasible."""
    from scipy.optimize import Bounds, LinearConstraint, milp

    S = len(cands)
    sizes = [len(cs) for cs in cands]
    if any(sz == 0 for sz in sizes):
        return None
    nx = sum(sizes)
    off = np.cumsum([0] + sizes[:-1])
    iT, iD = nx, nx + 1
    nvar = nx + 2

    t_big = max(max(c.t for c in cs) for cs in cands)
    d_big = max(max(c.d for c in cs) for cs in cands)

    cobj = np.zeros(nvar)
    for i, cs in enumerate(cands):
        for j, c in enumerate(cs):
            cobj[off[i] + j] = c.t          # sum_i t_i
    cobj[iT] = G - 1
    cobj[iD] = 1.0

    A, lb, ub = [], [], []

    # one-hot per stage
    for i in range(S):
        row = np.zeros(nvar)
        row[off[i]:off[i] + sizes[i]] = 1.0
        A.append(row); lb.append(1.0); ub.append(1.0)

    # layer + device budgets
    row_l = np.zeros(nvar)
    row_n = np.zeros(nvar)
    for i, cs in enumerate(cands):
        for j, c in enumerate(cs):
            row_l[off[i] + j] = c.layers
            row_n[off[i] + j] = c.n_devices
    A.append(row_l); lb.append(total_layers); ub.append(total_layers)
    A.append(row_n); lb.append(total_devices); ub.append(total_devices)

    # T >= t_i  <=>  T - sum_c x[i,c] t_c >= 0
    for i, cs in enumerate(cands):
        row = np.zeros(nvar)
        row[iT] = 1.0
        for j, c in enumerate(cs):
            row[off[i] + j] = -c.t
        A.append(row); lb.append(0.0); ub.append(np.inf)

    # D >= d_i - sum_{j<i} t_j
    #  <=> D - sum_c x[i,c] d_c + sum_{j<i} sum_c x[j,c] t_c >= 0
    for i, cs in enumerate(cands):
        row = np.zeros(nvar)
        row[iD] = 1.0
        for j, c in enumerate(cs):
            row[off[i] + j] = -c.d
        for jj in range(i):
            for j, c in enumerate(cands[jj]):
                row[off[jj] + j] += c.t
        A.append(row); lb.append(0.0); ub.append(np.inf)

    integrality = np.zeros(nvar)
    integrality[:nx] = 1
    bounds = Bounds(np.concatenate([np.zeros(nx), [0.0, -d_big - 1.0]]),
                    np.concatenate([np.ones(nx), [t_big * S + 1.0,
                                                  d_big + 1.0]]))
    res = milp(c=cobj,
               constraints=LinearConstraint(np.asarray(A), np.asarray(lb),
                                            np.asarray(ub)),
               integrality=integrality, bounds=bounds,
               options={"time_limit": time_limit})
    if not res.success:
        return None
    sel = []
    for i, cs in enumerate(cands):
        xi = res.x[off[i]:off[i] + sizes[i]]
        sel.append(cs[int(np.argmax(xi))])
    obj = pipeline_objective([c.t for c in sel], [c.d for c in sel], G)
    return InterStageSolution(objective=obj, selection=sel)


def solve_exact(cands: Sequence[Sequence[StageCand]], *, total_layers: int,
                total_devices: int, G: int) -> Optional[InterStageSolution]:
    """Brute-force enumeration (exponential; property-test cross-check)."""
    best: Optional[InterStageSolution] = None
    for combo in itertools.product(*cands):
        if sum(c.layers for c in combo) != total_layers:
            continue
        if sum(c.n_devices for c in combo) != total_devices:
            continue
        obj = pipeline_objective([c.t for c in combo],
                                 [c.d for c in combo], G)
        if best is None or obj < best.objective - 1e-12:
            best = InterStageSolution(objective=obj, selection=list(combo),
                                      status="exact")
    return best
