"""Batched interference estimation — paper Algorithm 1, ported verbatim.

Four channels run concurrently on a chip: MXU compute (C), ICI collectives
(G2G), device->host DMA (D2H), host->device DMA (H2D).  Each combination of
co-running channels has slowdown factors; the algorithm progressively
resolves the overlap from 4-way concurrency down to 2-way, then adds the
serial remainder.

Vectorized over a leading batch of configurations (numpy arrays in, array
out), which is what makes Mist's brute-force intra-stage sweep cheap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

CHANNELS = ("C", "G2G", "D2H", "H2D")

# default slowdown factors per co-running combination, literature-informed
# (compute slows mildly under concurrent DMA/collectives; the two PCIe/DMA
# directions contend more strongly with each other).  ``calibrate`` refits
# these from measurements on real hardware.
_DEFAULT = {
    # 4-way
    (0, 1, 2, 3): (1.25, 1.30, 1.45, 1.45),
    # 3-way
    (0, 1, 2): (1.15, 1.20, 1.30),
    (0, 1, 3): (1.15, 1.20, 1.30),
    (0, 2, 3): (1.10, 1.35, 1.35),
    (1, 2, 3): (1.15, 1.35, 1.35),
    # 2-way
    (0, 1): (1.08, 1.12),
    (0, 2): (1.05, 1.15),
    (0, 3): (1.05, 1.15),
    (1, 2): (1.08, 1.20),
    (1, 3): (1.08, 1.20),
    (2, 3): (1.30, 1.30),
}


# packed-activity weights: channel i active contributes 1 << i, so a row's
# co-running pattern is one small int compared against each combo's code
_CODE_W = np.array([1, 2, 4, 8], np.int64)


@dataclass
class InterferenceModel:
    factors: Dict[Tuple[int, ...], Tuple[float, ...]] = field(
        default_factory=lambda: dict(_DEFAULT))

    def predict(self, c, g2g, d2h, h2d) -> np.ndarray:
        """Algorithm 1 (PredINTF): total latency of four concurrent streams.

        Inputs broadcastable arrays of per-channel serial times (e.g. the
        per-phase channel totals a compiled cost-model tape produces);
        returns the overlapped wall time per element.
        """
        x = np.stack(np.broadcast_arrays(
            np.asarray(c, np.float64), np.asarray(g2g, np.float64),
            np.asarray(d2h, np.float64), np.asarray(h2d, np.float64)), -1)
        return self.predict_stacked(x)

    def _tables(self):
        """Factor lookup tables indexed by packed activity code: per-channel
        slowdown (1.0 outside the combo), in-combo mask, and a validity bit
        for codes that have a factor set.  Rebuilt whenever the factor
        contents change — keyed on the dict's items, so both replacing the
        dict (calibrate) and mutating entries in place are detected."""
        src = tuple(self.factors.items())
        if getattr(self, "_tab_src", None) != src:
            F = np.ones((16, 4), np.float64)
            M = np.zeros((16, 4), bool)
            V = np.zeros(16, bool)
            for combo, fac in self.factors.items():
                if len(combo) < 2:      # Alg. 1 resolves levels 4..2 only;
                    continue            # a lone stream is never scaled
                code = int(_CODE_W[list(combo)].sum())
                F[code, list(combo)] = fac
                M[code, list(combo)] = True
                V[code] = True
            self._tab_src, self._tab = src, (F, M, V)
        return self._tab

    def predict_stacked(self, x: np.ndarray) -> np.ndarray:
        """Batched Alg. 1 on a pre-stacked (..., 4) channel array.

        Level-synchronous formulation: each pass resolves every row's
        current co-running combination at once (factor vectors gathered by
        the row's packed activity code), and resolving always deactivates
        the shortest stream, so three passes reach 2-way or done.  The
        per-row arithmetic is exactly the reference per-combo formulation,
        hence results are bitwise identical.
        """
        lead = x.shape[:-1]
        x = np.ascontiguousarray(x, np.float64).reshape(-1, 4)
        F, M, V = self._tables()
        t = np.zeros(x.shape[0], np.float64)
        for _ in range(3):                      # 4-way -> 3-way -> 2-way
            code = (x > 1e-12) @ _CODE_W
            valid = V[code]
            if not valid.any():
                break
            f = F[code]
            m = M[code]
            scaled = np.where(m, x * f, np.inf)
            overlap = np.where(valid, scaled.min(-1), 0.0)
            rem = np.where(m, (scaled - overlap[:, None]) / f, x)
            x = np.where(valid[:, None], rem, x)
            t += overlap
        return (t + x.sum(-1)).reshape(lead)

    def predict_reference(self, c, g2g, d2h, h2d) -> np.ndarray:
        """The pre-refactor per-combination mask formulation, kept verbatim
        as the legacy-engine baseline (benchmarks/tuning_time.py measures
        the compiled engine against it).  Bitwise identical to `predict`
        — `tests/test_interference.py` asserts it."""
        import itertools
        x = np.stack(np.broadcast_arrays(
            np.asarray(c, np.float64), np.asarray(g2g, np.float64),
            np.asarray(d2h, np.float64), np.asarray(h2d, np.float64)), -1)
        x = x.copy()
        t = np.zeros(x.shape[:-1], np.float64)
        for n in range(4, 1, -1):                      # concurrency level
            for combo in itertools.combinations(range(4), n):
                fac = self.factors.get(combo)
                if fac is None:          # partial factor sets: no overlap
                    continue             # data at this level -> resolved
                mask = np.zeros(4, bool)  # pairwise (or serially) later
                mask[list(combo)] = True
                factors = np.asarray(fac, np.float64)
                self._update(x, t, mask, factors, combo)
        t += x.sum(-1)                                 # serial remainder
        return t

    @staticmethod
    def _update(x, t, mask, factors, combo):
        active = x > 1e-12
        ids = (active == mask).all(-1)                 # rows matching combo
        if not ids.any():
            return
        scaled = x[ids][:, list(combo)] * factors
        overlap = scaled.min(-1)
        rem = (scaled - overlap[:, None]) / factors
        xi = x[ids]
        xi[:, list(combo)] = rem
        x[ids] = xi
        t[ids] += overlap

    # -- data-driven fitting --------------------------------------------------
    def calibrate(self, samples) -> float:
        """Fit slowdown factors from measured (times, wall) pairs.

        samples: list of ((c, g2g, d2h, h2d), measured_wall).  Returns the
        post-fit mean relative error.  Minimizes squared wall error with
        Nelder-Mead over the factor offsets (scipy when available, a pure
        numpy simplex otherwise — the calibration subsystem must run in
        environments without scipy)."""
        keys = sorted(self.factors)
        sizes = [len(self.factors[k]) for k in keys]

        def loss(theta):
            m = InterferenceModel(factors={
                k: tuple(1.0 + max(v, 0.0) for v in theta[i:i + n])
                for (k, n, i) in zip(keys, sizes,
                                     np.cumsum([0] + sizes[:-1]))})
            err = 0.0
            for (ch, wall) in samples:
                pred = m.predict(*ch)
                err += float((pred - wall) ** 2)
            return err

        x0 = np.concatenate([np.asarray(self.factors[k]) - 1.0 for k in keys])
        th = _minimize_simplex(loss, x0, maxiter=2000, fatol=1e-12)
        offs = np.cumsum([0] + sizes[:-1])
        self.factors = {
            k: tuple(1.0 + max(v, 0.0) for v in th[i:i + n])
            for (k, n, i) in zip(keys, sizes, offs)}
        rel = []
        for (ch, wall) in samples:
            pred = float(self.predict(*ch))
            rel.append(abs(pred - wall) / max(wall, 1e-12))
        return float(np.mean(rel))


def _scipy_minimize(loss, x0, *, maxiter, fatol) -> np.ndarray:
    import scipy.optimize as so

    res = so.minimize(loss, x0, method="Nelder-Mead",
                      options={"maxiter": maxiter, "fatol": fatol})
    return np.asarray(res.x, np.float64)


def _minimize_simplex(loss, x0, *, maxiter=2000, fatol=1e-12) -> np.ndarray:
    """Nelder-Mead with graceful degradation: scipy's implementation when
    installed, else the pure-numpy fallback below (same initial simplex
    convention, so the two paths converge to comparable minima)."""
    try:
        return _scipy_minimize(loss, x0, maxiter=maxiter, fatol=fatol)
    except ImportError:
        return _nelder_mead(loss, x0, maxiter=maxiter, fatol=fatol)


def _nelder_mead(loss, x0, *, maxiter=2000, fatol=1e-12) -> np.ndarray:
    """Compact downhill-simplex (Nelder & Mead 1965) — standard reflection /
    expansion / contraction / shrink coefficients and scipy's initial-simplex
    construction (each vertex perturbs one coordinate by 5%, or 0.00025 for
    zero coordinates)."""
    x0 = np.asarray(x0, np.float64)
    n = x0.size
    simplex = np.tile(x0, (n + 1, 1))
    for i in range(n):
        if simplex[i + 1, i] != 0.0:
            simplex[i + 1, i] *= 1.05
        else:
            simplex[i + 1, i] = 0.00025
    f = np.array([loss(v) for v in simplex])
    for _ in range(maxiter):
        order = np.argsort(f, kind="stable")
        simplex, f = simplex[order], f[order]
        if abs(f[-1] - f[0]) <= fatol:
            break
        centroid = simplex[:-1].mean(0)
        xr = centroid + (centroid - simplex[-1])           # reflect
        fr = loss(xr)
        if fr < f[0]:
            xe = centroid + 2.0 * (centroid - simplex[-1])  # expand
            fe = loss(xe)
            simplex[-1], f[-1] = (xe, fe) if fe < fr else (xr, fr)
        elif fr < f[-2]:
            simplex[-1], f[-1] = xr, fr
        else:
            xc = centroid + 0.5 * (simplex[-1] - centroid)  # contract
            fc = loss(xc)
            if fc < f[-1]:
                simplex[-1], f[-1] = xc, fc
            else:                                           # shrink
                simplex[1:] = simplex[0] + 0.5 * (simplex[1:] - simplex[0])
                f[1:] = [loss(v) for v in simplex[1:]]
    return simplex[int(np.argmin(f))]


DEFAULT_MODEL = InterferenceModel()


def pred_intf(c, g2g, d2h, h2d, model: Optional[InterferenceModel] = None
              ) -> np.ndarray:
    return (model or DEFAULT_MODEL).predict(c, g2g, d2h, h2d)
