"""Symbolic cost model: runtime + peak memory of one pipeline stage as
symbolic expressions over the optimization variables (paper §5.2).

The model is built ONCE per (arch, seq_len, stage-role); evaluating a batch
of N candidate configurations is a vectorized numpy substitution into the
expression DAG (`core/symbolic.py`) followed by the batched interference
model (`core/interference.py`, paper Alg. 1) — this is what makes Mist's
brute-force intra-stage sweep cheap (paper reports >1e5x vs per-config
simulation; see benchmarks/tuning_time.py for ours).

Symbols (per stage i, paper Table 2):
    b, dp, tp          parallelism
    L                  layers in this stage
    G                  gradient accumulation steps
    ckpt               number of recomputed layers (0..L)
    z1, z2, z3         ZeRO level indicators (z1 >= z2 >= z3, 0/1 floats)
    wo, go, oo, ao     offload ratios [0,1]
    inflight           live microbatches at peak (1F1B: S - stage_idx)

Outputs (numpy arrays over the candidate batch):
    mem_fwd, mem_bwd   peak bytes per device during fwd / bwd
    t_stable           stable-microbatch wall time (Eq. 5)
    d_delta            first+last microbatch extra time (Eq. 6)
    t_step             full-step estimate for S=1: G*t + d + const
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import symbolic as S
from repro.core.costmodel_params import (KERNEL_SYMBOLIC_OPS, KernelCoeffs,
                                         kernel_time_terms,
                                         kernel_vmem_terms, mxu_efficiency,
                                         ssd_dims)
from repro.core.hardware import V5E, HardwareSpec
from repro.core.interference import InterferenceModel, pred_intf
from repro.core.plan import DEFAULT_KERNEL_CONFIG
from repro.core.schedule import OVERLAP_SCHEDULE, Candidate, PhaseTraffic
from repro.core.symbolic import (Expr, Sym, ceil_div, rint, smax, smin,
                                 where, wrap)
# the shared state-layout derivation (spec-exact shard counts + integer
# host splits) — jax-free to import; see repro/lowering/state_layout.py
from repro.lowering.state_layout import symbolic_state_terms


# ---------------------------------------------------------------------------
# Tunable constants (calibratable; literature-informed defaults)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostParams:
    mxu_eff_peak: float = 0.75       # best-case MXU efficiency of big matmuls
    mxu_eff_floor: float = 0.08
    mxu_sat_tokens: float = 1024.0   # tokens/device at which eff saturates
    vpu_tax: float = 0.12            # non-matmul compute as a fraction of dot time
    ici_eff: float = 0.85            # achievable fraction of link bandwidth
    host_eff: float = 0.90           # achievable fraction of host DMA bw
    coll_latency_us: float = 12.0    # per-collective launch latency
    mem_headroom: float = 0.92       # usable fraction of HBM
    # XLA runtime + fragmentation.  The default was cross-checked against
    # real allocator stats (compiled-executable peak minus the modeled
    # terms) by ``tools/calibrate_reserved.py`` on a reduced golden cell;
    # re-run that tool on a real accelerator host to refit it there.
    # Predictor and memory_report both read THIS field, so the
    # predicted-vs-lowered cross-check is independent of its value.
    runtime_reserved: float = 0.75 * 2**30
    # serving (docs/serving.md): decode-step working-set envelope and the
    # decode roofline's MXU efficiency (GEMV-shaped matmuls run far below
    # the big-matmul peak)
    serve_decode_transient: float = 0.3 * 2**30
    decode_mxu_eff: float = 0.30
    # paged-KV serving (docs/continuous-batching.md): expected request
    # fill fraction of the decode horizon under a mixed-length trace
    # (drives the occupancy-aware page-size objective) and the strided
    # page-gather penalty in rows (smaller pages touch more, shorter,
    # HBM bursts)
    serve_page_fill: float = 0.5
    serve_page_stride_rows: float = 4.0
    # per-kernel roofline coefficients (the kernel-config plan dimension);
    # calibratable from kernels.autotune bench measurements
    kernels: KernelCoeffs = KernelCoeffs()


# ---------------------------------------------------------------------------
# Analytic per-arch constants (derived from abstract param shapes — exact)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchStats:
    n_layer: float            # params per (stacked) backbone layer
    n_layer_active: float     # ... counting only routed-active experts (MoE)
    n_shared: float           # shared-block params (Zamba2) applied repeatedly
    shared_apps_per_layer: float  # shared-block applications per backbone layer
    n_embed: float            # embedding (+ head + final norm) params
    attn_layers_frac: float   # fraction of layers with full attention
    d_model: int
    d_ff: int
    num_heads: int
    kv_heads: int
    head_dim: int
    vocab: int
    act_coef_full: float      # saved-act bytes per token per layer / d_model (no remat)
    act_coef_ckpt: float      # ... for a rematerialized layer (boundary only)
    flops_token_layer: float  # non-attention matmul flops per token per layer (fwd)
    attn_flops_coef: float    # attention score+pv flops per token per layer = c*s


def _sum_params(tree: Dict[str, Any]) -> float:
    return float(sum(math.prod(v.shape) for v in tree.values()))


def arch_stats(cfg: ArchConfig) -> ArchStats:
    from repro.models.zoo import abstract_params

    params, _ = abstract_params(cfg)
    layer_tot = 0.0
    shared_tot = 0.0
    embed_tot = 0.0
    lead_divisor = None
    for name, sds in params.items():
        n = math.prod(sds.shape)
        if name.startswith(("layers/", "backbone/", "encoder/", "decoder/")):
            layer_tot += n
        elif name.startswith(("shared/", "shared_attn/")):
            shared_tot += n
        else:
            embed_tot += n
    # stacked leading dims: L or (groups, per-group)
    L = cfg.num_layers
    n_layer = layer_tot / max(1, L)

    # MoE: active = layer minus inactive routed experts
    n_layer_active = n_layer
    if cfg.num_experts:
        per_expert = (3 if cfg.mlp_gated else 2) * cfg.d_model * cfg.moe_d_ff
        n_layer_active = n_layer - (cfg.num_experts
                                    - cfg.num_experts_per_tok) * per_expert

    shared_apps = (1.0 / cfg.shared_attn_every if cfg.shared_attn_every
                   else 0.0)
    if cfg.family == "hybrid":
        attn_frac = shared_apps
    elif cfg.family == "ssm":
        attn_frac = 0.0
    elif cfg.family == "audio":
        attn_frac = 1.0          # self+cross handled via flops coef below
    else:
        attn_frac = 1.0

    d, dff = cfg.d_model, (cfg.moe_d_ff if cfg.num_experts else cfg.d_ff)
    hd = cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads

    # --- saved activations per token per layer (bf16, units of d_model) ----
    # attention: norm-in(1) + q(H*hd/d) + k,v(2*KV*hd/d) + attn-out(H*hd/d)
    #            + norm-in(1) + gate/up(2*dff*topk_eff/d) + down-in(dff*topk/d)
    # flash/blocked attention saves only O(1) softmax stats (ignored).
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        topk = (cfg.num_experts_per_tok + cfg.num_shared_experts
                if cfg.num_experts else 1)
        mlp_units = (3 if cfg.mlp_gated else 2) * dff * topk / d
        attn_units = 2 + 2 * (H * hd) / d + 2 * (KV * hd) / d
        act_full = attn_units + 1 + mlp_units
    elif cfg.family == "hybrid":
        dinner = cfg.ssm_expand * d
        act_full = 2 + 2 * (2 * dinner + 2 * cfg.ssm_groups
                            * cfg.ssm_state) / d
        act_full += shared_apps * (4 + (3 * cfg.d_ff) / d)
    else:  # ssm / xlstm
        dinner = cfg.ssm_expand * d
        act_full = 2 + 2 * (2 * dinner) / d + (2 * cfg.ssm_groups
                                               * cfg.ssm_state) / d
    act_ckpt = 1.0  # layer boundary (residual stream) only

    # --- fwd matmul flops per token per layer (2*active params works) ------
    flops_tok = 2.0 * n_layer_active
    if cfg.family == "hybrid":
        flops_tok = 2.0 * (n_layer_active + shared_apps * shared_tot)

    # attention O(s) term per token per layer: QK^T + PV, causal halves it:
    # 2 matmuls * 2 flops * H * hd * (s/2) = 2*H*hd*s per token
    attn_coef = 2.0 * H * hd * attn_frac
    if cfg.family == "audio":
        # decoder self (causal) + cross-attn to encoder_seq + encoder self
        attn_coef = 2.0 * H * hd * 2.0

    return ArchStats(
        n_layer=n_layer, n_layer_active=n_layer_active, n_shared=shared_tot,
        shared_apps_per_layer=shared_apps, n_embed=embed_tot,
        attn_layers_frac=attn_frac, d_model=d, d_ff=dff, num_heads=H,
        kv_heads=KV, head_dim=hd, vocab=cfg.vocab_size,
        act_coef_full=act_full, act_coef_ckpt=act_ckpt,
        flops_token_layer=flops_tok, attn_flops_coef=attn_coef,
    )


# ---------------------------------------------------------------------------
# The stage cost model
# ---------------------------------------------------------------------------

SYMS = ("b", "dp", "tp", "L", "G", "ckpt", "z1", "z2", "z3",
        "wo", "go", "oo", "ao", "inflight",
        "qb", "kvb", "rnb", "sch")

BACKENDS = ("numpy", "jax", "auto")

# "auto" switches a tape run to jax at this many grid rows — the measured
# numpy/jax crossover on a 2-core CPU host (XLA multithreads the
# elementwise kernels, numpy does not; accelerators cross over far
# earlier).  Instance attribute so tests/benchmarks can lower it.
JAX_AUTO_THRESHOLD = 1 << 19


class StageCostModel:
    """Symbolic runtime + memory for one pipeline stage of `cfg` at `seq`.

    ``backend`` selects how compiled tapes execute:

      * ``"numpy"`` (default) — the in-process numpy instruction loop with
        scratch-buffer reuse.
      * ``"jax"`` — ``Tape.lower_jax()`` exact mode: per-instruction jax
        ops on device arrays, bitwise identical to numpy (the
        plan-identity guarantee in tests/test_tape_backends.py).  Runs
        jax only where that guarantee actually holds — x64 enabled and
        the tape free of non-correctly-rounded ops — and silently
        degrades to numpy otherwise or when jax is missing entirely
        (``repro.compat`` gates it).
      * ``"auto"`` — like "jax", but additionally stays on numpy below
        ``jax_auto_threshold`` grid rows.

    Downstream consumers (interference model, Pareto selection) always
    see numpy float64 arrays regardless of backend.
    """

    def __init__(self, cfg: ArchConfig, seq_len: int, *,
                 hw: HardwareSpec = V5E, cp: CostParams = CostParams(),
                 has_embed: bool = True, has_head: bool = True,
                 interference: Optional[InterferenceModel] = None,
                 sequence_parallel: bool = True,
                 backend: str = "numpy", profile=None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
        # ``profile`` is a calibration.CalibrationProfile: fitted per-platform
        # constants layered over the caller's cp/interference (an explicit
        # ``interference=`` argument wins over the profile's).  The default
        # profile carries no overrides, so passing it changes nothing — the
        # frozen-default guarantee the golden fixtures rely on.
        if profile is not None:
            cp = profile.cost_params(cp)
            if interference is None:
                interference = profile.interference_model()
        self.cfg, self.seq, self.hw, self.cp = cfg, seq_len, hw, cp
        self.has_embed, self.has_head = has_embed, has_head
        self.intf = interference or InterferenceModel()
        self.st = arch_stats(cfg)
        self.sp = sequence_parallel
        self.backend = backend
        self.jax_auto_threshold = JAX_AUTO_THRESHOLD
        if profile is not None and profile.jax_auto_threshold is not None:
            self.jax_auto_threshold = int(profile.jax_auto_threshold)
        self.last_backend = "numpy"     # backend of the most recent tape run
        self._build()

    # -- expression construction ---------------------------------------------
    def _build(self):
        st, seq, hw, cp = self.st, self.seq, self.hw, self.cp
        b, dp, tp = Sym("b"), Sym("dp"), Sym("tp")
        L, G, ckpt = Sym("L"), Sym("G"), Sym("ckpt")
        z1, z2, z3 = Sym("z1"), Sym("z2"), Sym("z3")
        wo, go, oo, ao = Sym("wo"), Sym("go"), Sym("oo"), Sym("ao")
        inflight = Sym("inflight")

        # ---- parameter byte counts (per device) ----------------------------
        # Memory charges state SPEC-EXACTLY via the shared state-layout
        # module: per tensor group, the shard count its PartitionSpec
        # implies (indivisible dims replicate at full size) and the
        # runtime's integer WO/OO host splits on stacked entries only —
        # the same derivation LoweredPlan.memory_report() evaluates
        # concretely, so predicted and lowered bytes agree bitwise.
        lay = symbolic_state_terms(self.cfg, has_embed=self.has_embed,
                                   has_head=self.has_head)
        states = lay["weight"] + lay["grad"] + lay["master"] + lay["opt"]
        # The *time* terms below keep the idealized uniform division:
        # collective/DMA message sizes are bandwidth estimates calibrated
        # as a whole (CostParams), not bytes the runtime must hold.
        n_stage = st.n_layer * L + st.n_shared \
            + (st.n_embed if (self.has_embed or self.has_head) else 0.0)
        n_tp = n_stage / tp                      # TP shards ~everything
        g_bytes = 4.0 * n_tp / where(z2, dp, 1.0) * (1.0 - go)  # f32 accum

        # ---- activations ----------------------------------------------------
        sp_div = tp if self.sp else wrap(1.0)
        tok = b * seq
        act_full_l = 2.0 * st.act_coef_full * st.d_model * tok / sp_div
        act_ckpt_l = 2.0 * st.act_coef_ckpt * st.d_model * tok / sp_div
        ck = smin(ckpt, L)
        # AO offloads an INTEGER layer count, exactly the lowering's
        # ExecConfig.offload_layers = round(ao * ckpt_layers)
        off = rint(ao * ck)
        acts_mb = (ck - off) * act_ckpt_l + (L - ck) * act_full_l
        acts = acts_mb * inflight
        host_acts = off * act_ckpt_l * inflight

        # transient working set: one layer's full intermediates during
        # (re)compute + gathered zero-3 params for ~2 layers + attn scratch
        trans = 2.0 * act_full_l + z3 * 2.0 * (2.0 * st.n_layer / tp)
        trans = trans + 2.0 * act_ckpt_l * inflight  # bwd boundary grads
        logits = (2.0 * b * min(512, seq) * st.vocab * 4.0 / tp
                  if self.has_head else wrap(0.0))

        self.mem_fwd: Expr = states + acts + trans + logits + cp.runtime_reserved
        self.mem_bwd: Expr = states + acts + trans + logits \
            + act_full_l + cp.runtime_reserved  # recompute scratch in bwd
        # per-term peak-memory breakdown (bwd side == the peak, since bwd
        # only adds the recompute scratch): evaluated by estimate_plan /
        # memory_consistency so predicted-vs-lowered disagreement is
        # attributable to a term, not just a total
        self.mem_terms: Dict[str, Expr] = {
            "state": states, "act": acts,
            "transient": trans + act_full_l, "logits": wrap(logits),
            "host_state": lay["host"], "host_act": host_acts,
        }

        # ---- kernel VMEM working set (grid legality, not HBM peak) ----------
        # Tiles must fit on-core VMEM; the budget is floored at the default
        # config's own working set so the default tiles are feasible by
        # construction (they are today's behaviour) and the mask can only
        # prune configs strictly larger than both the budget and the default.
        self.vmem_peak: Expr = self._kernel_vmem(
            Sym("qb"), Sym("kvb"), Sym("rnb"), Sym("sch"))
        vmem_default = self._kernel_vmem(
            *(float(v) for v in DEFAULT_KERNEL_CONFIG.astuple()),
            concrete=True)
        self.vmem_budget_bytes: float = max(float(hw.vmem_bytes),
                                            float(vmem_default))

        # ---- compute times (per microbatch, this stage) ---------------------
        flops_fwd = (st.flops_token_layer * L
                     + st.attn_flops_coef * seq * L) * tok / tp
        if self.has_embed or self.has_head:
            flops_fwd = flops_fwd + 2.0 * st.n_embed * tok / tp
        # MXU efficiency: saturating in per-device tokens — the shared
        # formula (costmodel_params.mxu_efficiency), also exposed concretely
        # via the public ``mxu_efficiency`` method so external consumers
        # (benchmarks/accuracy.py) cannot drift from the tape's arithmetic
        eff = mxu_efficiency(tok, eff_peak=cp.mxu_eff_peak,
                             eff_floor=cp.mxu_eff_floor,
                             sat_tokens=cp.mxu_sat_tokens)
        t_fwd = flops_fwd * (1.0 + cp.vpu_tax) / (hw.peak_flops_bf16 * eff)

        # ---- kernel-config roofline delta (tile/block knobs) ----------------
        # The kernel dimension is priced as a DELTA against the default
        # config: the same shared formula (costmodel_params.kernel_time_terms)
        # is built once over the knob symbols (qb/kvb/rnb/sch) and once over
        # the default constants.  At the default bindings both sides run the
        # identical op sequence on equal float64 values, so the delta is
        # exactly 0.0 and t_fwd (hence every phase sum, t_stable, d_delta,
        # and the golden objectives) is bitwise unchanged — the term only
        # moves candidates when the kernel dimension is actually swept.
        t_kernel_sym = self._kernel_time(
            b, tp, sp_div, Sym("qb"), Sym("kvb"), Sym("rnb"), Sym("sch"), L)
        t_kernel_def = self._kernel_time(
            b, tp, sp_div, *(wrap(float(v)) for v in
                             DEFAULT_KERNEL_CONFIG.astuple()), L)
        self.kernel_time_delta: Expr = t_kernel_sym - t_kernel_def
        # floor at a fraction of the base estimate: the delta is a roofline
        # *correction*, never allowed to swallow the base matmul time (a
        # mis-calibrated coefficient must not produce negative step times).
        # At the defaults delta == 0 and t_fwd > 0.1 * t_fwd, so the max
        # passes the base through bitwise and goldens are unaffected.
        t_fwd = smax(t_fwd + self.kernel_time_delta, 0.1 * t_fwd)
        t_bwd = 2.0 * t_fwd
        t_recompute = t_fwd * (ck / smax(L, 1.0))

        # dot-flops per pass (per microbatch, per device) — the quantities
        # the time items above price.  Exposed as their own exprs
        # (``evaluate_flops``) so consumers that need ground-truth flops
        # (benchmarks/accuracy.py) read the model's OWN counts instead of
        # inverting the time formula — inversion breaks once the kernel
        # roofline delta or the smax floor moves a time item, flops do not.
        self.flops_items: Dict[str, Expr] = {
            "fwd": wrap(flops_fwd), "bwd": wrap(2.0 * flops_fwd),
            "recompute": wrap(flops_fwd * (ck / smax(L, 1.0))),
        }

        # ---- collective times (per microbatch) ------------------------------
        ici = hw.ici_bw_total * cp.ici_eff
        lat = cp.coll_latency_us * 1e-6
        tp_on = (tp > 1)
        # TP: 2 AR (or AG+RS pair ~ same wire bytes) per layer fwd; 2 in bwd
        tp_wire_l = 2.0 * (2.0 * (tp - 1.0) / tp) * (2.0 * st.d_model * tok
                                                     / sp_div)
        attn_layers = st.attn_layers_frac
        t_tp_fwd = tp_on * (L * tp_wire_l / ici + L * 2.0 * lat)
        t_tp_bwd = tp_on * (L * tp_wire_l / ici + L * 2.0 * lat) \
            + tp_on * t_recompute * 0.0  # recompute TP comm folded below
        # recomputed layers redo their fwd TP collectives in bwd
        t_tp_bwd = t_tp_bwd + tp_on * (ck * tp_wire_l / ici)

        dp_on = (dp > 1)
        w_msg = 2.0 * n_tp                      # bf16 params
        g_msg = 4.0 * n_tp                      # f32 grads
        # ZeRO-3: AG params each microbatch fwd + bwd
        t_z3_fwd = z3 * dp_on * ((dp - 1.0) / dp * w_msg / ici + lat * 8.0)
        t_z3_bwd = t_z3_fwd
        # ZeRO-2: RS grads each microbatch (no persistent full-grad buffer)
        t_z2_rs = z2 * dp_on * ((dp - 1.0) / dp * g_msg / ici + lat * 8.0)
        # ZeRO<=1: one grad AR at the last microbatch
        t_dp_sync = (1.0 - z2) * dp_on * (2.0 * (dp - 1.0) / dp * g_msg / ici
                                          + lat * 8.0)
        # ZeRO>=1: updated-param AG once per step (first microbatch)
        t_z1_ag = z1 * dp_on * ((dp - 1.0) / dp * w_msg / ici + lat * 8.0)

        # ---- host-offload DMA times -----------------------------------------
        host = hw.host_bw * cp.host_eff
        opt_shard = 8.0 * n_tp / where(z1, dp, 1.0)
        mst_shard = 4.0 * n_tp / where(z1, dp, 1.0)
        grd_shard = 4.0 * n_tp / where(z2, dp, 1.0)
        t_opt_in = oo * opt_shard / host
        t_opt_out = t_opt_in
        t_mst_in = wo * mst_shard / host
        t_mst_out = t_mst_in
        t_go_out = go * grd_shard / host       # per microbatch
        t_go_in = t_go_out
        t_ao_out = off * act_ckpt_l / host      # per microbatch fwd
        t_ao_in = t_ao_out                      # bwd

        # ---- analytic HBM traffic per microbatch (TPU target) --------------
        # weights re-read per pass (fwd, bwd, + recomputed fraction), saved
        # activations written+read, residual stream through every layer,
        # f32 grad-accum read+write; optimizer traffic amortized per step.
        w_local = 2.0 * n_tp
        act_rw = 2.0 * acts_mb + 2.0 * L * (2.0 * st.d_model * tok / sp_div)
        hbm_mb = (2.0 + ck / smax(L, 1.0)) * w_local + act_rw \
            + 2.0 * g_bytes / smax(1.0, 1.0) \
            + 2.0 * act_full_l * (1.0 + ck / smax(L, 1.0))
        hbm_step_const = 2.0 * (12.0 * n_tp / where(z1, dp, 1.0)) \
            + 2.0 * 2.0 * n_tp
        self.hbm_bytes_mb: Expr = hbm_mb
        self.hbm_bytes_step: Expr = Sym("G") * hbm_mb + hbm_step_const

        self.items: Dict[str, Expr] = {
            "fwd": t_fwd, "bwd": t_bwd, "recompute": t_recompute,
            "opt_step": 0.02 * t_fwd,  # per-layer decoupled optimizer math
            "tp_fwd": t_tp_fwd, "tp_bwd": t_tp_bwd,
            "zero3_allgather_fwd": t_z3_fwd, "zero3_allgather_bwd": t_z3_bwd,
            "zero2_reduce_scatter": t_z2_rs,
            "dp_grad_sync": t_dp_sync,
            "zero1_param_allgather": t_z1_ag,
            "act_offload_out": t_ao_out, "act_offload_in": t_ao_in,
            "grad_offload_out": t_go_out, "grad_offload_in": t_go_in,
            "opt_swap_in": t_opt_in, "opt_swap_out": t_opt_out,
            "master_swap_in": t_mst_in, "master_swap_out": t_mst_out,
        }
        # extra items referenced by phases but folded elsewhere
        self._first_extra = ("zero1_param_allgather",)

        # ---- compile everything into ONE expression tape --------------------
        # All outputs (per-item times, both memory peaks, and the per-phase
        # channel totals consumed by the interference model) evaluate in a
        # single topologically-sorted pass; hash-consing dedupes the shared
        # subexpressions across them.
        outputs: Dict[str, Expr] = dict(self.items)
        outputs["mem_fwd"] = self.mem_fwd
        outputs["mem_bwd"] = self.mem_bwd
        for p in OVERLAP_SCHEDULE:
            for chan, expr in zip(("C", "G2G", "D2H", "H2D"),
                                  self._phase_channel_exprs(p)):
                outputs[f"phase:{p.name}:{chan}"] = expr
        self.tape = S.compile_tape(outputs)
        # split tapes: memory feasibility is checked on the full candidate
        # grid, runtime only on the feasible survivors (tune_stage); the
        # kernel VMEM legality rides on the memory tape so one pass masks
        # both HBM and VMEM infeasibility
        self.tape_mem = S.compile_tape({"mem_fwd": self.mem_fwd,
                                        "mem_bwd": self.mem_bwd,
                                        "vmem_peak": self.vmem_peak})
        self.tape_time = S.compile_tape(
            {k: v for k, v in outputs.items()
             if k not in ("mem_fwd", "mem_bwd")})
        # reusable intermediate buffers for the hot tapes (sweep loops)
        self._scratch = {id(t): t.make_scratch()
                         for t in (self.tape, self.tape_mem, self.tape_time)}
        # G-independence is structural: the time tape never loads G or
        # inflight, the memory tape never loads G, so callers can cache
        # results under cheap structural keys that omit them (collapses
        # the tuner's G loop, ROADMAP item).  The loaded-sym sets are
        # recorded so evaluate_times can REFUSE a structural key if a
        # model change ever makes the time tape read inflight (the one
        # symbol the callers' keys don't determine) — the cache then
        # degrades to disabled instead of serving wrong results.
        self._time_syms = tuple(sorted({n for n, _ in
                                        self.tape_time.sym_loads}))
        self._mem_syms = tuple(sorted({n for n, _ in
                                       self.tape_mem.sym_loads}))
        self._tape_cache: Dict[Tuple, Dict[str, Any]] = {}
        self.cache_hits = 0
        self.cache_misses = 0

    def _kernel_time(self, b, tp, sp_div, qb, kvb, rnb, sch, L) -> Expr:
        """Stage kernel time per microbatch for one (qb, kvb, rnb, sch)
        binding — the shared roofline formula gated to the ops this arch
        actually runs, times the stage's layer count."""
        st, hw, kc = self.st, self.hw, self.cp.kernels
        sd_h, sd_p, sd_n = ssd_dims(self.cfg)
        terms = kernel_time_terms(
            seq=self.seq, b=b, tp=tp, sp_div=sp_div, qb=qb, kvb=kvb,
            rnb=rnb, sch=sch, num_heads=st.num_heads, head_dim=st.head_dim,
            d_model=st.d_model, ssd_heads=sd_h, ssd_head_dim=sd_p,
            ssd_state=sd_n, hbm_bw=hw.hbm_bw,
            peak_flops=hw.peak_flops_bf16, kc=kc, ops=KERNEL_SYMBOLIC_OPS)
        per_layer = wrap(terms["rms"])
        if st.attn_layers_frac:
            per_layer = per_layer + st.attn_layers_frac * terms["attn"]
        if sd_h:
            per_layer = per_layer + terms["ssd"]
        return L * per_layer

    def _kernel_vmem(self, qb, kvb, rnb, sch, concrete: bool = False):
        """Worst-op VMEM working set; Expr over the knob symbols, or a
        float (``concrete=True``) for the default-config budget floor."""
        from repro.core.costmodel_params import KERNEL_CONCRETE_OPS
        st = self.st
        sd_h, sd_p, sd_n = ssd_dims(self.cfg)
        ops = KERNEL_CONCRETE_OPS if concrete else KERNEL_SYMBOLIC_OPS
        vt = kernel_vmem_terms(qb=qb, kvb=kvb, rnb=rnb, sch=sch,
                               head_dim=st.head_dim, d_model=st.d_model,
                               ssd_head_dim=sd_p, ssd_state=sd_n, ops=ops)
        peak = vt["rms"] if concrete else wrap(vt["rms"])
        if st.attn_layers_frac:
            peak = ops.max(peak, vt["attn"])
        if sd_h:
            peak = ops.max(peak, vt["ssd"])
        return peak

    def _phase_channel_exprs(self, phase: PhaseTraffic
                             ) -> Tuple[Expr, Expr, Expr, Expr]:
        """Symbolic per-channel totals for one phase (same summation order
        as the legacy `phase_channels`, so results are bitwise identical)."""
        def tot(names) -> Expr:
            out: Expr = wrap(0.0)
            for n in names:
                out = out + self.items[n]
            return out
        g2g = list(phase.g2g)
        if phase.name == "first":
            g2g += list(self._first_extra)
        return (tot(phase.compute), tot(g2g), tot(phase.d2h),
                tot(phase.h2d))

    # -- evaluation -----------------------------------------------------------
    def _env(self, env: Dict[str, Any]) -> Dict[str, Any]:
        e = dict(env)
        zero = np.asarray(e.pop("zero"))
        e["z1"] = (zero >= 1).astype(np.float64)
        e["z2"] = (zero >= 2).astype(np.float64)
        e["z3"] = (zero >= 3).astype(np.float64)
        e.setdefault("inflight", 1.0)
        # kernel knobs default to the frozen config so pre-existing callers
        # that never sweep the kernel dimension keep working unchanged
        for k, v in zip(("qb", "kvb", "rnb", "sch"),
                        DEFAULT_KERNEL_CONFIG.astuple()):
            e.setdefault(k, float(v))
        for k in SYMS:
            if k not in e:
                raise KeyError(f"cost-model env missing {k!r}")
        e = {k: np.asarray(v, np.float64) for k, v in e.items()}
        return e

    def phase_channels(self, phase: PhaseTraffic, vals: Dict[str, np.ndarray]
                       ) -> Tuple[np.ndarray, ...]:
        def tot(names):
            out = 0.0
            for n in names:
                out = out + vals[n]
            return np.asarray(out, np.float64)
        g2g = list(phase.g2g)
        if phase.name == "first":
            g2g += list(self._first_extra)
        return (tot(phase.compute), tot(g2g), tot(phase.d2h), tot(phase.h2d))

    def _use_jax(self, tape, e: Dict[str, Any]) -> bool:
        """Whether this tape run should execute on the jax backend.

        The identical-results guarantee is refused structurally, never
        assumed: no jax without x64 (f32 evaluation would silently drift
        from the numpy path and poison the backend-interchangeable
        knob-tuple cache), and no jax for tapes containing ops that are
        not correctly rounded in both numpy and XLA (``pow``/``log2``;
        see ``BITEXACT_OPS``) — in either case the model degrades to
        numpy, same as when jax is absent entirely."""
        if self.backend == "numpy":
            return False
        from repro import compat
        if not compat.has_jax():
            return False                # numpy-only container: degrade
        if not compat.jax_x64_enabled() or not tape.jax_bitexact:
            return False                # bitwise guarantee would be void
        if self.backend == "jax":
            return True
        # auto: jax pays off only on large grids
        n = max((v.shape[0] for v in e.values() if v.ndim), default=0)
        return n >= self.jax_auto_threshold

    def _run_tape(self, tape, e: Dict[str, Any]) -> Dict[str, Any]:
        """One tape evaluation on the selected backend; numpy values out."""
        if self._use_jax(tape, e):
            self.last_backend = "jax"
            raw = tape.lower_jax()(e)
            return {k: np.asarray(v) for k, v in raw.items()}
        self.last_backend = "numpy"
        return tape.run(e, self._scratch[id(tape)])

    _TAPE_CACHE_MAX = 128

    def _cache_get(self, key):
        hit = self._tape_cache.get(key)
        if hit is not None:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        return hit

    def _cache_put(self, key, value):
        if len(self._tape_cache) >= self._TAPE_CACHE_MAX:
            self._tape_cache.pop(next(iter(self._tape_cache)))
        self._tape_cache[key] = value

    def evaluate(self, env: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """env binds each symbol to a scalar or a 1-D candidate array.

        Runs the compiled tape: one linear pass over the shared expression
        DAG producing every output, then the batched interference model on
        the precomputed phase-channel totals."""
        e = self._env(env)
        raw = self._run_tape(self.tape, e)
        vals = {k: np.asarray(raw[k], np.float64) for k in self.items}
        mem_fwd = np.asarray(raw["mem_fwd"], np.float64)
        mem_bwd = np.asarray(raw["mem_bwd"], np.float64)
        return self._finish(e, vals, mem_fwd, mem_bwd, self._phases(raw))

    def _phases(self, raw: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Interference prediction per phase on tape-produced channel
        totals, deduplicating identical channel rows first (the algorithm
        is per-row independent, so dedup is result-identical; e.g. the
        stable phase does not read the oo/wo knobs, collapsing the grid)."""
        phases = {}
        for p in OVERLAP_SCHEDULE:
            x = np.stack(np.broadcast_arrays(
                *(np.asarray(raw[f"phase:{p.name}:{c}"], np.float64)
                  for c in ("C", "G2G", "D2H", "H2D"))), -1)
            if x.ndim == 2 and x.shape[0] > 512:
                # group exactly-equal rows via a column lexsort (much
                # cheaper than np.unique's structured-dtype argsort)
                order = np.lexsort((x[:, 3], x[:, 2], x[:, 1], x[:, 0]))
                xs = x[order]
                starts = np.empty(xs.shape[0], bool)
                starts[0] = True
                np.any(xs[1:] != xs[:-1], axis=1, out=starts[1:])
                inv = np.empty(xs.shape[0], np.intp)
                inv[order] = np.cumsum(starts) - 1
                phases[p.name] = self.intf.predict_stacked(
                    xs[starts])[inv]
            else:
                phases[p.name] = self.intf.predict_stacked(x)
        return phases

    def evaluate_memory(self, env: Dict[str, Any],
                        cache_key: Optional[Tuple] = None
                        ) -> Dict[str, np.ndarray]:
        """Memory outputs only (the Eq. 4 feasibility inputs), via the
        dedicated memory tape — used to mask the grid before the more
        expensive runtime evaluation.

        ``cache_key`` enables the knob-tuple result cache under a
        caller-supplied structural key; the caller guarantees the key
        determines the env columns exactly (see tune_stage_multi_g).
        Cached results are shared objects — treat them as read-only."""
        e = self._env(env)
        key = None
        if cache_key is not None:
            key = ("memk",) + tuple(cache_key)
            hit = self._cache_get(key)
            if hit is not None:
                return hit
        raw = self._run_tape(self.tape_mem, e)
        mem_fwd = np.asarray(raw["mem_fwd"], np.float64)
        mem_bwd = np.asarray(raw["mem_bwd"], np.float64)
        out = {"mem_fwd": mem_fwd, "mem_bwd": mem_bwd,
               "mem_peak": np.maximum(mem_fwd, mem_bwd),
               "vmem_peak": np.asarray(raw["vmem_peak"], np.float64)}
        if key is not None:
            self._cache_put(key, out)
        return out

    def evaluate_memory_terms(self, env: Dict[str, Any]
                              ) -> Dict[str, np.ndarray]:
        """Per-term peak-memory breakdown (state / act / transient /
        logits, plus the host_state / host_act bytes the plan moves off
        device).  The four device terms + runtime_reserved sum to
        ``mem_bwd`` — the peak side, since bwd only adds scratch on top
        of fwd.  Diagnostics path (memory_consistency, estimate_plan):
        recursive evaluation with one shared memo, not the sweep tape."""
        e = self._env(env)
        memo: Dict[int, Any] = {}
        return {k: np.asarray(expr.evaluate(e, memo), np.float64)
                for k, expr in self.mem_terms.items()}

    def evaluate_flops(self, env: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Per-microbatch, per-device dot flops by pass (``fwd`` / ``bwd``
        / ``recompute``) — the model's own counts, kernel-config invariant.
        Diagnostics path (recursive evaluation with one shared memo), like
        ``evaluate_memory_terms``."""
        e = self._env(env)
        memo: Dict[int, Any] = {}
        return {k: np.asarray(expr.evaluate(e, memo), np.float64)
                for k, expr in self.flops_items.items()}

    def mxu_efficiency(self, tok) -> np.ndarray:
        """Concrete MXU efficiency at ``tok`` per-device tokens per
        microbatch — the SAME formula the time tape bakes in (shared via
        ``costmodel_params.mxu_efficiency``), for consumers that need to
        invert compute times back to flops or vice versa."""
        cp = self.cp
        return np.asarray(mxu_efficiency(
            np.asarray(tok, np.float64), eff_peak=cp.mxu_eff_peak,
            eff_floor=cp.mxu_eff_floor, sat_tokens=cp.mxu_sat_tokens))

    def evaluate_times(self, env: Dict[str, Any],
                       cache_key: Optional[Tuple] = None
                       ) -> Dict[str, np.ndarray]:
        """Runtime outputs only (per-item times, phase interference,
        t_stable/d_delta/t_step) via the time tape.

        ``cache_key`` enables the knob-tuple result cache under a
        caller-supplied structural key.  The time tape loads neither G
        nor inflight, so identical knob columns hit across the tuner's G
        loop and across same-role stage hypotheses that differ only in
        inflight depth; ``t_step`` (the only G-dependent output) is
        recomputed from the current env.  Callers' keys carry G but by
        design NOT inflight — if a model change ever makes the time tape
        read inflight, caching is refused here rather than serving
        results computed under a different inflight.  Cached results are
        shared objects — treat them as read-only."""
        e = self._env(env)
        key = None
        if cache_key is not None and "inflight" not in self._time_syms:
            key = ("timek",) + tuple(cache_key)
            hit = self._cache_get(key)
            if hit is not None:
                return dict(hit, t_step=e["G"] * hit["t_stable"]
                            + hit["d_delta"])
        raw = self._run_tape(self.tape_time, e)
        vals = {k: np.asarray(raw[k], np.float64) for k in self.items}
        phases = self._phases(raw)
        t_stable = phases["stable"]
        d_delta = np.maximum(phases["first"] - t_stable, 0.0) \
            + np.maximum(phases["last"] - t_stable, 0.0)
        out = {"t_stable": t_stable, "d_delta": d_delta,
               "t_first": phases["first"], "t_last": phases["last"],
               "items": vals}
        if key is not None:
            self._cache_put(key, out)
        return dict(out, t_step=e["G"] * t_stable + d_delta)

    def evaluate_recursive(self, env: Dict[str, Any]
                           ) -> Dict[str, np.ndarray]:
        """Reference path: per-output recursive `Expr.evaluate` walks with a
        shared id-keyed memo, python-level channel summation, and the
        per-combination interference formulation.  Kept verbatim as the
        pre-compilation baseline for equivalence tests and the tuning-time
        benchmark; must produce bitwise-identical results to `evaluate`."""
        e = self._env(env)
        memo: Dict[int, Any] = {}
        vals = {k: np.asarray(expr.evaluate(e, memo), np.float64)
                for k, expr in self.items.items()}
        mem_fwd = np.asarray(self.mem_fwd.evaluate(e, memo), np.float64)
        mem_bwd = np.asarray(self.mem_bwd.evaluate(e, memo), np.float64)
        phases = {p.name: self.intf.predict_reference(
                      *self.phase_channels(p, vals))
                  for p in OVERLAP_SCHEDULE}
        return self._finish(e, vals, mem_fwd, mem_bwd, phases)

    def _finish(self, e, vals, mem_fwd, mem_bwd, phases
                ) -> Dict[str, np.ndarray]:
        t_stable = phases["stable"]
        d_delta = np.maximum(phases["first"] - t_stable, 0.0) \
            + np.maximum(phases["last"] - t_stable, 0.0)
        G = e["G"]
        t_step = G * t_stable + d_delta
        return {
            "mem_fwd": mem_fwd, "mem_bwd": mem_bwd,
            "mem_peak": np.maximum(mem_fwd, mem_bwd),
            "t_stable": t_stable, "d_delta": d_delta, "t_step": t_step,
            "t_first": phases["first"], "t_last": phases["last"],
            "items": vals,
        }

    # -- convenience: evaluate a list of Candidates ---------------------------
    def env_from_candidates(self, cands: Sequence[Candidate], *, layers: int,
                            grad_accum: int, inflight: float = 1.0
                            ) -> Dict[str, np.ndarray]:
        def arr(f):
            return np.asarray([f(c) for c in cands], np.float64)
        return {
            "b": arr(lambda c: c.b), "dp": arr(lambda c: c.dp),
            "tp": arr(lambda c: c.tp), "zero": arr(lambda c: c.zero),
            "ckpt": arr(lambda c: min(c.ckpt, layers)),
            "wo": arr(lambda c: c.wo), "go": arr(lambda c: c.go),
            "oo": arr(lambda c: c.oo), "ao": arr(lambda c: c.ao),
            "qb": arr(lambda c: c.qb), "kvb": arr(lambda c: c.kvb),
            "rnb": arr(lambda c: c.rnb), "sch": arr(lambda c: c.sch),
            "L": float(layers), "G": float(grad_accum),
            "inflight": float(inflight),
        }

    def memory_budget(self) -> float:
        return self.hw.hbm_bytes * self.cp.mem_headroom


# ---------------------------------------------------------------------------
# Whole-plan estimate (S = 1 fast path; pipeline handled by inter_stage)
# ---------------------------------------------------------------------------


def estimate_plan(cfg: ArchConfig, shape: ShapeConfig, plan, *,
                  hw: HardwareSpec = V5E, cp: CostParams = CostParams(),
                  interference: Optional[InterferenceModel] = None,
                  profile=None) -> Dict[str, float]:
    """Step-time / memory estimate of a concrete Plan (any S) using the same
    stage model + paper Eq. 1 for the pipeline objective.  ``profile`` layers
    fitted calibration constants over ``cp``/``interference`` (see
    ``StageCostModel``)."""
    n_st = len(plan.stages)
    ts, ds, mems, terms = [], [], [], []
    for i, stg in enumerate(plan.stages):
        scm = StageCostModel(cfg, shape.seq_len, hw=hw, cp=cp,
                             has_embed=(i == 0), has_head=(i == n_st - 1),
                             interference=interference, profile=profile,
                             sequence_parallel=plan.sequence_parallel)
        kc = plan.kernel
        cand = Candidate(b=stg.micro_batch, dp=stg.dp, tp=stg.tp,
                         zero=stg.zero, ckpt=min(stg.ckpt_layers, stg.layers),
                         wo=stg.wo, go=stg.go, oo=stg.oo, ao=stg.ao,
                         qb=kc.attn_q_block, kvb=kc.attn_kv_block,
                         rnb=kc.rmsnorm_block, sch=kc.ssd_chunk)
        env = scm.env_from_candidates([cand], layers=stg.layers,
                                      grad_accum=plan.grad_accum,
                                      inflight=max(1, n_st - i))
        r = scm.evaluate(env)
        ts.append(float(r["t_stable"][0]))
        ds.append(float(r["d_delta"][0]))
        mems.append(float(r["mem_peak"][0]))
        terms.append({k: float(np.asarray(v).flat[0]) for k, v in
                      scm.evaluate_memory_terms(env).items()})
    G = plan.grad_accum
    # paper Eq. 1
    t_step = (G - 1) * max(ts) + sum(ts) + max(
        d - sum(ts[:i]) for i, d in enumerate(ds))
    tokens = shape.global_batch * shape.seq_len
    return {
        "t_step": t_step, "throughput_tokens": tokens / t_step,
        "throughput_samples": shape.global_batch / t_step,
        "mem_peak_max": max(mems), "mem_per_stage": mems,
        "mem_terms_per_stage": terms,
        "t_stable_per_stage": ts, "d_delta_per_stage": ds,
        "fits": max(mems) <= hw.hbm_bytes * cp.mem_headroom,
    }


# ---------------------------------------------------------------------------
# Serving cost model (docs/serving.md)
# ---------------------------------------------------------------------------


class ServeCostModel:
    """Symbolic memory + latency of a single-stage SERVING deployment.

    Symbols (per candidate): ``dp``, ``tp``, ``z1``/``z2``/``z3`` (ZeRO
    indicators — only z3 matters for inference weights, the others are
    bound for the shared state-layout expression), and ``kv8`` (0/1:
    int8 KV cache).  The workload (batch, max context) is fixed per
    model instance, mirroring ``StageCostModel``'s (arch, seq) binding.

    Memory terms are the SHARED derivations — ``state_layout`` weights +
    ``cache_layout`` caches — evaluated over Exprs, so the predicted
    serve memory is bitwise-equal to ``LoweredPlan.memory_report()`` on
    matched plan/mesh pairs (the PR-5 two-evaluation contract, extended
    to serve shapes; tests/test_cache_layout.py).  Time terms are the
    ``serve_time_terms`` roofline: decode is HBM-bound (weights + KV
    prefix per token), prefill is compute-bound.
    """

    SYMS = ("dp", "tp", "z1", "z2", "z3", "kv8")

    def __init__(self, cfg: ArchConfig, *, batch: int, max_len: int,
                 page_size: int = 0, hw: HardwareSpec = V5E,
                 cp: CostParams = CostParams()):
        from repro.core.costmodel_params import (param_count,
                                                 serve_time_terms)
        from repro.lowering.cache_layout import (prefill_transient_bytes,
                                                 serve_device_bytes,
                                                 symbolic_cache_bytes,
                                                 symbolic_paged_cache_bytes)
        from repro.lowering.state_layout import SYMBOLIC_OPS
        self.cfg, self.hw, self.cp = cfg, hw, cp
        self.batch, self.max_len = int(batch), int(max_len)
        self.page_size = int(page_size)
        st = arch_stats(cfg)
        self.st = st
        dp, tp, kv8 = Sym("dp"), Sym("tp"), Sym("kv8")

        # weights: the shared state layout (z1/z2/z3, wo, oo, L are bound
        # in the env — serve stages carry no optimizer state or offload,
        # so wo = oo = 0 and L = num_layers)
        weight = symbolic_state_terms(cfg, has_embed=True,
                                      has_head=True)["weight"]
        # caches: the shared cache layout (page pools when page_size > 0),
        # one derivation per dtype, blended by the exact-0/1 kv8 indicator.
        # page_size == 0 builds exactly the contiguous exprs, so existing
        # serve plans and golden fixtures are untouched.
        if self.page_size:
            c16 = symbolic_paged_cache_bytes(cfg, self.batch, self.max_len,
                                             self.page_size, "bf16")
            c8 = symbolic_paged_cache_bytes(cfg, self.batch, self.max_len,
                                            self.page_size, "int8")
        else:
            c16 = symbolic_cache_bytes(cfg, self.batch, self.max_len, "bf16")
            c8 = symbolic_cache_bytes(cfg, self.batch, self.max_len, "int8")
        cache = where(kv8, c8, c16)
        mem_decode = serve_device_bytes(
            weight=weight, cache=cache,
            transient=cp.serve_decode_transient,
            reserved=cp.runtime_reserved)
        mem_prefill = serve_device_bytes(
            weight=weight, cache=0.0,
            transient=prefill_transient_bytes(
                st.act_coef_full, float(st.d_model), float(self.batch),
                float(self.max_len), dp, tp),
            reserved=cp.runtime_reserved)
        # occupancy-aware decode stream (docs/continuous-batching.md):
        # with paging only LIVE pages stream per step — the expected fill
        # fraction rounded up to page granularity (internal fragmentation)
        # — but each page is a separate, shorter HBM burst (strided-read
        # penalty).  Memory exprs stay the exact pool bytes; only the
        # t_decode stream is scaled, by a concrete python float.
        if self.page_size:
            ps = float(self.page_size)
            live_frac = (math.ceil(cp.serve_page_fill * self.max_len / ps)
                         * ps / float(self.max_len))
            stream_cache = cache * (
                live_frac * (1.0 + cp.serve_page_stride_rows / ps))
        else:
            stream_cache = cache
        times = serve_time_terms(
            batch=float(self.batch), seq_len=float(self.max_len),
            dp=dp, tp=tp, z3=Sym("z3"),
            n_active=float(param_count(cfg, active_only=True)),
            n_layers=cfg.num_layers, d_model=st.d_model,
            attn_flops_coef=st.attn_flops_coef, cache_bytes=stream_cache,
            hbm_bw=hw.hbm_bw, peak_flops=hw.peak_flops_bf16,
            ici_bw=hw.ici_bw_total * cp.ici_eff,
            mxu_eff_peak=cp.mxu_eff_peak, mxu_eff_floor=cp.mxu_eff_floor,
            mxu_sat_tokens=cp.mxu_sat_tokens,
            decode_mxu_eff=cp.decode_mxu_eff,
            coll_latency_us=cp.coll_latency_us, ops=SYMBOLIC_OPS)
        self.exprs = {"mem_decode": wrap(mem_decode),
                      "mem_prefill": wrap(mem_prefill),
                      "t_decode": wrap(times["t_decode"]),
                      "t_prefill": wrap(times["t_prefill"])}
        self.tape = S.compile_tape(self.exprs)

    def memory_budget(self) -> float:
        return self.hw.hbm_bytes * self.cp.mem_headroom

    def _env(self, env: Dict[str, Any]) -> Dict[str, Any]:
        full = {"wo": 0.0, "oo": 0.0, "L": float(self.cfg.num_layers)}
        full.update(env)
        return {k: np.asarray(v, np.float64) for k, v in full.items()}

    def evaluate(self, env: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Vectorized tape run over candidate arrays (``dp``/``tp``/
        ``z1``/``z2``/``z3``/``kv8``)."""
        return self.tape.run(self._env(env))

    def evaluate_one(self, *, dp: int, tp: int, zero: int = 0,
                     kv_cache_dtype: str = "bf16") -> Dict[str, float]:
        env = {"dp": float(dp), "tp": float(tp),
               "z1": 1.0 if zero >= 1 else 0.0,
               "z2": 1.0 if zero >= 2 else 0.0,
               "z3": 1.0 if zero >= 3 else 0.0,
               "kv8": 1.0 if kv_cache_dtype == "int8" else 0.0}
        return {k: float(v) for k, v in self.evaluate(env).items()}


def estimate_serve_plan(cfg: ArchConfig, shape: ShapeConfig, plan, *,
                        hw: HardwareSpec = V5E,
                        cp: CostParams = CostParams()) -> Dict[str, float]:
    """Serve-side twin of ``estimate_plan``: predicted per-device memory
    (decode and prefill kinds) and roofline latencies for one concrete
    single-stage plan.  ``mem_decode``/``mem_prefill`` are bitwise-equal
    to ``memory_report().peak_bytes`` of the matching lowering."""
    if len(plan.stages) != 1:
        raise ValueError("serving plans are single-stage (S=1); got "
                         f"{len(plan.stages)} stages")
    st0 = plan.stages[0]
    scm = ServeCostModel(cfg, batch=shape.global_batch,
                         max_len=shape.seq_len,
                         page_size=getattr(plan, "page_size", 0),
                         hw=hw, cp=cp)
    r = scm.evaluate_one(dp=st0.dp, tp=st0.tp, zero=st0.zero,
                         kv_cache_dtype=plan.kv_cache_dtype)
    r["fits"] = max(r["mem_decode"], r["mem_prefill"]) \
        <= scm.memory_budget()
    return r
