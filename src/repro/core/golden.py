"""Golden-plan regression fixtures: pinned fingerprints of the tuner's
selected plan + objective for every search-space preset.

The tuning stack guarantees *identical results* across engines, worker
counts, and tape backends; this module pins the results themselves, so an
unintended change to the cost model, the schedule template, the Pareto
selection, or the MILP shows up as a readable field-level diff instead of
a silently different plan.  One fixture exists per (SPACES preset, model
config) cell under ``tests/golden/``; ``tests/test_golden_plans.py``
recomputes each cell and compares fingerprints, and
``python tools/regen_golden.py`` rewrites the fixtures after an
*intentional* change (commit the diff together with the change that
caused it).

Fingerprints are sha256 over a canonical JSON document.  Floats are
formatted with ``%.12g`` — coarse enough to absorb last-ulp noise across
BLAS/platforms, fine enough that any real modeling change flips the
fingerprint.  The selection itself depends on the MILP solver's
tie-breaking on degenerate-optimum cells, so CI pins scipy to the minor
the fixtures were generated under (see .github/workflows/ci.yml); bump
the pin and regenerate together.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.configs.base import get_arch
from repro.core.tuner import SPACES, MistTuner, TuneSpec

# two paper-relevant model families: a dense GQA decoder and an MoE
GOLDEN_ARCHS: Tuple[str, ...] = ("granite-3-8b", "qwen2-moe-a2.7b")
GOLDEN_SPACES: Tuple[str, ...] = SPACES

# small but non-trivial workload: 8 devices leave room for S in {1, 2}
# and a real (dp, tp, zero, ckpt, offload) grid per stage
_WORKLOAD = dict(seq_len=2048, global_batch=16, n_devices=8,
                 stage_counts=(1, 2), grad_accums=(2, 4))

GOLDEN_DIR = Path(__file__).resolve().parents[3] / "tests" / "golden"


def golden_spec(space: str, arch: str) -> TuneSpec:
    return TuneSpec(arch=get_arch(arch), space=space, **_WORKLOAD)


def golden_path(space: str, arch: str, base: Optional[Path] = None) -> Path:
    return (base or GOLDEN_DIR) / f"{space}__{arch.replace('.', 'p')}.json"


def _fmt(x: float) -> str:
    return f"{float(x):.12g}"


def compute_doc(space: str, arch: str) -> Dict:
    """Run the tuner for one golden cell and canonicalize its result."""
    rep = MistTuner(golden_spec(space, arch)).tune()
    plan = None
    if rep.plan is not None:
        plan = json.loads(rep.plan.to_json())
    return {
        "space": space,
        "arch": arch,
        "workload": {k: list(v) if isinstance(v, tuple) else v
                     for k, v in _WORKLOAD.items()},
        "objective": _fmt(rep.objective),
        "best_S": rep.best_S,
        "best_G": rep.best_G,
        "infeasible": rep.infeasible,
        "per_sg": [[S, G, _fmt(obj)] for S, G, obj in rep.per_sg],
        "plan": plan,
    }


def fingerprint(doc: Dict) -> str:
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def diff_docs(want: Dict, got: Dict, prefix: str = "") -> List[str]:
    """Readable field-level differences between two golden documents."""
    if type(want) is not type(got):
        return [f"{prefix or '<root>'}: {want!r} != {got!r}"]
    if isinstance(want, dict):
        out: List[str] = []
        for k in sorted(set(want) | set(got)):
            p = f"{prefix}.{k}" if prefix else str(k)
            if k not in want:
                out.append(f"{p}: <absent in golden> != {got[k]!r}")
            elif k not in got:
                out.append(f"{p}: {want[k]!r} != <absent>")
            else:
                out.extend(diff_docs(want[k], got[k], p))
        return out
    if isinstance(want, list):
        if len(want) != len(got):
            return [f"{prefix}: length {len(want)} != {len(got)}"]
        out = []
        for i, (a, b) in enumerate(zip(want, got)):
            out.extend(diff_docs(a, b, f"{prefix}[{i}]"))
        return out
    if want != got:
        return [f"{prefix}: {want!r} != {got!r}"]
    return []


def check(base: Optional[Path] = None) -> Dict[Tuple[str, str], List[str]]:
    """Recompute every golden cell in-memory and diff it against the
    committed fixture.  Returns ``{(space, arch): [field-level diffs]}``
    for every stale / missing cell (empty dict == fixtures are current).

    This is the fail-fast guard behind ``tools/regen_golden.py --check``
    (run in CI): a change that shifts tuner selections without
    regenerating the fixtures surfaces here as a readable diff instead
    of as a cryptic sha mismatch later in tests/test_golden_plans.py."""
    base = base or GOLDEN_DIR
    problems: Dict[Tuple[str, str], List[str]] = {}
    for space in GOLDEN_SPACES:
        for arch in GOLDEN_ARCHS:
            path = golden_path(space, arch, base)
            doc = compute_doc(space, arch)
            if not path.exists():
                problems[(space, arch)] = [f"missing fixture {path.name}"]
                continue
            pinned = json.loads(path.read_text())
            diffs = diff_docs(pinned["doc"], doc)
            if not diffs and pinned.get("fingerprint") != fingerprint(doc):
                diffs = ["fingerprint mismatch with identical doc "
                         "(fixture written by an older canonicalization?)"]
            if diffs:
                problems[(space, arch)] = diffs
    return problems


def regen(base: Optional[Path] = None,
          only: Optional[Tuple[str, str]] = None) -> List[Path]:
    """(Re)write golden fixtures; returns the paths written."""
    base = base or GOLDEN_DIR
    base.mkdir(parents=True, exist_ok=True)
    written = []
    for space in GOLDEN_SPACES:
        for arch in GOLDEN_ARCHS:
            if only is not None and (space, arch) != only:
                continue
            doc = compute_doc(space, arch)
            path = golden_path(space, arch, base)
            path.write_text(json.dumps(
                {"fingerprint": fingerprint(doc), "doc": doc},
                indent=2, sort_keys=True) + "\n")
            written.append(path)
    return written
