"""Analytic parameter counting + per-kernel roofline coefficients.

``param_count`` sums abstract param shapes exactly.  The rest of the
module is the kernel-config cost layer: ``KernelCoeffs`` holds the
calibratable per-kernel roofline constants, and ``kernel_time_terms`` /
``kernel_vmem_terms`` are the ONE formula pair shared by

* the symbolic cost model (``core/costmodel.py`` builds them over
  ``Expr`` knobs — ``qb``/``kvb``/``rnb``/``sch`` — so the compiled
  tapes price the kernel dimension of the candidate grid), and
* the concrete predictor (``kernels/autotune.py`` evaluates them with
  floats against real bench measurements and anchors the per-kernel
  ``*_scale`` so the prediction is exact at the default config).

Both paths run the same arithmetic in the same order through a tiny
``Ops`` adapter (the ``lowering/state_layout.py`` idiom), so symbolic
and concrete evaluation agree bitwise (tests/test_kernel_tuning.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Dict

from repro.core import symbolic as S

if TYPE_CHECKING:
    from repro.configs.base import ArchConfig


def param_count(cfg: "ArchConfig", active_only: bool = False) -> int:
    from repro.models.zoo import abstract_params

    params, _ = abstract_params(cfg)
    total = sum(math.prod(s.shape) for s in params.values())
    if active_only and cfg.num_experts:
        # subtract inactive routed-expert weights
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        inactive = (cfg.num_experts - cfg.num_experts_per_tok) * per_expert
        total -= inactive * cfg.num_layers
    return int(total)


# ---------------------------------------------------------------------------
# Per-kernel roofline coefficients (CostParams.kernels)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelCoeffs:
    """Calibratable roofline constants, one group per Pallas kernel.

    The ``*_scale`` factors are dimensionless multipliers anchored by
    ``kernels.autotune.calibrate`` so the predicted time of the DEFAULT
    kernel config equals its measured time exactly; the remaining
    coefficients shape the relative cost across tile sizes.  Because
    the cost model prices the kernel dimension as a *delta* against the
    default config, the scales cancel at the defaults and golden plans
    are unaffected by calibration."""
    # flash attention
    attn_bw_eff: float = 0.85        # achieved HBM fraction for tile DMA
    attn_mxu_eff: float = 0.70       # MXU efficiency at aligned tiles
    attn_tile_overhead_us: float = 0.03  # per-grid-step launch cost
    attn_scale: float = 1.0
    # rmsnorm (bandwidth bound)
    rms_bw_eff: float = 0.85
    rms_tile_overhead_us: float = 0.05
    rms_scale: float = 1.0
    # mamba2 SSD chunk scan
    ssd_bw_eff: float = 0.85
    ssd_vpu_eff: float = 0.08        # fraction of peak for the scan math
    ssd_step_overhead_us: float = 0.2
    ssd_scale: float = 1.0

    def replace(self, **kw) -> "KernelCoeffs":
        import dataclasses
        return dataclasses.replace(self, **kw)


def mxu_efficiency(tok, *, eff_peak, eff_floor, sat_tokens):
    """Saturating MXU efficiency at ``tok`` per-device tokens per microbatch.

    The ONE training-side efficiency formula: ``StageCostModel._build``
    evaluates it over ``Expr`` knobs when compiling the time tape, and
    consumers that need the concrete curve (``StageCostModel.mxu_efficiency``,
    ``benchmarks/accuracy.py``) evaluate it over floats/arrays — identical
    arithmetic in identical order, so external users cannot drift from the
    model.  Rises from ``eff_floor`` toward ``eff_peak`` with half-saturation
    at ``sat_tokens``."""
    return eff_floor + (eff_peak - eff_floor) * (tok / (tok + sat_tokens))


# ---------------------------------------------------------------------------
# Ops adapters: the same formula runs over Exprs (tapes) or floats (bench
# predictor); min/max are the only non-native operations the formulas use.
# ---------------------------------------------------------------------------


class KernelSymbolicOps:
    @staticmethod
    def max(a, b):
        return S.smax(S.wrap(a), S.wrap(b))

    @staticmethod
    def min(a, b):
        return S.smin(S.wrap(a), S.wrap(b))


class KernelConcreteOps:
    @staticmethod
    def max(a, b):
        return a if a >= b else b

    @staticmethod
    def min(a, b):
        return a if a <= b else b


KERNEL_SYMBOLIC_OPS = KernelSymbolicOps()
KERNEL_CONCRETE_OPS = KernelConcreteOps()


def kernel_time_terms(*, seq: int, b, tp, sp_div, qb, kvb, rnb, sch,
                      num_heads: int, head_dim: int, d_model: int,
                      ssd_heads: int, ssd_head_dim: int, ssd_state: int,
                      hbm_bw: float, peak_flops: float, kc: KernelCoeffs,
                      ops=KERNEL_SYMBOLIC_OPS) -> Dict[str, Any]:
    """Per-layer, per-microbatch, per-device kernel times, by op.

    Returns ``{"attn", "rms", "ssd"}`` seconds.  ``b``/``tp``/``sp_div``
    and the four kernel knobs may be floats or ``Expr``s; everything
    else is a python scalar.  The caller gates each term by whether the
    arch actually runs that op and multiplies by the stage's layer
    count.

    Model per op:

    * attention — flash tiling: K/V stream once per query tile, so HBM
      traffic falls with ``qb``; tiles below the 128-wide MXU run at
      proportionally lower efficiency; each (q, kv) grid step pays a
      launch overhead.  ``t = max(compute, memory) + overhead``.
    * rmsnorm — bandwidth bound; the row-block only sets how many grid
      steps (launch overheads) cover the rows.
    * ssd scan — intra-chunk matmul work grows with the chunk length
      while the number of sequential state steps (and their launch +
      state-materialization traffic) shrinks: an interior optimum.
    """
    heads = num_heads / tp
    hd = float(head_dim)
    fseq = float(seq)

    # -- flash attention ----------------------------------------------------
    attn_bytes = 2.0 * b * heads * hd * (2.0 * fseq
                                         + 2.0 * fseq * (fseq / qb))
    t_attn_mem = attn_bytes / (hbm_bw * kc.attn_bw_eff)
    align = (ops.min(qb, 128.0) / 128.0) * (ops.min(kvb, 128.0) / 128.0)
    attn_flops = 4.0 * b * heads * fseq * fseq * hd
    t_attn_comp = attn_flops / (peak_flops * kc.attn_mxu_eff * align)
    attn_steps = b * heads * (fseq / qb) * (fseq / kvb)
    t_attn = kc.attn_scale * (ops.max(t_attn_comp, t_attn_mem)
                              + attn_steps * kc.attn_tile_overhead_us * 1e-6)

    # -- rmsnorm (2 norms per layer) ---------------------------------------
    rows = b * fseq / sp_div
    rms_bytes = 2.0 * 2.0 * rows * float(d_model) * 2.0
    t_rms_mem = rms_bytes / (hbm_bw * kc.rms_bw_eff)
    rms_steps = 2.0 * rows / rnb
    t_rms = kc.rms_scale * (t_rms_mem
                            + rms_steps * kc.rms_tile_overhead_us * 1e-6)

    # -- ssd chunk scan -----------------------------------------------------
    hs, ps, ns = float(ssd_heads), float(ssd_head_dim), float(ssd_state)
    ssd_flops = 4.0 * b * fseq * hs * ps * (ns + sch)
    t_ssd_comp = ssd_flops / (peak_flops * kc.ssd_vpu_eff)
    nchunks = fseq / sch
    ssd_bytes = 2.0 * 2.0 * b * fseq * hs * (ps + 2.0 * ns) \
        + 8.0 * b * nchunks * hs * ns * ps
    t_ssd_mem = ssd_bytes / (hbm_bw * kc.ssd_bw_eff)
    t_ssd = kc.ssd_scale * (ops.max(t_ssd_comp, t_ssd_mem)
                            + b * hs * nchunks
                            * kc.ssd_step_overhead_us * 1e-6)

    return {"attn": t_attn, "rms": t_rms, "ssd": t_ssd}


def kernel_vmem_terms(*, qb, kvb, rnb, sch, head_dim: int, d_model: int,
                      ssd_head_dim: int, ssd_state: int,
                      ops=KERNEL_SYMBOLIC_OPS) -> Dict[str, Any]:
    """Worst-case VMEM working set per op, in bytes.

    Mirrors the Pallas kernels' BlockSpecs + scratch shapes: flash
    attention holds a (qb, d) f32 accumulator, two (qb, 1) f32 stats
    rows, and bf16 q/k/v/o tiles; rmsnorm holds an f32 row block in and
    out plus the scale row; ssd holds (sch, p)/(sch, n) tiles and the
    (n, p) f32 carried state."""
    hd = float(head_dim)
    attn = qb * hd * 4.0 + 2.0 * qb * 4.0 \
        + (qb * hd + 2.0 * kvb * hd + qb * hd) * 2.0
    rms = 2.0 * rnb * float(d_model) * 4.0 + float(d_model) * 4.0
    ps, ns = float(ssd_head_dim), float(ssd_state)
    ssd = (sch * ps + 2.0 * sch * ns) * 4.0 + ns * ps * 4.0 \
        + sch * ps * 4.0
    return {"attn": attn, "rms": rms, "ssd": ssd}


# ---------------------------------------------------------------------------
# Serve-time roofline (docs/serving.md): decode is memory-bound (stream the
# weights + the KV prefix per emitted token), prefill is compute-bound (one
# big prefix matmul).  ONE formula pair over an Ops adapter with
# ``where``/``gt`` (the state_layout adapters), evaluated symbolically by
# ``ServeCostModel`` and concretely by ``estimate_serve_plan`` / tests.
# ---------------------------------------------------------------------------


def serve_time_terms(*, batch, seq_len, dp, tp, z3, n_active: float,
                     n_layers: int, d_model: int, attn_flops_coef: float,
                     cache_bytes, hbm_bw: float, peak_flops: float,
                     ici_bw: float, mxu_eff_peak: float,
                     mxu_eff_floor: float, mxu_sat_tokens: float,
                     decode_mxu_eff: float, coll_latency_us: float,
                     ops) -> Dict[str, Any]:
    """``{"t_decode", "t_prefill"}`` seconds per device.

    * ``t_decode`` — latency of ONE decode step (== per-token latency for
      every sequence in the batch): roofline max of the GEMV compute and
      the HBM stream of local weights + the full local KV prefix
      (steady state at max context — the conservative, SLO-relevant
      point), plus TP collective latency per layer and, under ZeRO-3
      weight sharding, the per-step weight all-gather — the time price
      of the memory the z3 knob saves.
    * ``t_prefill`` — the one-shot prefix cost: prompt-slab matmul flops
      at the saturating MXU efficiency, plus the same TP collectives
      over the token slab and a single z3 all-gather.

    ``batch``/``dp``/``tp``/``z3`` may be floats or ``Expr``s; the rest
    are python scalars.  ``cache_bytes`` is the per-device cache term
    from ``lowering/cache_layout.py`` (symbolic or concrete to match).
    """
    b_local = batch / dp
    w_stream = 2.0 * n_active / tp          # bf16 weight bytes per device
    lat = coll_latency_us * 1e-6
    L = float(n_layers)

    # -- decode step ---------------------------------------------------------
    flops_dec = (2.0 * n_active + attn_flops_coef * seq_len) * b_local / tp
    t_comp = flops_dec / (peak_flops * decode_mxu_eff)
    t_hbm = (w_stream + cache_bytes) / hbm_bw
    roof = ops.where(ops.gt(t_comp, t_hbm), t_comp, t_hbm)
    tp_msg = 2.0 * b_local * float(d_model)
    t_tp = (2.0 * L * (2.0 * (tp - 1.0) / tp) * tp_msg / ici_bw
            + ops.gt(tp, 1.0) * 2.0 * L * lat)
    t_z3 = z3 * ((dp - 1.0) / dp * w_stream / ici_bw
                 + ops.gt(dp, 1.0) * lat * L)
    t_decode = roof + t_tp + t_z3

    # -- prefill (one-shot prefix) -------------------------------------------
    tok_local = batch * seq_len / dp
    sat = ops.where(ops.gt(tok_local, mxu_sat_tokens), 1.0,
                    tok_local / mxu_sat_tokens)
    eff = mxu_eff_floor + (mxu_eff_peak - mxu_eff_floor) * sat
    flops_pre = (2.0 * n_active + attn_flops_coef * seq_len) * tok_local / tp
    pre_msg = 2.0 * tok_local * float(d_model)
    t_pre = (flops_pre / (peak_flops * eff)
             + 2.0 * L * (2.0 * (tp - 1.0) / tp) * pre_msg / ici_bw
             + ops.gt(tp, 1.0) * 2.0 * L * lat
             + z3 * (dp - 1.0) / dp * w_stream / ici_bw)

    return {"t_decode": t_decode, "t_prefill": t_pre}


def ssd_dims(cfg: "ArchConfig"):
    """(heads, head_dim, state) of the arch's SSD scan, or zeros when the
    family has no SSM mixer."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0, 0, 0
    di = cfg.ssm_expand * cfg.d_model
    return di // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state
