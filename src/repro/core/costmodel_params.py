"""Analytic parameter counting (exact: sums abstract param shapes)."""
from __future__ import annotations

import math
from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from repro.configs.base import ArchConfig


def param_count(cfg: "ArchConfig", active_only: bool = False) -> int:
    from repro.models.zoo import abstract_params

    params, _ = abstract_params(cfg)
    total = sum(math.prod(s.shape) for s in params.values())
    if active_only and cfg.num_experts:
        # subtract inactive routed-expert weights
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        inactive = (cfg.num_experts - cfg.num_experts_per_tok) * per_expert
        total -= inactive * cfg.num_layers
    return int(total)
