"""jax API compatibility layer.

The runtime code targets current jax (``jax.sharding.AxisType``,
``jax.set_mesh``, top-level ``jax.shard_map`` with ``axis_names`` /
``check_vma``).  The pinned CI / container environment may carry an older
jax (0.4.x) where those spellings do not exist yet:

  * ``Mesh`` takes no ``axis_types`` (every axis is implicitly Auto);
  * ``AbstractMesh`` takes ``((name, size), ...)`` instead of
    ``(shape, names)``;
  * the ambient mesh is entered with the ``Mesh`` context manager rather
    than ``jax.set_mesh``;
  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells
    partial-manual mode as ``auto=`` (the complement of ``axis_names``)
    and replication checking as ``check_rep``.

Every mesh/shard_map construction in the repo goes through this module so
both API generations work.  Evaluate capabilities at call time (not import
time) so test-time monkeypatching and upgrades behave predictably.

The module also gates jax *availability* for the analysis stack: the
tuner / cost-model path is pure numpy and must keep working in numpy-only
containers, with the optional jax tape backend (``Tape.lower_jax``,
``StageCostModel(backend=...)``) degrading cleanly when jax is absent.
Those callers probe ``has_jax()`` / ``require_jax()`` here instead of
importing jax themselves.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Sequence, Tuple

try:
    import jax
except Exception as _e:          # numpy-only container: analysis-only mode
    jax = None                   # type: ignore[assignment]
    _JAX_IMPORT_ERROR: Exception = _e


def has_jax() -> bool:
    """Whether jax imported successfully (tape backends probe this)."""
    return jax is not None


def require_jax() -> Tuple["jax", "jax.numpy"]:
    """(jax, jax.numpy), or ImportError with the original import failure."""
    if jax is None:
        raise ImportError(
            "jax is unavailable in this environment; use the numpy tape "
            "backend") from _JAX_IMPORT_ERROR
    import jax.numpy as jnp
    return jax, jnp


def jax_x64_enabled() -> bool:
    """Whether jax currently produces 64-bit floats (honors both the
    global ``jax_enable_x64`` flag and the thread-local ``enable_x64``
    context).  The tape backends' bitwise-equivalence-to-numpy guarantee
    holds only when this is True; ``backend="auto"`` refuses jax
    otherwise."""
    if jax is None:
        return False
    import numpy as np
    import jax.numpy as jnp
    return jnp.result_type(float) == np.float64


def enable_x64():
    """Context manager forcing 64-bit jax types inside the block (the
    backend equivalence suite runs under it).  Uses
    ``jax.experimental.enable_x64`` where it exists; falls back to
    flipping the config flag (not thread-safe, but only reachable on jax
    versions without the scoped context)."""
    if jax is None:
        raise ImportError("jax is unavailable; cannot enable x64")
    ctx = getattr(getattr(jax, "experimental", None), "enable_x64", None)
    if ctx is not None:
        return ctx()

    @contextlib.contextmanager
    def _flag():
        old = bool(jax.config.jax_enable_x64)
        jax.config.update("jax_enable_x64", True)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", old)
    return _flag()


def axis_type_auto():
    """``jax.sharding.AxisType.Auto`` where it exists, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    return getattr(at, "Auto", None)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with every axis Auto, across the API drift."""
    auto = axis_type_auto()
    if auto is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-less mesh carrying only (name, size) metadata."""
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(shape), tuple(axes))
    except TypeError:   # 0.4.x signature: ((name, size), ...)
        return AM(tuple(zip(axes, shape)))


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient (``jax.set_mesh`` on new
    jax; the ``Mesh`` object is itself the context manager on old jax)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: frozenset, check_vma: bool = True):
    """Partial-manual shard_map: ``axis_names`` are manual, the rest of the
    mesh axes stay auto (XLA SPMD keeps handling them)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    # old check_rep uses a different (per-primitive replication-rule)
    # mechanism that rejects with_sharding_constraint inside the region;
    # replication of outputs is established explicitly by the callers
    # (psum over the manual axis), so it is safe to disable.
    return _sm(f, mesh, in_specs, out_specs, check_rep=False, auto=auto)


@functools.lru_cache(maxsize=1)
def host_memory_kind():
    """Memory kind for host-offloaded state ("pinned_host"), or None when
    the backend has no separate host memory space (jax 0.4.x CPU exposes
    only "unpinned_host", which is also the default device memory there).
    None means offload ratios degrade gracefully to resident placement —
    values and update math are unchanged, only the placement differs.
    Cached: callers probe it per pytree leaf, and a backend's memory
    spaces don't change within a process."""
    try:
        kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
    except Exception:       # pragma: no cover - exotic backends
        return None
    return "pinned_host" if "pinned_host" in kinds else None


def jax_version() -> Tuple[int, ...]:
    """jax's version as an int tuple ((0,) when jax is absent or the
    version string is exotic).  Capability gates that guard against
    *bundled-XLA* behavior — which no Python-API probe can see — compare
    against this."""
    if jax is None:
        return (0,)
    out = []
    for part in str(getattr(jax, "__version__", "0")).split("."):
        digits = ""
        for c in part:  # digits *prefix*: "37rc1" is 37, not 371
            if not c.isdigit():
                break
            digits += c
        if not digits:
            break
        out.append(int(digits))
    return tuple(out) or (0,)


def supports_pipeline_stage_mapping() -> bool:
    """Whether this jax can run the pipeline executor's partial-manual
    shard_map (scan + ppermute over a manual 'stage' axis with auto
    data/model axes).  On jax 0.4.x — including the container's pinned
    0.4.37 — the bundled XLA SPMD partitioner hard CHECK-fails on that
    pattern (hlo_sharding_util IsManualSubgroup), so the pipeline train
    step is gated to jax >= 0.5; single-stage SPMD, tuning, and all
    analysis paths are unaffected.

    The version floor is checked EXPLICITLY, not inferred from
    ``hasattr(jax, "shard_map")``: the crash lives in the bundled XLA,
    not the Python API, so a 0.4.x that aliased ``shard_map`` to the
    top level (or a test monkeypatch) must still be rejected.  The API
    probe stays as the second conjunct because the executor also needs
    the new ``axis_names``/``check_vma`` spelling's semantics."""
    return jax_version() >= (0, 5) and hasattr(jax, "shard_map")
