"""Fitting half of the calibration loop (docs/calibration.md).

``attribute_cell`` prices a measured cell through the REAL cost model at
base constants, keeping the per-phase channel totals (C / G2G / D2H /
H2D) the interference model consumes.  ``fit_scales`` then fits three
group multipliers — compute, collective, DMA — by least squares in log
step time.  The key property making this cheap is *exact scaling*: the
surrogate that divides a channel total by its group scale equals a full
model rebuild with the correspondingly scaled ``CostParams``, because

* scaling ``mxu_eff_peak`` AND ``mxu_eff_floor`` by ``s`` scales the MXU
  efficiency curve — hence 1/compute-time — exactly by ``s`` (the kernel
  roofline delta is exactly 0 at default kernel configs, which
  ``attribute_cell`` asserts),
* scaling ``ici_eff`` by ``s`` while dividing ``coll_latency_us`` by
  ``s`` scales every collective item exactly by ``1/s``,
* scaling ``host_eff`` by ``s`` scales every offload-DMA item by
  ``1/s``,

so one attribution pass per cell suffices for the whole optimization
(no tape rebuilds inside the loss), and ``scales_to_overrides`` turns
the winning scales back into the equivalent ``CostParams`` overrides.
``tests/test_calibration.py`` asserts surrogate == rebuilt model.

``fit_profile`` composes the pieces: scalar fit, optional
``InterferenceModel.calibrate`` refit on the scaled stable-phase
channels, optional ``KernelCoeffs`` anchors, a keep-if-better guard
(never return a profile that predicts worse than what it started from),
and a per-cell error report (paper Fig. 11 style: predicted vs measured,
before/after fitting).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.calibration.measure import MeasuredCell
from repro.calibration.profile import (DEFAULT_PROFILE, KERNEL_FIELDS,
                                       CalibrationProfile)
from repro.core.costmodel import (JAX_AUTO_THRESHOLD, CostParams,
                                  StageCostModel, estimate_plan)
from repro.core.costmodel_params import KernelCoeffs
from repro.core.interference import InterferenceModel
from repro.core.plan import DEFAULT_KERNEL_CONFIG
from repro.core.schedule import OVERLAP_SCHEDULE, Candidate

# time-tape item -> fitted group.  Covers every StageCostModel item
# (tests assert the two key sets match, so a new item cannot be silently
# left out of calibration).
ITEM_GROUP: Dict[str, str] = {
    "fwd": "compute", "bwd": "compute", "recompute": "compute",
    "opt_step": "compute",
    "tp_fwd": "collective", "tp_bwd": "collective",
    "zero3_allgather_fwd": "collective", "zero3_allgather_bwd": "collective",
    "zero2_reduce_scatter": "collective", "dp_grad_sync": "collective",
    "zero1_param_allgather": "collective",
    "act_offload_out": "dma", "act_offload_in": "dma",
    "grad_offload_out": "dma", "grad_offload_in": "dma",
    "opt_swap_in": "dma", "opt_swap_out": "dma",
    "master_swap_in": "dma", "master_swap_out": "dma",
}
GROUPS = ("compute", "collective", "dma")
# channel index (C, G2G, D2H, H2D) -> group index: DMA covers both
# directions (one host_eff constant prices both)
_CHANNEL_GROUP = np.array([0, 1, 2, 2])


@dataclass
class CellAttribution:
    """One cell priced at base constants: phase channel totals + items."""
    label: str
    G: int
    phases: Dict[str, np.ndarray]   # phase name -> (4,) channel seconds
    items: Dict[str, float]         # named time-tape items (per microbatch)
    t_step_pred: float              # base-constant step prediction


def attribute_cell(cell: MeasuredCell, *,
                   profile: CalibrationProfile = DEFAULT_PROFILE
                   ) -> CellAttribution:
    """Price one measured cell through the real StageCostModel and keep
    the attribution the fit needs."""
    if len(cell.plan.stages) != 1:
        raise ValueError("calibration cells are single-stage (S=1)")
    if cell.plan.kernel != DEFAULT_KERNEL_CONFIG:
        # non-default kernels move t_fwd through the roofline delta, which
        # does NOT rescale with mxu_eff_* — the exact-scaling surrogate
        # would be approximate, so refuse rather than silently drift
        raise ValueError("calibration cells must use the default kernel "
                         "config (exact-scaling surrogate)")
    cfg, shape, stg = cell.config(), cell.shape(), cell.plan.stages[0]
    scm = StageCostModel(cfg, shape.seq_len,
                         sequence_parallel=cell.plan.sequence_parallel,
                         profile=profile)
    kc = cell.plan.kernel
    cand = Candidate(b=stg.micro_batch, dp=stg.dp, tp=stg.tp, zero=stg.zero,
                     ckpt=min(stg.ckpt_layers, stg.layers),
                     wo=stg.wo, go=stg.go, oo=stg.oo, ao=stg.ao,
                     qb=kc.attn_q_block, kvb=kc.attn_kv_block,
                     rnb=kc.rmsnorm_block, sch=kc.ssd_chunk)
    env = scm.env_from_candidates([cand], layers=stg.layers,
                                  grad_accum=cell.plan.grad_accum)
    out = scm.evaluate(env)
    phases = {
        p.name: np.array([float(np.asarray(v).reshape(-1)[0])
                          for v in scm.phase_channels(p, out["items"])],
                         np.float64)
        for p in OVERLAP_SCHEDULE}
    items = {k: float(np.asarray(v).reshape(-1)[0])
             for k, v in out["items"].items()}
    return CellAttribution(label=cell.label, G=cell.plan.grad_accum,
                           phases=phases, items=items,
                           t_step_pred=float(out["t_step"][0]))


def _phase_walls(attr: CellAttribution, scales,
                 intf: InterferenceModel) -> Dict[str, float]:
    inv = 1.0 / np.asarray(scales, np.float64)[_CHANNEL_GROUP]
    return {name: float(intf.predict_stacked(ch * inv))
            for name, ch in attr.phases.items()}


def predict_step_scaled(attr: CellAttribution, scales,
                        intf: InterferenceModel) -> float:
    """Surrogate step-time prediction under group scales — exactly equal
    to rebuilding the model with ``scales_to_overrides`` applied."""
    walls = _phase_walls(attr, scales, intf)
    t_stable = walls["stable"]
    d_delta = (max(walls["first"] - t_stable, 0.0)
               + max(walls["last"] - t_stable, 0.0))
    return attr.G * t_stable + d_delta


def fit_scales(attrs: Sequence[CellAttribution],
               measured: Sequence[float], *,
               intf: Optional[InterferenceModel] = None,
               log_lo: float = -5.0, log_hi: float = 2.0,
               sweeps: int = 4, tol: float = 1e-4
               ) -> Tuple[float, float, float]:
    """Least squares in log step time over the three group scales, by
    cyclic coordinate descent with golden-section line search on
    ``log10(scale)`` in ``[log_lo, log_hi]``.  Pure numpy — no scipy.
    Groups with no observed traffic across all cells stay pinned at 1
    (they are unidentifiable; fitting them would be noise)."""
    intf = intf or InterferenceModel()
    meas = [max(float(m), 1e-30) for m in measured]
    active = [False, False, False]
    for a in attrs:
        tot = np.sum([np.abs(ch) for ch in a.phases.values()], axis=0)
        for g in range(3):
            if float(tot[_CHANNEL_GROUP == g].sum()) > 1e-15:
                active[g] = True

    def loss(logs) -> float:
        s = 10.0 ** np.asarray(logs, np.float64)
        err = 0.0
        for a, m in zip(attrs, meas):
            p = max(predict_step_scaled(a, s, intf), 1e-30)
            err += (math.log(p) - math.log(m)) ** 2
        return err / max(1, len(attrs))

    gr = (math.sqrt(5.0) - 1.0) / 2.0
    logs = np.zeros(3, np.float64)
    for _ in range(max(1, sweeps)):
        for i in range(3):
            if not active[i]:
                continue
            lo, hi = log_lo, log_hi
            probe = logs.copy()

            def f(v, i=i, probe=probe):
                probe[i] = v
                return loss(probe)

            c = hi - gr * (hi - lo)
            d = lo + gr * (hi - lo)
            fc, fd = f(c), f(d)
            while hi - lo > tol:
                if fc < fd:
                    hi, d, fd = d, c, fc
                    c = hi - gr * (hi - lo)
                    fc = f(c)
                else:
                    lo, c, fc = c, d, fd
                    d = lo + gr * (hi - lo)
                    fd = f(d)
            logs[i] = (lo + hi) / 2.0
    s = 10.0 ** logs
    return float(s[0]), float(s[1]), float(s[2])


def scales_to_overrides(scales, base: CostParams) -> Dict[str, float]:
    """The CostParams overrides equivalent to the fitted group scales
    (see module docstring for why the equivalence is exact)."""
    s_comp, s_coll, s_dma = (float(s) for s in scales)

    def eff(v: float) -> float:
        return min(0.98, max(1e-9, v))

    out: Dict[str, float] = {}
    if s_comp != 1.0:
        out["mxu_eff_peak"] = eff(base.mxu_eff_peak * s_comp)
        out["mxu_eff_floor"] = eff(base.mxu_eff_floor * s_comp)
    if s_coll != 1.0:
        out["ici_eff"] = eff(base.ici_eff * s_coll)
        out["coll_latency_us"] = base.coll_latency_us / s_coll
    if s_dma != 1.0:
        out["host_eff"] = eff(base.host_eff * s_dma)
    return out


def _kernel_overrides(kc: Optional[KernelCoeffs]) -> Dict[str, float]:
    if kc is None:
        return {}
    base = KernelCoeffs()
    return {f: float(getattr(kc, f)) for f in KERNEL_FIELDS
            if getattr(kc, f) != getattr(base, f)}


def fit_profile(cells: Sequence[MeasuredCell], *,
                base: CalibrationProfile = DEFAULT_PROFILE,
                platform: str = "cpu", fit_interference: bool = True,
                kernel_coeffs: Optional[KernelCoeffs] = None,
                jax_auto_threshold: Optional[int] = None,
                sweeps: int = 4
                ) -> Tuple[CalibrationProfile, Dict]:
    """Fit a CalibrationProfile from measured cells.  Returns
    ``(profile, report)``; the report carries the per-cell
    predicted-vs-measured table before and after fitting."""
    if not cells:
        raise ValueError("no measured cells to fit")
    intf_base = base.interference_model()
    attrs = [attribute_cell(c, profile=base) for c in cells]
    measured = [c.t_measured for c in cells]

    scales = fit_scales(attrs, measured, intf=intf_base, sweeps=sweeps)
    base_cp = base.cost_params()
    cost_over = dict(base.cost)
    cost_over.update(scales_to_overrides(scales, base_cp))
    kern_over = dict(base.kernels)
    kern_over.update(_kernel_overrides(kernel_coeffs))
    if jax_auto_threshold is None:
        # accelerator backends cross the numpy->jax tape threshold far
        # earlier than the 2-core-CPU default (see costmodel.py)
        jax_auto_threshold = (JAX_AUTO_THRESHOLD if platform == "cpu"
                              else JAX_AUTO_THRESHOLD >> 5)
    source = f"measured ({len(cells)} cells)"

    def make_profile(intf_factors) -> CalibrationProfile:
        return CalibrationProfile.make(
            platform=platform, source=source, cost=cost_over,
            kernels=kern_over, interference=intf_factors,
            jax_auto_threshold=jax_auto_threshold)

    # optional interference refit: feed calibrate() the scaled stable-phase
    # channels with the wall time the measurement implies for one stable
    # microbatch ((measured - d_delta) / G)
    n_samples = 0
    intf_fit = None
    if fit_interference:
        inv = 1.0 / np.asarray(scales, np.float64)[_CHANNEL_GROUP]
        samples = []
        for a, m in zip(attrs, measured):
            ch = a.phases["stable"] * inv
            if int((ch > 1e-12).sum()) < 2:
                continue        # single active channel: no overlap to fit
            walls = _phase_walls(a, scales, intf_base)
            d_delta = (max(walls["first"] - walls["stable"], 0.0)
                       + max(walls["last"] - walls["stable"], 0.0))
            wall = (float(m) - d_delta) / max(1, a.G)
            if wall > 0.0:
                samples.append((tuple(float(v) for v in ch), wall))
        n_samples = len(samples)
        if n_samples >= 2:
            model = base.interference_model()
            model.calibrate(samples)
            intf_fit = model.factors

    # evaluate candidates through the REAL model (estimate_plan), not the
    # surrogate — this is the number the report publishes
    def errors(profile: Optional[CalibrationProfile]) -> List[float]:
        out = []
        for c, a in zip(cells, attrs):
            if profile is None:
                pred = a.t_step_pred
            else:
                pred = estimate_plan(c.config(), c.shape(), c.plan,
                                     profile=profile)["t_step"]
            out.append(abs(pred - c.t_measured) / max(c.t_measured, 1e-30))
        return out

    err_uncal = errors(None)
    candidates = [(base, err_uncal)]
    prof_scaled = make_profile(base.interference)
    candidates.append((prof_scaled, errors(prof_scaled)))
    if intf_fit is not None:
        prof_intf = make_profile(intf_fit)
        candidates.append((prof_intf, errors(prof_intf)))
    # keep-if-better: never publish a profile that predicts worse than
    # its own starting point
    profile, err_fit = min(candidates, key=lambda t: float(np.mean(t[1])))

    rows = []
    for c, a, eu, ef in zip(cells, attrs, err_uncal, err_fit):
        t_fit = (a.t_step_pred if profile is base else
                 estimate_plan(c.config(), c.shape(), c.plan,
                               profile=profile)["t_step"])
        rows.append({
            "label": c.label, "t_measured": c.t_measured,
            "t_pred_uncalibrated": a.t_step_pred, "t_pred_fitted": t_fit,
            "err_uncalibrated": eu, "err_fitted": ef,
            "items": a.items, "memory": dict(c.memory),
        })
    report = {
        "platform": platform, "n_cells": len(cells),
        "scales": dict(zip(GROUPS, [float(s) for s in scales])),
        "interference_refit": (intf_fit is not None
                               and profile.interference != base.interference),
        "interference_samples": n_samples,
        "cells": rows,
        "mean_err_uncalibrated": float(np.mean(err_uncal)),
        "mean_err_fitted": float(np.mean(err_fit)),
        "improved": float(np.mean(err_fit)) < float(np.mean(err_uncal)),
    }
    return profile, report


def calibrate_kernels(archs: Sequence[str], *, seq_len: int = 2048,
                      reduced: bool = True) -> KernelCoeffs:
    """Anchor the KernelCoeffs ``*_scale`` factors through the existing
    ``kernels.autotune.calibrate`` bench cache, chained across archs so
    each arch anchors the ops it actually runs."""
    from repro.configs.base import get_arch
    from repro.kernels.autotune import calibrate

    kc = KernelCoeffs()
    for arch in archs:
        cfg = get_arch(arch)
        if reduced:
            cfg = cfg.reduced()
        kc = calibrate(cfg, seq_len=seq_len, kc=kc)
    return kc
