"""End-to-end calibration driver: measure → fit → report → persist.

Shared by ``tools/calibrate.py``, ``launch/train.py --calibrate`` and
``benchmarks/accuracy.py --measured`` so all three entry points produce
the same JSON artifact shape (the CI calibration smoke uploads it).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional, Sequence

from repro.calibration.measure import GOLDEN_ARCHS, DEFAULT_SEQ


def run_calibration(*, archs: Sequence[str] = GOLDEN_ARCHS,
                    steps: int = 4, warmup: int = 2,
                    seq_len: int = DEFAULT_SEQ,
                    platform: Optional[str] = None,
                    fit_interference: bool = True,
                    fit_kernels: bool = False,
                    write_profile: Optional[str] = None,
                    max_cells_per_arch: Optional[int] = None,
                    sweeps: int = 4) -> Dict:
    """Measure the golden cells on the current devices, fit a profile,
    and return the full report (cells, errors, profile, skip reasons).

    ``write_profile``: a path, or ``"auto"`` for the platform's default
    location under ``$REPRO_CALIBRATION_DIR``."""
    from repro.calibration.fit import calibrate_kernels, fit_profile
    from repro.calibration.measure import measure_cells
    from repro.calibration.profile import default_platform, profile_path

    platform = platform or default_platform()
    cells, skipped = measure_cells(archs, steps=steps, warmup=warmup,
                                   seq_len=seq_len,
                                   max_cells_per_arch=max_cells_per_arch)
    if not cells:
        return {"platform": platform, "n_cells": 0, "cells": [],
                "skipped_cells": skipped, "improved": False,
                "error": "no cell ran to completion"}
    kc = calibrate_kernels(archs) if fit_kernels else None
    profile, report = fit_profile(cells, platform=platform,
                                  fit_interference=fit_interference,
                                  kernel_coeffs=kc, sweeps=sweeps)
    report["skipped_cells"] = skipped
    report["measured_cells"] = [c.to_doc() for c in cells]
    report["profile"] = profile.to_doc()
    if write_profile:
        path = (profile_path(platform) if write_profile == "auto"
                else Path(write_profile))
        profile.save(path)
        report["profile_path"] = str(path)
    return report


def format_table(report: Dict) -> str:
    """Human-readable uncalibrated-vs-fitted error table."""
    lines = []
    if report.get("error"):
        lines.append(f"calibration failed: {report['error']}")
    for row in report.get("cells", []):
        lines.append(
            f"{row['label']:42s} measured {row['t_measured'] * 1e3:9.2f} ms"
            f"  pred(uncal) {row['t_pred_uncalibrated'] * 1e3:9.2f} ms"
            f"  pred(fit) {row['t_pred_fitted'] * 1e3:9.2f} ms"
            f"  err {row['err_uncalibrated']:8.1%} -> "
            f"{row['err_fitted']:7.1%}")
    if "mean_err_uncalibrated" in report:
        lines.append(
            f"{'MEAN (' + str(report['n_cells']) + ' cells)':42s} "
            f"err {report['mean_err_uncalibrated']:8.1%} -> "
            f"{report['mean_err_fitted']:7.1%}  "
            f"improved={report['improved']}")
    for s in report.get("skipped_cells", []):
        lines.append(f"SKIPPED {s['arch']}/{s['label']}: {s['error']}")
    return "\n".join(lines)


def write_report(report: Dict, path) -> None:
    Path(path).write_text(json.dumps(report, indent=2, sort_keys=True)
                          + "\n")
