"""Per-platform calibration profiles: fitted cost-model constants.

The tune→execute→measure loop (docs/calibration.md) fits the TIME-side
constants of the cost model — ``CostParams`` scalars, ``KernelCoeffs``
anchors, ``InterferenceModel.factors`` — from measured step times, and
persists them as a versioned JSON ``CalibrationProfile`` keyed by
platform (``jax.default_backend()``: cpu / tpu / gpu).

``StageCostModel(profile=...)``, ``estimate_plan(profile=...)`` and
``TuneSpec.profile`` layer the profile's overrides over the frozen
defaults.  The DEFAULT profile carries no overrides and returns the
caller's ``CostParams`` object *unchanged* — the frozen-default
guarantee: every golden fixture is byte-identical with or without it
(tests/test_calibration.py asserts this).

Overrides are stored as sorted ``(name, value)`` tuples rather than
dicts so the dataclass stays hashable (``TuneSpec`` is frozen and is
pickled to sweep workers) and serialization is deterministic.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.core.costmodel import CostParams
from repro.core.costmodel_params import KernelCoeffs
from repro.core.interference import InterferenceModel

PROFILE_VERSION = 1

COST_FIELDS = tuple(f.name for f in dataclasses.fields(CostParams)
                    if f.name != "kernels")
KERNEL_FIELDS = tuple(f.name for f in dataclasses.fields(KernelCoeffs))

Overrides = Tuple[Tuple[str, float], ...]


def _as_overrides(d, allowed, what) -> Overrides:
    if not d:
        return ()
    d = dict(d)
    bad = sorted(set(d) - set(allowed))
    if bad:
        raise ValueError(f"unknown {what} field(s) {bad}; "
                         f"have {sorted(allowed)}")
    return tuple(sorted((k, float(v)) for k, v in d.items()))


@dataclass(frozen=True)
class CalibrationProfile:
    """Fitted constants for ONE platform.

    ``cost`` / ``kernels`` override individual ``CostParams`` /
    ``KernelCoeffs`` fields; ``interference`` replaces the slowdown-factor
    table wholesale (it is fit as a unit); ``jax_auto_threshold`` pins the
    tape-backend crossover for the platform.  Empty/None everywhere means
    "use the frozen defaults"."""
    version: int = PROFILE_VERSION
    platform: str = "default"
    source: str = "frozen-default"
    cost: Overrides = ()
    kernels: Overrides = ()
    interference: Tuple[Tuple[Tuple[int, ...], Tuple[float, ...]], ...] = ()
    jax_auto_threshold: Optional[int] = None

    @classmethod
    def make(cls, *, platform: str = "default", source: str = "measured",
             cost=None, kernels=None, interference=None,
             jax_auto_threshold: Optional[int] = None
             ) -> "CalibrationProfile":
        """Build from plain dicts, validating field names eagerly (a typo'd
        override must fail at fit time, not silently at apply time)."""
        intf: Tuple = ()
        if interference:
            items = (interference.items() if isinstance(interference, dict)
                     else interference)
            intf = tuple(sorted(
                (tuple(int(i) for i in combo),
                 tuple(float(x) for x in fac)) for combo, fac in items))
        return cls(
            platform=platform, source=source,
            cost=_as_overrides(cost, COST_FIELDS, "CostParams"),
            kernels=_as_overrides(kernels, KERNEL_FIELDS, "KernelCoeffs"),
            interference=intf,
            jax_auto_threshold=(None if jax_auto_threshold is None
                                else int(jax_auto_threshold)))

    # -- incremental updates -------------------------------------------------
    def with_cost(self, **overrides) -> "CalibrationProfile":
        """This profile with additional/updated ``CostParams`` overrides
        merged in (e.g. ``runtime_reserved`` from
        ``tools/calibrate_reserved.py`` folding into a profile fitted by
        ``tools/calibrate.py``).  Field names are validated; existing
        overrides for other fields are preserved."""
        merged = dict(self.cost)
        merged.update(overrides)
        return dataclasses.replace(
            self, cost=_as_overrides(merged, COST_FIELDS, "CostParams"))

    # -- application ---------------------------------------------------------
    def cost_params(self, base: CostParams = CostParams()) -> CostParams:
        """``base`` with this profile's overrides applied.  The no-override
        profile returns ``base`` ITSELF (not a copy) — the frozen-default
        guarantee the golden fixtures rely on."""
        if not self.cost and not self.kernels:
            return base
        kw: Dict[str, float] = dict(self.cost)
        out = dataclasses.replace(base, **kw) if kw else base
        if self.kernels:
            out = dataclasses.replace(
                out, kernels=base.kernels.replace(**dict(self.kernels)))
        return out

    def kernel_coeffs(self, base: KernelCoeffs = KernelCoeffs()
                      ) -> KernelCoeffs:
        return base.replace(**dict(self.kernels)) if self.kernels else base

    def interference_model(self) -> InterferenceModel:
        m = InterferenceModel()
        if self.interference:
            m.factors = {tuple(c): tuple(f) for c, f in self.interference}
        return m

    # -- serialization -------------------------------------------------------
    def to_doc(self) -> Dict:
        return {
            "version": self.version,
            "platform": self.platform,
            "source": self.source,
            "cost": dict(self.cost),
            "kernels": dict(self.kernels),
            "interference": {",".join(str(i) for i in c): list(f)
                             for c, f in self.interference},
            "jax_auto_threshold": self.jax_auto_threshold,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_doc(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        doc = json.loads(text)
        version = int(doc.get("version", 0))
        if version > PROFILE_VERSION:
            raise ValueError(f"calibration profile version {version} is "
                             f"newer than supported {PROFILE_VERSION}")
        intf = {tuple(int(i) for i in key.split(",")): tuple(fac)
                for key, fac in (doc.get("interference") or {}).items()}
        return cls.make(
            platform=doc.get("platform", "default"),
            source=doc.get("source", "measured"),
            cost=doc.get("cost"), kernels=doc.get("kernels"),
            interference=intf,
            jax_auto_threshold=doc.get("jax_auto_threshold"))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path) -> "CalibrationProfile":
        return cls.from_json(Path(path).read_text())


DEFAULT_PROFILE = CalibrationProfile()


# -- discovery ---------------------------------------------------------------


def profile_dir() -> Path:
    return Path(os.environ.get("REPRO_CALIBRATION_DIR",
                               "~/.cache/repro/calibration")).expanduser()


def profile_path(platform: str) -> Path:
    return profile_dir() / f"{platform}.json"


def default_platform() -> str:
    """The jax backend name, or "cpu" in a jax-free container."""
    from repro import compat
    if compat.has_jax():
        import jax
        return jax.default_backend()
    return "cpu"


def load_profile(platform: Optional[str] = None,
                 path=None) -> CalibrationProfile:
    """Resolve the active profile: explicit ``path`` >
    ``$REPRO_CALIBRATION_PROFILE`` > the per-platform file under
    ``$REPRO_CALIBRATION_DIR`` (default ``~/.cache/repro/calibration``) >
    the frozen ``DEFAULT_PROFILE``."""
    env_path = os.environ.get("REPRO_CALIBRATION_PROFILE")
    if path is not None or env_path:
        return CalibrationProfile.load(path or env_path)
    f = profile_path(platform or default_platform())
    if f.exists():
        return CalibrationProfile.load(f)
    return DEFAULT_PROFILE
