"""Measurement half of the calibration loop (docs/calibration.md).

Runs golden cells END-TO-END through the real execution path —
``lower_plan`` → ``make_train_step`` → compiled XLA steps on the live
mesh — and records, per cell:

* warmed median step wall time (``warmup`` discarded steps, then
  ``steps`` timed steps with ``block_until_ready``; the median resists
  host-side jitter),
* the compiled executable's memory analysis (argument + temp + output −
  alias, per device — the ``tools/calibrate_reserved.py`` protocol) and
  the live allocator's peak where the backend keeps one (TPU/GPU).

Cells are REDUCED same-family configs of the golden-fixture archs (the
``launch/train.py --smoke`` convention) in several plan variants chosen
to exercise distinct time-tape item mixes: pure-DP ZeRO-0 (compute +
one grad all-reduce), ZeRO-2 + full recompute (per-microbatch
reduce-scatter + recompute time), and TP=2 (per-layer collectives) when
the head counts divide.  Cells that fail to lower/execute are returned
as a skip list with reasons, never silently dropped.

CPU caveat: XLA:CPU legalizes bf16 compute to f32 and overlaps nothing,
so measured times are *host* ground truth — exactly what a cpu-platform
profile should fit, and far from the V5E defaults (which is what the
uncalibrated-vs-fitted error spread in ``benchmarks/accuracy.py
--measured`` demonstrates).  Re-run on a real accelerator host to fit a
tpu/gpu profile.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig, get_arch
from repro.core.plan import Plan, single_stage_plan

GOLDEN_ARCHS = ("granite-3-8b", "qwen2-moe-a2.7b")
DEFAULT_SEQ = 128


@dataclass
class MeasuredCell:
    """One executed cell: the plan that ran plus what the hardware said."""
    label: str
    arch: str                 # full arch name; config() re-derives reduced
    reduced: bool
    seq_len: int
    global_batch: int
    plan: Plan
    steps: int
    step_seconds: Tuple[float, ...]
    t_measured: float         # warmed median step seconds
    memory: Dict[str, Optional[float]] = field(default_factory=dict)

    def config(self) -> ArchConfig:
        cfg = get_arch(self.arch)
        return cfg.reduced() if self.reduced else cfg

    def shape(self) -> ShapeConfig:
        return ShapeConfig(self.label, self.seq_len, self.global_batch,
                           "train")

    def to_doc(self) -> Dict:
        return {
            "label": self.label, "arch": self.arch, "reduced": self.reduced,
            "seq_len": self.seq_len, "global_batch": self.global_batch,
            "plan": json.loads(self.plan.to_json()),
            "steps": self.steps, "step_seconds": list(self.step_seconds),
            "t_measured": self.t_measured, "memory": dict(self.memory),
        }


def _cell_plans(cfg: ArchConfig, n_dev: int) -> List[Tuple[str, Plan]]:
    """Plan variants for one arch on ``n_dev`` host devices, each lighting
    up a different subset of time-tape items."""
    L = cfg.num_layers
    G = 2
    out = [
        (f"dp{n_dev}_z0", single_stage_plan(
            L, dp=n_dev, tp=1, micro_batch=1, grad_accum=G,
            zero=0, ckpt_layers=0)),
        (f"dp{n_dev}_z2_ckpt", single_stage_plan(
            L, dp=n_dev, tp=1, micro_batch=1, grad_accum=G,
            zero=2, ckpt_layers=L)),
    ]
    if n_dev % 2 == 0 and n_dev >= 2 and cfg.num_heads % 2 == 0:
        out.append((f"dp{n_dev // 2}_tp2_z1", single_stage_plan(
            L, dp=n_dev // 2, tp=2, micro_batch=1, grad_accum=G,
            zero=1, ckpt_layers=L // 2)))
    return out


def measure_plan(cfg: ArchConfig, shape: ShapeConfig, plan: Plan, *,
                 steps: int = 4, warmup: int = 2
                 ) -> Tuple[float, Tuple[float, ...],
                            Dict[str, Optional[float]]]:
    """Execute one cell and return (median step seconds, all step times,
    memory stats).  Same execution path as ``launch/train.py --smoke``."""
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.launch.mesh import make_host_mesh
    from repro.lowering import lower_plan
    from repro.models.zoo import build_model
    from repro.training.data import BatchSpec, SyntheticLM
    from repro.training.step import init_sharded_state, make_train_step

    st0 = plan.stages[0]
    mesh = make_host_mesh(st0.dp * st0.tp, st0.tp)
    model = build_model(cfg)
    low = lower_plan(cfg, shape, plan, mesh)
    mem: Dict[str, Optional[float]] = {
        "modeled_peak_bytes": float(low.memory_report().peak_bytes)}
    with compat.set_mesh(mesh):
        step = make_train_step(model, plan, mesh, lowered=low)
        state, _shardings = init_sharded_state(
            model, plan, mesh, jax.random.PRNGKey(0), lowered=low)
        data = SyntheticLM(BatchSpec(global_batch=shape.global_batch,
                                     seq_len=shape.seq_len,
                                     vocab_size=cfg.vocab_size))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        try:
            ma = step.fn.lower(state, batch).compile().memory_analysis()
            mem["executable_bytes"] = float(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        except Exception:           # backend exposes no analysis: optional
            mem["executable_bytes"] = None
        for _ in range(max(1, warmup)):
            state, _metrics = step.fn(state, batch)
        jax.block_until_ready(state)
        times: List[float] = []
        for _ in range(max(1, steps)):
            t0 = time.perf_counter()
            state, _metrics = step.fn(state, batch)
            jax.block_until_ready(state)
            times.append(time.perf_counter() - t0)
        dev = jax.devices()[0]
        stats = dev.memory_stats() if hasattr(dev, "memory_stats") else None
        mem["allocator_peak_bytes"] = (stats or {}).get("peak_bytes_in_use")
    return sorted(times)[len(times) // 2], tuple(times), mem


def measure_cells(archs: Sequence[str] = GOLDEN_ARCHS, *,
                  steps: int = 4, warmup: int = 2,
                  seq_len: int = DEFAULT_SEQ, reduced: bool = True,
                  max_cells_per_arch: Optional[int] = None
                  ) -> Tuple[List[MeasuredCell], List[Dict]]:
    """Measure every cell variant of every arch on the current devices.

    Returns ``(cells, skipped)`` — skipped entries carry the failure
    reason so callers can report them (no-silent-caps)."""
    import jax

    n_dev = len(jax.devices())
    cells: List[MeasuredCell] = []
    skipped: List[Dict] = []
    for arch in archs:
        cfg = get_arch(arch)
        cfg_run = cfg.reduced() if reduced else cfg
        plans = _cell_plans(cfg_run, n_dev)
        if max_cells_per_arch is not None:
            dropped = plans[max_cells_per_arch:]
            skipped += [{"arch": arch, "label": lbl,
                         "error": "capped by max_cells_per_arch"}
                        for lbl, _ in dropped]
            plans = plans[:max_cells_per_arch]
        for label, plan in plans:
            st0 = plan.stages[0]
            gbs = st0.dp * st0.micro_batch * plan.grad_accum
            shape = ShapeConfig(label, seq_len, gbs, "train")
            try:
                t_med, ts, mem = measure_plan(cfg_run, shape, plan,
                                              steps=steps, warmup=warmup)
            except Exception as exc:
                skipped.append({"arch": arch, "label": label,
                                "error": f"{type(exc).__name__}: {exc}"})
                continue
            cells.append(MeasuredCell(
                label=f"{arch}/{label}", arch=arch, reduced=reduced,
                seq_len=seq_len, global_batch=gbs, plan=plan,
                steps=steps, step_seconds=ts, t_measured=t_med,
                memory=mem))
    return cells, skipped
