"""Measurement-driven calibration of the cost model (docs/calibration.md).

Three stages close the tune→execute→measure loop:

* ``measure`` — run golden cells end-to-end through ``lower_plan`` →
  ``make_train_step`` and record warmed median step times + allocator /
  executable memory stats.
* ``fit`` — attribute the measurements back to the named time-tape items,
  fit ``CostParams`` group scales, refit ``InterferenceModel.factors``
  via its ``calibrate()``, and anchor ``KernelCoeffs``.
* ``profile`` — persist the result as a per-platform JSON
  ``CalibrationProfile`` consumed by ``StageCostModel`` / ``TuneSpec``.

Only ``profile`` is imported eagerly (numpy-only); ``measure``/``fit``
and the ``driver`` import jax lazily so the package is safe to import
anywhere the core is.
"""
from repro.calibration.profile import (DEFAULT_PROFILE, PROFILE_VERSION,
                                       CalibrationProfile, default_platform,
                                       load_profile, profile_dir,
                                       profile_path)

__all__ = [
    "CalibrationProfile", "DEFAULT_PROFILE", "PROFILE_VERSION",
    "default_platform", "load_profile", "profile_dir", "profile_path",
]
