"""Mixture-of-Experts block: top-k routing, capacity-based scatter dispatch
(no (T,E,C) one-hot materialization), expert-parallel shardable, shared
experts (Qwen2-MoE style), Switch-style load-balancing aux loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ExecConfig, Params, ScopedBuilder, shard_act


def init_moe(b: ScopedBuilder, cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    b.add("router", (d, e), ("embed", "expert"), scale=1.0 / math.sqrt(d))
    b.add("wg", (e, d, f), ("expert", "embed", "mlp"))
    b.add("wu", (e, d, f), ("expert", "embed", "mlp"))
    b.add("wd", (e, f, d), ("expert", "mlp", "embed"))
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        b.add("shared_wg", (d, fs), ("embed", "mlp"))
        b.add("shared_wu", (d, fs), ("embed", "mlp"))
        b.add("shared_wd", (fs, d), ("mlp", "embed"))
        b.add("shared_gate", (d, 1), ("embed", None), scale=1.0 / math.sqrt(d))


def _capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.num_experts_per_tok
                      * cfg.capacity_factor / cfg.num_experts))
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def moe(p: Params, x: jax.Array, cfg: ArchConfig, ec: ExecConfig
        ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    bsz, seq, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = bsz * seq
    tg = min(ec.moe_group_size, t)
    g = t // tg
    assert g * tg == t, f"tokens {t} not divisible by group {tg}"
    xg = x.reshape(g, tg, d)
    xg = shard_act(xg, ("dp", None, None))

    logits = jnp.einsum("gtd,de->gte", xg, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, -1)                       # (G,Tg,E) f32
    gates, idx = jax.lax.top_k(probs, k)                     # (G,Tg,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32),
                       axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)

    cap = _capacity(tg, cfg)
    # position of each (token, choice) within its expert, per group
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (G,Tg,k,E)
    ohf = oh.reshape(g, tg * k, e)
    pos = jnp.cumsum(ohf, axis=1) - ohf
    pos = (pos.reshape(g, tg, k, e) * oh).sum(-1)            # (G,Tg,k)
    keep = pos < cap

    # einsum dispatch/combine (Mesh-TF style): one-hot dispatch (G,Tg,E,C)
    # and gate-weighted combine tensors, built per top-k choice.  Scatter /
    # gather forms lower to dense f32 one-hot expansions under SPMD (6 GiB
    # temporaries per layer at dbrx scale); the einsum form stays in the
    # compute dtype, shards over the expert axis, and has clean transposes.
    disp = jnp.zeros((g, tg, e, cap), x.dtype)
    comb = jnp.zeros((g, tg, e, cap), jnp.float32)
    for j in range(k):                                       # k is 2..4
        sel = (jax.nn.one_hot(idx[:, :, j], e, dtype=x.dtype)
               * keep[:, :, j, None].astype(x.dtype))        # (G,Tg,E)
        slot = jax.nn.one_hot(pos[:, :, j], cap, dtype=x.dtype)
        dj = sel[..., None] * slot[:, :, None, :]            # (G,Tg,E,C)
        disp = disp + dj
        comb = comb + dj.astype(jnp.float32) \
            * gates[:, :, j, None, None].astype(jnp.float32)
    disp = shard_act(disp, ("dp", None, "expert", None))
    comb = shard_act(comb, ("dp", None, "expert", None))

    buf = jnp.einsum("gtec,gtd->gecd", disp, xg)             # (G,E,C,D)
    buf = shard_act(buf, ("dp", "expert", None, None))
    h = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"])
    h = shard_act(h, ("dp", "expert", None, None))
    yb = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    yb = shard_act(yb, ("dp", "expert", None, None))

    y = jnp.einsum("gtec,gecd->gtd", comb.astype(yb.dtype), yb)
    out = y.reshape(bsz, seq, d)

    if cfg.num_shared_experts:
        hs = xg.reshape(bsz, seq, d)
        a = jax.nn.silu(hs @ p["shared_wg"]) if cfg.act == "silu" else \
            jax.nn.gelu(hs @ p["shared_wg"])
        sh = (a * (hs @ p["shared_wu"])) @ p["shared_wd"]
        sgate = jax.nn.sigmoid(hs @ p["shared_gate"])
        out = out + sh * sgate.astype(sh.dtype)
    return out, aux.astype(jnp.float32)
