"""Whisper-style encoder-decoder backbone.  The conv1d audio frontend is a
STUB: ``input_specs()`` supplies precomputed frame embeddings (B, T_enc, D).
[arXiv:2212.04356]
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.common import (Axes, ExecConfig, ParamBuilder, Params,
                                 StackedBuilder, name_act,
                                 segmented_layer_scan, shard_act, subtree)
from repro.models.decoder import chunked_xent

MAX_DECODER_POS = 32_768


def sinusoid_pos(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encdec(rng: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16,
                abstract: bool = False) -> Tuple[Params, Axes]:
    pb = ParamBuilder(rng, dtype, abstract=abstract)
    d = cfg.d_model
    pb.add("embed/w", (cfg.vocab_size, d), ("vocab", "embed"), scale=0.02)
    pb.add("pos_dec/w", (MAX_DECODER_POS, d), (None, "embed"), scale=0.02)

    eb = StackedBuilder(pb, "encoder/layers", cfg.encoder_layers)
    L.init_norm(eb.scope("ln1"), cfg)
    L.init_attention(eb.scope("attn"), cfg)
    L.init_norm(eb.scope("ln2"), cfg)
    L.init_mlp(eb.scope("mlp"), cfg)
    L.init_norm(pb.scope("encoder/final_norm"), cfg)

    db = StackedBuilder(pb, "decoder/layers", cfg.num_layers)
    L.init_norm(db.scope("ln1"), cfg)
    L.init_attention(db.scope("self_attn"), cfg)
    L.init_norm(db.scope("lnx"), cfg)
    L.init_attention(db.scope("cross_attn"), cfg)
    L.init_norm(db.scope("ln2"), cfg)
    L.init_mlp(db.scope("mlp"), cfg)
    L.init_norm(pb.scope("decoder/final_norm"), cfg)
    return pb.params, pb.axes


def encode(params: Params, frames: jax.Array, cfg: ArchConfig, ec: ExecConfig
           ) -> jax.Array:
    """frames (B, T_enc, D) precomputed (stub frontend) -> encoder output."""
    x = frames.astype(ec.compute_dtype) + \
        sinusoid_pos(frames.shape[1], cfg.d_model).astype(ec.compute_dtype)
    x = shard_act(x, ("dp", None, None))
    stacked = subtree(params, "encoder/layers")

    def body(carry, lp):
        h, = carry
        hn = L.norm(subtree(lp, "ln1"), h, cfg)
        a, _ = L.attention(subtree(lp, "attn"), hn, cfg, ec, mask_kind="full")
        h = h + a
        hn = L.norm(subtree(lp, "ln2"), h, cfg)
        h = h + L.mlp(subtree(lp, "mlp"), hn, cfg)
        h = name_act(shard_act(h, ("dp", None, None)), "resid")
        return (h,)

    (h,) = segmented_layer_scan(body, (x,), stacked, cfg.encoder_layers, ec)
    return L.norm(subtree(params, "encoder/final_norm"), h, cfg)


def _decoder_block(lp: Params, h: jax.Array, enc_out, cfg, ec,
                   self_cache=None, cross_cache=None, pos0: int = 0,
                   return_cache: bool = False):
    hn = L.norm(subtree(lp, "ln1"), h, cfg)
    a, new_self = L.attention(subtree(lp, "self_attn"), hn, cfg, ec,
                              cache=self_cache)
    if return_cache and self_cache is None:
        from repro.models.decoder import _fresh_attn_cache
        new_self = _fresh_attn_cache(subtree(lp, "self_attn"), hn, cfg)
    h = h + a
    hn = L.norm(subtree(lp, "lnx"), h, cfg)
    if cross_cache is not None:
        a, new_cross = L.attention(subtree(lp, "cross_attn"), hn, cfg, ec,
                                   cache=cross_cache)
    else:
        a, _ = L.attention(subtree(lp, "cross_attn"), hn, cfg, ec,
                           mask_kind="full", kv_x=enc_out)
        new_cross = None
        if return_cache:
            pa = subtree(lp, "cross_attn")
            k = jnp.einsum("bsd,dhk->bshk", enc_out, pa["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, pa["wv"])
            new_cross = {"k": k, "v": v}
    h = h + a
    hn = L.norm(subtree(lp, "ln2"), h, cfg)
    h = h + L.mlp(subtree(lp, "mlp"), hn, cfg)
    h = name_act(shard_act(h, ("dp", "sp", None)), "resid")
    return h, new_self, new_cross


def encdec_loss(params: Params, batch: Dict, cfg: ArchConfig, ec: ExecConfig
                ) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, ec)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = jnp.take(params["embed/w"], tokens, axis=0).astype(ec.compute_dtype)
    x = x + params["pos_dec/w"][:s].astype(ec.compute_dtype)
    x = shard_act(x, ("dp", "sp", None))
    stacked = subtree(params, "decoder/layers")

    def body(carry, lp):
        h, = carry
        h, _, _ = _decoder_block(lp, h, enc_out, cfg, ec)
        return (h,)

    (h,) = segmented_layer_scan(body, (x,), stacked, cfg.num_layers, ec)
    h = L.norm(subtree(params, "decoder/final_norm"), h, cfg)
    return chunked_xent(h, params["embed/w"].T, batch["labels"],
                        batch.get("loss_mask"))


def encdec_prefill(params: Params, batch: Dict, cfg: ArchConfig,
                   ec: ExecConfig, return_cache: bool = False):
    enc_out = encode(params, batch["frames"], cfg, ec)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = jnp.take(params["embed/w"], tokens, axis=0).astype(ec.compute_dtype)
    x = x + params["pos_dec/w"][:s].astype(ec.compute_dtype)
    x = shard_act(x, ("dp", "sp", None))
    stacked = subtree(params, "decoder/layers")

    if not return_cache:
        def body(carry, lp):
            h, = carry
            h, _, _ = _decoder_block(lp, h, enc_out, cfg, ec)
            return (h,)

        (h,) = segmented_layer_scan(body, (x,), stacked, cfg.num_layers, ec)
        h = L.norm(subtree(params, "decoder/final_norm"), h, cfg)
        logits = (h[:, -1:] @ params["embed/w"].T).astype(jnp.float32)
        return shard_act(logits, ("dp", None, "tp"))

    def body(carry, lp):
        h, = carry
        h, sc, cc = _decoder_block(lp, h, enc_out, cfg, ec, return_cache=True)
        return (h,), {"self": sc, "cross": cc}

    (h,), caches = jax.lax.scan(body, (x,), stacked)
    h = L.norm(subtree(params, "decoder/final_norm"), h, cfg)
    logits = (h[:, -1:] @ params["embed/w"].T).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), caches


def encdec_decode(params: Params, tokens: jax.Array, caches, cfg: ArchConfig,
                  ec: ExecConfig):
    """caches: {"self": stacked self KV (+pos), "cross": stacked cross KV}."""
    x = jnp.take(params["embed/w"], tokens, axis=0).astype(ec.compute_dtype)
    p0 = caches["self"]["pos"][0]
    if p0.ndim:  # per-request decode positions (continuous batching)
        x = x + jnp.take(params["pos_dec/w"], p0, axis=0
                         ).astype(ec.compute_dtype)[:, None]
    else:
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec/w"], p0, 1
                                             ).astype(ec.compute_dtype)[None]
    stacked = subtree(params, "decoder/layers")

    def body(h, xs):
        lp, sc, cc = xs
        h, new_self, new_cross = _decoder_block(lp, h, None, cfg, ec,
                                                self_cache=sc, cross_cache=cc)
        return h, {"self": new_self, "cross": new_cross}

    h, new_caches = jax.lax.scan(body, x,
                                 (stacked, caches["self"], caches["cross"]))
    h = L.norm(subtree(params, "decoder/final_norm"), h, cfg)
    logits = (h @ params["embed/w"].T).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), new_caches


def init_encdec_caches(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    selfc = L.init_self_kv_cache(cfg, batch, max_len, dtype)
    crossc = {
        "k": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads,
                        cfg.head_dim), dtype),
    }
    ld = cfg.num_layers
    return {
        "self": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (ld,) + v.shape), selfc),
        "cross": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (ld,) + v.shape), crossc),
    }
