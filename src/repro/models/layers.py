"""Core layers: norms, rotary embeddings, MLPs, GQA and MLA attention.

Every layer is an (init, apply) pair over flat-dict params.  Attention
supports train/prefill (full sequence, causal or bidirectional) and decode
(one token against a KV cache) with MLA using the absorbed-matmul decode path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (ExecConfig, Params, ScopedBuilder, shard_act)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(b: ScopedBuilder, cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    b.add("scale", (d,), ("embed",), init="ones")
    if cfg.norm_type == "layernorm":
        b.add("bias", (d,), ("embed",), init="zeros")


def norm(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x + p["bias"].astype(jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary
# ---------------------------------------------------------------------------


def rope_freqs(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., dim//2), f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, d); cos/sin (..., S, d//2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(b: ScopedBuilder, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_gated:
        b.add("wg", (d, f), ("embed", "mlp"))
        b.add("wu", (d, f), ("embed", "mlp"))
    else:
        b.add("wu", (d, f), ("embed", "mlp"))
        b.add("bu", (f,), ("mlp",), init="zeros")
        b.add("bd", (d,), ("embed",), init="zeros")
    b.add("wd", (f, d), ("mlp", "embed"))


def _act(x: jax.Array, kind: str) -> jax.Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.mlp_gated:
        h = _act(x @ p["wg"], cfg.act) * (x @ p["wu"])
        h = shard_act(h, ("dp", None, "tp"))
        return h @ p["wd"]
    h = _act(x @ p["wu"] + p["bu"], cfg.act)
    h = shard_act(h, ("dp", None, "tp"))
    return h @ p["wd"] + p["bd"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def init_attention(b: ScopedBuilder, cfg: ArchConfig):
    if cfg.attention_type == "mla":
        return init_mla(b, cfg)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b.add("wq", (d, h, hd), ("embed", "heads", "head_dim"),
          scale=1.0 / math.sqrt(d))
    b.add("wk", (d, kv, hd), ("embed", "kv_heads", "head_dim"),
          scale=1.0 / math.sqrt(d))
    b.add("wv", (d, kv, hd), ("embed", "kv_heads", "head_dim"),
          scale=1.0 / math.sqrt(d))
    b.add("wo", (h, hd, d), ("heads", "head_dim", "embed"),
          scale=1.0 / math.sqrt(h * hd))
    if cfg.qkv_bias:
        b.add("bq", (h, hd), ("heads", "head_dim"), init="zeros")
        b.add("bk", (kv, hd), ("kv_heads", "head_dim"), init="zeros")
        b.add("bv", (kv, hd), ("kv_heads", "head_dim"), init="zeros")


def _sdpa(q, k, v, cfg: ArchConfig, mask_kind: str, q_pos0=None,
          kv_valid_len=None, acc_dtype=jnp.float32) -> jax.Array:
    """q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd) -> (B,Sq,KV,G,hd).  f32 softmax.

    acc_dtype: QK^T accumulation type.  The decode path passes the cache
    dtype: on the CPU host-compile target an f32-accumulating dot makes XLA
    legalize bf16 operands with a convert that LICM hoists out of the layer
    scan — materializing a full f32 copy of the KV cache.  (On TPU the MXU
    accumulates bf16 x bf16 -> f32 natively; softmax stats stay f32 here
    either way.)"""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=acc_dtype
                        ).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    if mask_kind == "causal":
        qp = jnp.arange(sq) + (q_pos0 if q_pos0 is not None else 0)
        mask = qp[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_valid_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_valid_len
        scores = jnp.where(valid[:, None, None, None] if valid.ndim == 2
                           else valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)


def attention(p: Params, x: jax.Array, cfg: ArchConfig, exec_cfg: ExecConfig,
              *, positions: Optional[jax.Array] = None, mask_kind="causal",
              cache: Optional[Dict] = None, kv_x: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """GQA attention.  train/prefill: cache=None; decode: cache holds
    {"k","v","pos"} and x is (B,1,D).  kv_x: cross-attention source."""
    if cfg.attention_type == "mla":
        return mla_attention(p, x, cfg, exec_cfg, positions=positions,
                             cache=cache)
    b_, s, d = x.shape
    h, kv, hd, g = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.q_per_kv
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    has_kv_cache = cache is not None and "pos" not in cache  # cross-attn cache
    if not has_kv_cache:
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
    q = shard_act(q, ("dp", None, "tp", None))

    is_cross = kv_x is not None or (cache is not None and "pos" not in cache)
    use_rope = mask_kind == "causal" and not is_cross
    if use_rope:
        if positions is not None:
            pos_q = positions
        elif cache is None:
            pos_q = jnp.arange(s)[None, :]
        elif cache["pos"].ndim == 1:
            # per-request decode positions (continuous batching): every
            # row rotates by its own offset
            pos_q = cache["pos"][:, None]
        else:
            pos_q = jnp.full((1, 1), cache["pos"], jnp.int32)
        cos_q, sin_q = rope_freqs(pos_q, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)

    new_cache = None
    if cache is None:
        if use_rope:
            cos_k, sin_k = rope_freqs(jnp.arange(s)[None, :], hd, cfg.rope_theta)
            k = apply_rope(k, cos_k, sin_k)
        k = shard_act(k, ("dp", None, "tp", None))
        if exec_cfg.attn_impl != "naive":
            from repro.kernels import ops as kops
            ctx = kops.attention(q, k, v, causal=(mask_kind == "causal"),
                                 impl=exec_cfg.attn_impl,
                                 q_block=exec_cfg.attn_q_block,
                                 kv_block=exec_cfg.attn_kv_block)
            ctx = ctx.reshape(b_, s, kv, g, hd)
        else:
            qg = q.reshape(b_, s, kv, g, hd)
            ctx = _sdpa(qg, k, v, cfg, mask_kind)
    elif is_cross:  # cross-attention with precomputed k/v cache
        k, v = cache["k"], cache["v"]
        qg = q.reshape(b_, s, kv, g, hd)
        ctx = _sdpa(qg, k, v, cfg, "full")
        new_cache = cache
    else:  # self-attention decode
        pos = cache["pos"]
        vec = pos.ndim == 1  # per-request positions (continuous batching)
        if use_rope:
            pos_k = pos[:, None] if vec else jnp.full((1, 1), pos, jnp.int32)
            cos_k, sin_k = rope_freqs(pos_k, hd, cfg.rope_theta)
            k = apply_rope(k, cos_k, sin_k)
        new_cache = {"pos": pos + 1}
        if cache["k"].dtype == jnp.int8:   # quantized KV cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            ck = cache_write(cache["k"], kq, pos, 1, exec_cfg)
            cv = cache_write(cache["v"], vq, pos, 1, exec_cfg)
            kss = cache_write(cache["k_scale"], ks, pos, 1, exec_cfg)
            vss = cache_write(cache["v_scale"], vs, pos, 1, exec_cfg)
            new_cache.update(k=ck, v=cv, k_scale=kss, v_scale=vss)
            kd = dequantize_kv(ck, kss, x.dtype)
            vd = dequantize_kv(cv, vss, x.dtype)
        else:
            kd = ck = cache_write(cache["k"], k, pos, 1, exec_cfg)
            vd = cv = cache_write(cache["v"], v, pos, 1, exec_cfg)
            new_cache.update(k=ck, v=cv)
        qg = q.reshape(b_, s, kv, g, hd).astype(kd.dtype)
        ctx = _sdpa(qg, kd, vd, cfg, "full",
                    kv_valid_len=(pos[:, None] + 1) if vec else pos + 1,
                    acc_dtype=kd.dtype)

    ctx = ctx.reshape(b_, s, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return out, new_cache


def cache_write(buf: jax.Array, upd: jax.Array, pos: jax.Array,
                seq_dim: int, exec_cfg: ExecConfig) -> jax.Array:
    """Write a one-token update into the cache at `pos` along `seq_dim`.

    pos may be a scalar (lock-step decode) or a (B,) vector of per-request
    positions (continuous batching); the vector form always lowers to the
    one-hot masked write because DUS cannot express per-row start offsets.
    Both forms write the same values bitwise: `where` selects exact
    operands, so only the untouched-row representation differs."""
    upd = upd.astype(buf.dtype)
    if getattr(pos, "ndim", 0) == 1:
        assert upd.shape[seq_dim] == 1, "one-token decode writes only"
        oh = jnp.arange(buf.shape[seq_dim])[None, :] == pos[:, None]  # (B,S)
        shape = [1] * buf.ndim
        shape[0] = buf.shape[0]
        shape[seq_dim] = buf.shape[seq_dim]
        oh = oh.reshape(shape)
        return jnp.where(oh, jnp.broadcast_to(upd, buf.shape), buf)
    if exec_cfg.cache_update == "dus":
        start = [0] * buf.ndim
        start[seq_dim] = pos
        return jax.lax.dynamic_update_slice(buf, upd, tuple(start))
    # one-hot masked write: elementwise, so a 'model'-sharded sequence dim
    # stays fully local (GSPMD would replicate the equivalent DUS)
    assert upd.shape[seq_dim] == 1, "one-token decode writes only"
    oh = (jnp.arange(buf.shape[seq_dim]) == pos)
    shape = [1] * buf.ndim
    shape[seq_dim] = buf.shape[seq_dim]
    oh = oh.reshape(shape)
    return jnp.where(oh, jnp.broadcast_to(upd, buf.shape), buf)


def init_self_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> Dict:
    if cfg.attention_type == "mla":
        return {
            "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    out = {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if dtype == jnp.int8:
        # quantized KV: dynamic per-(token, head) scales (beyond-paper —
        # the only way 32k x 128 MHA caches fit a 16 GiB-chip pod)
        out["k_scale"] = jnp.zeros((batch, max_len, cfg.num_kv_heads),
                                   jnp.float32)
        out["v_scale"] = jnp.zeros((batch, max_len, cfg.num_kv_heads),
                                   jnp.float32)
    return out


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (..., KV, hd) -> (int8 values, f32 per-(.., KV) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(b: ScopedBuilder, cfg: ArchConfig):
    d, h = cfg.d_model, cfg.num_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b.add("wq_down", (d, ql), ("embed", "q_lora"))
    b.add("q_norm", (ql,), ("q_lora",), init="ones")
    b.add("wq_up", (ql, h, dn + dr), ("q_lora", "heads", "head_dim"))
    b.add("wkv_down", (d, kl + dr), ("embed", "kv_lora"))
    b.add("kv_norm", (kl,), ("kv_lora",), init="ones")
    b.add("wkv_up", (kl, h, dn + dv), ("kv_lora", "heads", "head_dim"))
    b.add("wo", (h, dv, d), ("heads", "head_dim", "embed"),
          scale=1.0 / math.sqrt(h * dv))


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def mla_attention(p: Params, x: jax.Array, cfg: ArchConfig,
                  exec_cfg: ExecConfig, *, positions=None, cache=None):
    b_, s, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    q = _rms(x @ p["wq_down"], p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", q, p["wq_up"])
    q = shard_act(q, ("dp", None, "tp", None))
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv = x @ p["wkv_down"]
    latent, k_rope = kv[..., :kl], kv[..., kl:]
    latent = _rms(latent, p["kv_norm"])

    if cache is None:
        pos = jnp.arange(s)[None, :] if positions is None else positions
        cos, sin = rope_freqs(pos, dr, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
        kvu = jnp.einsum("bsl,lhk->bshk", latent, p["wkv_up"])
        kvu = shard_act(kvu, ("dp", None, "tp", None))
        k_nope, v = kvu[..., :dn], kvu[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], (b_, s, h, dr))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        scores = jnp.einsum("bqhk,bskh->bhqs", qf,
                            k.transpose(0, 1, 3, 2),
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, -1)
        ctx = jnp.einsum("bhqs,bshv->bqhv", w.astype(v.dtype), v)
        new_cache = None
    else:
        # absorbed decode: score via latent cache, never expand K/V
        pos = cache["pos"]
        vec = pos.ndim == 1  # per-request positions (continuous batching)
        pos_r = pos[:, None] if vec else jnp.full((1, 1), pos, jnp.int32)
        cos, sin = rope_freqs(pos_r, dr, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
        lat_c = cache_write(cache["latent"], latent, pos, 1, exec_cfg)
        kr_c = cache_write(cache["k_rope"], k_rope, pos, 1, exec_cfg)
        wk = p["wkv_up"][..., :dn]  # (kl, h, dn)
        q_lat = jnp.einsum("bqhk,lhk->bqhl", q_nope, wk)
        scores = (jnp.einsum("bqhl,bsl->bhqs", q_lat, lat_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr_c,
                               preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(lat_c.shape[1])[None, :] <= (
            pos[:, None] if vec else pos)
        scores = jnp.where(valid[:, None, None] if vec else valid[None, None],
                           scores, NEG_INF)
        w = jax.nn.softmax(scores, -1)
        ctx_lat = jnp.einsum("bhqs,bsl->bqhl", w.astype(lat_c.dtype), lat_c)
        wv = p["wkv_up"][..., dn:]  # (kl, h, dv)
        ctx = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, wv)
        new_cache = {"latent": lat_c, "k_rope": kr_c, "pos": pos + 1}

    out = jnp.einsum("bshv,hvd->bsd", ctx, p["wo"])
    return out, new_cache
