"""Mamba2 (SSD) block: chunked state-space dual form for train/prefill and a
single-step recurrence for decode.  [arXiv:2405.21060]
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ExecConfig, Params, ScopedBuilder, shard_act


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def num_ssm_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def conv_dim(cfg: ArchConfig) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba2(b: ScopedBuilder, cfg: ArchConfig):
    d = cfg.d_model
    di, h, gn = d_inner(cfg), num_ssm_heads(cfg), cfg.ssm_groups * cfg.ssm_state
    d_proj = 2 * di + 2 * gn + h
    b.add("in_proj", (d, d_proj), ("embed", "inner"), scale=1.0 / math.sqrt(d))
    b.add("conv_w", (cfg.ssm_conv, conv_dim(cfg)), (None, "inner"),
          scale=1.0 / math.sqrt(cfg.ssm_conv))
    b.add("conv_b", (conv_dim(cfg),), ("inner",), init="zeros")
    b.add("A_log", (h,), ("heads",), init="zeros")
    b.add("D", (h,), ("heads",), init="ones")
    b.add("dt_bias", (h,), ("heads",), init="zeros")
    b.add("norm_scale", (di,), ("inner",), init="ones")
    b.add("out_proj", (di, d), ("inner", "embed"), scale=1.0 / math.sqrt(di))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv; x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    return (yf * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, bb: jax.Array,
                cc: jax.Array, chunk: int, h0: Optional[jax.Array] = None,
                use_pallas: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD over chunks.  xh (B,S,H,P); dt (B,S,H) f32; a (H,) f32 (negative);
    bb/cc (B,S,H,N).  Returns (y (B,S,H,P), final state (B,H,P,N) f32)."""
    bsz, s, h, p = xh.shape
    n = bb.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert nc * q == s, (s, q)

    if use_pallas and h0 is None:
        from repro.kernels import ops as kops
        return kops.ssd_scan(xh, dt, a, bb, cc, chunk=q)

    xc = xh.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = bb.reshape(bsz, nc, q, h, n)
    ccc = cc.reshape(bsz, nc, q, h, n)

    da = dtc * a  # (B,C,Q,H) f32
    cum = jnp.cumsum(da, axis=2)
    # intra-chunk (diagonal blocks).  NOTE: mask BEFORE the exp — above the
    # diagonal rel > 0 grows with |da| and exp(rel) overflows; masking after
    # the exp leaves inf*0 = NaN in the backward pass.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,C,Qt,Qs,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.exp(jnp.where(tri[None, None, :, :, None], rel, -jnp.inf))
    scores = jnp.einsum("bcqhn,bcshn->bcqsh", ccc, bc,
                        preferred_element_type=jnp.float32)
    scores = scores * l_mat * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcqsh,bcshp->bcqhp", scores.astype(xh.dtype), xc)

    # per-chunk input states
    wdec = jnp.exp(cum[:, :, -1:, :] - cum) * dtc            # (B,C,Q,H)
    states = jnp.einsum("bcqhn,bcqhp->bchpn",
                        (bc * wdec[..., None]).astype(xh.dtype), xc,
                        preferred_element_type=jnp.float32)  # (B,C,H,P,N) f32

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,C,H)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        nxt = carry * dec[:, :, None, None] + st
        return nxt, carry

    hT, h_prev = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (B,C,H,P,N)

    c_dec = (ccc * jnp.exp(cum)[..., None]).astype(xh.dtype)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", c_dec,
                       h_prev.astype(xh.dtype))
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, hT


def mamba2_mixer(p: Params, x: jax.Array, cfg: ArchConfig, ec: ExecConfig,
                 cache: Optional[Dict] = None, return_state: bool = False
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x (B,S,D) -> (out, new_cache).  cache: {"conv": (B,K-1,convdim),
    "ssm": (B,H,P,N) f32} for decode.  return_state: populate a cache from
    a prefill pass."""
    bsz, s, _ = x.shape
    di, h, p_, n, g = (d_inner(cfg), num_ssm_heads(cfg), cfg.ssm_head_dim,
                       cfg.ssm_state, cfg.ssm_groups)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_dim(cfg)]
    dt_raw = zxbcdt[..., di + conv_dim(cfg):]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if cache is None:
        xbc_raw = xbc
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xin = xbc[..., :di].reshape(bsz, s, h, p_)
        xin = shard_act(xin, ("dp", None, "tp", None))
        bb = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
        cc = xbc[..., di + g * n:].reshape(bsz, s, g, n)
        rep = h // g
        bb = jnp.repeat(bb, rep, axis=2)
        cc = jnp.repeat(cc, rep, axis=2)
        y, h_final = ssd_chunked(xin, dt, a, bb, cc, ec.ssd_chunk,
                                 use_pallas=ec.use_pallas and not return_state)
        y = y + p["D"].astype(y.dtype)[:, None] * xin
        new_cache = None
        if return_state:
            kw = cfg.ssm_conv - 1
            new_cache = {"conv": xbc_raw[:, -kw:], "ssm": h_final}
    else:
        # decode: conv ring buffer + single-step SSD recurrence
        conv_st = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,K,convdim)
        xbc1 = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_st, p["conv_w"])
                           + p["conv_b"])[:, None, :]
        xin = xbc1[..., :di].reshape(bsz, 1, h, p_)
        bb = xbc1[..., di:di + g * n].reshape(bsz, g, n)
        cc = xbc1[..., di + g * n:].reshape(bsz, g, n)
        rep = h // g
        bb = jnp.repeat(bb, rep, axis=1)
        cc = jnp.repeat(cc, rep, axis=1)
        dt1 = dt[:, 0]                                          # (B,H)
        dec = jnp.exp(dt1 * a)                                  # (B,H)
        hs = cache["ssm"] * dec[:, :, None, None] + \
            (dt1[:, :, None] * xin[:, 0].astype(jnp.float32)
             )[..., None] * bb[:, :, None, :].astype(jnp.float32)
        y = jnp.einsum("bhpn,bhn->bhp", hs.astype(x.dtype), cc)
        y = y + p["D"].astype(y.dtype)[:, None] * xin[:, 0]
        y = y[:, None]                                          # (B,1,H,P)
        new_cache = {"conv": conv_st[:, 1:], "ssm": hs}

    y = _gated_norm(y.reshape(bsz, s, di), z, p["norm_scale"])
    return y @ p["out_proj"], new_cache


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
        "ssm": jnp.zeros((batch, num_ssm_heads(cfg), cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }
