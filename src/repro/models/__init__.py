from repro.models.common import ExecConfig, Params, ShardRules, use_rules  # noqa: F401
from repro.models.zoo import Model, abstract_params, build_model, input_specs  # noqa: F401
