"""Shared model machinery.

Params are a *flat dict* ``{"path/to/param": Array}`` with a parallel
``{"path/to/param": (logical_axis | None, ...)}`` axes table.  Flat dicts make
sharding rules, ZeRO partitioning, host offloading slices, and checkpoint
manifests trivial, and stacked-layer arrays (leading ``L`` dim) keep HLO size
O(1) in depth via ``lax.scan``.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

Params = Dict[str, jax.Array]
Axes = Dict[str, Tuple[Optional[str], ...]]

# ---------------------------------------------------------------------------
# Execution config (runtime knobs; plan-dependent, never changes the math)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecConfig:
    ckpt_layers: int = 10**9          # layers rematerialized (clamped to L)
    attn_impl: str = "naive"          # naive | blocked | pallas
    offload_layers: int = 0           # of the remat'd layers, how many offload acts
    remat_policy: str = "full"        # full | dots | none
    use_pallas: bool = False          # Pallas kernels (TPU); jnp ref path otherwise
    moe_group_size: int = 4096
    # kernel tile/block sizes (plan.kernel -> stage_exec_config); the
    # defaults match core/plan.DEFAULT_KERNEL_CONFIG
    attn_q_block: int = 512
    attn_kv_block: int = 512
    rmsnorm_block: int = 256
    ssd_chunk: int = 256
    mlstm_chunk: int = 256
    compute_dtype: Any = jnp.bfloat16
    logits_dtype: Any = jnp.float32
    sequence_parallel: bool = True
    # decode KV-cache write: "dus" (dynamic-update-slice; optimal when the
    # sequence dim is unsharded) or "onehot" (elementwise masked write; stays
    # local when the cache sequence dim is sharded over 'model' — GSPMD
    # replicates a DUS whose updated dim is sharded)
    cache_update: str = "dus"

    def replace(self, **kw) -> "ExecConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Logical-axis sharding context
# ---------------------------------------------------------------------------
# Models annotate activations with *logical* axes; the step builder installs a
# rules object mapping logical -> physical mesh axes.  Without rules installed
# (pure CPU smoke tests) annotations are no-ops.


@dataclass(frozen=True)
class ShardRules:
    """logical axis name -> physical mesh axis (or tuple of axes)."""

    mapping: Dict[str, Any]
    mesh: Any = None

    def spec_for(self, logical: Sequence[Optional[str]]):
        from jax.sharding import PartitionSpec as P

        return P(*[self.mapping.get(a) if a else None for a in logical])


_RULES: contextvars.ContextVar[Optional[ShardRules]] = contextvars.ContextVar(
    "shard_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardRules]):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def current_rules() -> Optional[ShardRules]:
    return _RULES.get()


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding by logical axes; no-op without rules."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = rules.spec_for(logical)
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Param builder
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Creates params and records their logical axes as it goes.

    ``abstract=True`` records ShapeDtypeStructs instead of allocating —
    used by the dry-run / tuner, which never materialize weights.
    """

    def __init__(self, rng: Optional[jax.Array], dtype=jnp.bfloat16,
                 abstract: bool = False):
        self._rng = rng
        self.dtype = dtype
        self.abstract = abstract
        self.params: Params = {}
        self.axes: Axes = {}

    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def add(self, name: str, shape: Tuple[int, ...],
            axes: Tuple[Optional[str], ...], init: str = "normal",
            scale: Optional[float] = None, dtype=None) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        assert name not in self.params, f"duplicate param {name}"
        dtype = dtype or self.dtype
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, dtype)
            self.axes[name] = tuple(axes)
            return
        if init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "normal":
            if scale is None:  # fan-in scaling
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(1, fan_in))
            v = (jax.random.normal(self._next_rng(), shape, jnp.float32)
                 * scale).astype(dtype)
        else:
            raise ValueError(init)
        self.params[name] = v
        self.axes[name] = tuple(axes)

    def scope(self, prefix: str) -> "ScopedBuilder":
        return ScopedBuilder(self, prefix)


class ScopedBuilder:
    def __init__(self, parent, prefix: str):
        self._p = parent
        self._prefix = prefix

    def add(self, name, *a, **kw):
        self._p.add(f"{self._prefix}/{name}", *a, **kw)

    def scope(self, prefix: str):
        return ScopedBuilder(self._p, f"{self._prefix}/{prefix}")

    @property
    def dtype(self):
        return self._p.dtype


class StackedBuilder(ScopedBuilder):
    """Adds params with leading stacked-layer dim(s) (for lax.scan).

    ``num_layers`` may be an int or a tuple (nested scans, e.g. Zamba2's
    (groups, layers-per-group)).
    """

    def __init__(self, parent, prefix: str, num_layers):
        super().__init__(parent, prefix)
        self._L = (num_layers,) if isinstance(num_layers, int) else tuple(num_layers)

    def add(self, name, shape, axes, **kw):
        lead_axes = tuple(f"layers{i if i else ''}" for i in range(len(self._L)))
        super().add(name, self._L + tuple(shape), lead_axes + tuple(axes), **kw)

    def scope(self, prefix: str):
        return StackedBuilder(self._p, f"{self._prefix}/{prefix}", self._L)


# -- flat-dict utilities -----------------------------------------------------


def subtree(params: Params, prefix: str) -> Params:
    """View of all params under ``prefix/`` with the prefix stripped."""
    pl = prefix + "/"
    return {k[len(pl):]: v for k, v in params.items() if k.startswith(pl)}


def stack_layer_tree(trees: Sequence[Params]) -> Params:
    """Stack per-layer flat dicts into one dict of (L, ...) arrays."""
    keys = trees[0].keys()
    return {k: jnp.stack([t[k] for t in trees]) for k in keys}


# ---------------------------------------------------------------------------
# Segmented scan with per-segment remat wrapping (CKPT_i / AO_i realization)
# ---------------------------------------------------------------------------


def _remat_wrap(body: Callable, policy: str, offload: bool) -> Callable:
    if policy == "none" and not offload:
        return body
    if offload:
        from repro import compat
        if compat.host_memory_kind() is None:
            # no separate host memory space on this backend: keep the same
            # saved/recomputed segmentation, resident instead of offloaded
            pol = jax.checkpoint_policies.save_only_these_names(
                "resid", "layer_in")
        else:
            pol = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["resid", "layer_in"],
                offload_src="device", offload_dst=compat.host_memory_kind())
    elif policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    elif policy == "full":
        pol = None  # full remat: save nothing
    else:
        raise ValueError(policy)
    return jax.checkpoint(body, policy=pol, prevent_cse=False)


def segmented_layer_scan(body: Callable, carry, stacked: Params,
                         num_layers: int, exec_cfg: ExecConfig,
                         extra_xs: Optional[Params] = None):
    """scan over stacked layers, split into [offload-remat | remat | saved].

    ``body(carry, layer_params, layer_idx_offset) -> carry`` is the layer fn.
    The first ``offload_layers`` rematerialize *and* offload their saved
    inputs to host; the next ``ckpt - offload`` only rematerialize; the rest
    save all intermediates (no remat).  This realizes Mist's (CKPT_i, AO_i)
    knobs as scan-split points.
    """
    ckpt = min(exec_cfg.ckpt_layers, num_layers)
    off = min(exec_cfg.offload_layers, ckpt)
    segments = []  # (start, stop, policy, offload)
    if off:
        segments.append((0, off, exec_cfg.remat_policy, True))
    if ckpt - off:
        segments.append((off, ckpt, exec_cfg.remat_policy, False))
    if num_layers - ckpt:
        segments.append((ckpt, num_layers, "none", False))

    def sliced(tree, lo, hi):
        return {k: v[lo:hi] for k, v in tree.items()}

    for lo, hi, policy, offload in segments:
        seg_body = _remat_wrap(
            lambda c, xs: (body(c, xs), None), policy, offload)
        xs = sliced(stacked, lo, hi)
        if extra_xs is not None:
            xs = (xs, sliced(extra_xs, lo, hi))
        carry, _ = jax.lax.scan(seg_body, carry, xs)
    return carry


def name_act(x: jax.Array, name: str) -> jax.Array:
    """Tag an activation for offload-aware remat policies."""
    return checkpoint_name(x, name)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy; logits (..., V) f32, labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
