"""Model registry: uniform (init / loss / prefill / decode / input_specs)
interface over every assigned architecture family.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, get_arch
from repro.models import decoder as DEC
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import vlm as VL
from repro.models.common import Axes, ExecConfig, Params


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]           # (rng) -> (params, axes)
    loss_fn: Callable[..., Any]        # (params, batch, ec) -> loss
    prefill_fn: Callable[..., Any]     # (params, batch, ec, return_cache=False)
    decode_fn: Callable[..., Any]      # (params, tokens, caches, ec)
    init_caches: Callable[..., Any]    # (batch, max_len) -> cache pytree


def build_model(arch: str | ArchConfig) -> Model:
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda rng, abstract=False: HY.init_hybrid(
                rng, cfg, abstract=abstract),
            loss_fn=lambda p, b, ec: HY.hybrid_loss(p, b, cfg, ec),
            prefill_fn=lambda p, b, ec, return_cache=False:
                HY.hybrid_prefill(p, b, cfg, ec, return_cache),
            decode_fn=lambda p, t, c, ec: HY.hybrid_decode(p, t, c, cfg, ec),
            init_caches=lambda batch, max_len, dtype=jnp.bfloat16:
                HY.init_hybrid_caches(cfg, batch, max_len, dtype),
        )
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda rng, abstract=False: ED.init_encdec(
                rng, cfg, abstract=abstract),
            loss_fn=lambda p, b, ec: ED.encdec_loss(p, b, cfg, ec),
            prefill_fn=lambda p, b, ec, return_cache=False:
                ED.encdec_prefill(p, b, cfg, ec, return_cache),
            decode_fn=lambda p, t, c, ec: ED.encdec_decode(p, t, c, cfg, ec),
            init_caches=lambda batch, max_len, dtype=jnp.bfloat16:
                ED.init_encdec_caches(cfg, batch, max_len, dtype),
        )
    if cfg.family == "vlm":
        return Model(
            cfg=cfg,
            init=lambda rng, abstract=False: VL.init_vlm(
                rng, cfg, abstract=abstract),
            loss_fn=lambda p, b, ec: VL.vlm_loss(p, b, cfg, ec),
            prefill_fn=lambda p, b, ec, return_cache=False:
                VL.vlm_prefill(p, b, cfg, ec, return_cache),
            decode_fn=lambda p, t, c, ec: VL.vlm_decode(p, t, c, cfg, ec),
            init_caches=lambda batch, max_len, dtype=jnp.bfloat16:
                VL.init_vlm_caches(cfg, batch, max_len, dtype),
        )
    # dense / moe / ssm uniform stacks
    return Model(
        cfg=cfg,
        init=lambda rng, abstract=False: DEC.init_lm(
            rng, cfg, abstract=abstract),
        loss_fn=lambda p, b, ec: DEC.lm_loss(p, b, cfg, ec),
        prefill_fn=lambda p, b, ec, return_cache=False:
            DEC.lm_prefill(p, b, cfg, ec, return_cache),
        decode_fn=lambda p, t, c, ec: DEC.lm_decode(p, t, c, cfg, ec),
        init_caches=lambda batch, max_len, dtype=jnp.bfloat16:
            DEC.init_lm_caches(cfg, batch, max_len, dtype),
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16,
                cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract inputs for one (arch, shape) cell.

    train/prefill -> batch dict; decode -> {"tokens", "caches"}.
    Modality frontends are stubs: VLM gets precomputed patch embeddings,
    audio gets precomputed frame embeddings (per the assignment spec).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            st = s - cfg.num_patches
            batch = {"patch_embeds": sds((b, cfg.num_patches, cfg.d_model), dtype),
                     "tokens": sds((b, st))}
            if shape.kind == "train":
                batch["labels"] = sds((b, st))
            return batch
        if cfg.family == "audio":
            batch = {"frames": sds((b, cfg.encoder_seq, cfg.d_model), dtype),
                     "tokens": sds((b, s))}
            if shape.kind == "train":
                batch["labels"] = sds((b, s))
            return batch
        batch = {"tokens": sds((b, s))}
        if shape.kind == "train":
            batch["labels"] = sds((b, s))
        return batch

    # decode: one new token against caches of length s
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(b, s, cache_dtype))
    return {"tokens": sds((b, 1)), "caches": caches}


def abstract_params(cfg: ArchConfig) -> tuple[Dict[str, Any], Axes]:
    """(ShapeDtypeStruct params, logical axes) without allocation."""
    model = build_model(cfg)
    return model.init(None, abstract=True)


def pad_caches(caches, extra: int):
    """Extend KV/latent cache sequence dims by `extra` zero slots (prefill
    populates caches of prompt length; decode needs room to append).

    Leading stacked-layer dims shift the sequence dim by one; the dim is
    located per leaf name counting from the batch dim found by value."""
    seq_keys = ("k", "v", "latent", "k_rope", "k_scale", "v_scale")

    def leaf(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        # enc-dec cross K/V is written once at prefill and never grows;
        # cross attention has no valid-length mask, so padding it with
        # zero rows would CHANGE the softmax (zero scores still weigh in)
        if any(getattr(p, "key", None) == "cross" for p in path):
            return x
        if key in seq_keys and x.ndim >= 3:
            # seq dim is the one right after batch: (..., B, S, ...) — for
            # stacked caches (L, B, S, ...) that is ndim-3 for k/v (4d tail)
            dim = x.ndim - 3 if key in ("k", "v") else x.ndim - 2
            pad = [(0, 0)] * x.ndim
            pad[dim] = (0, extra)
            return jnp.pad(x, pad)
        return x

    return jax.tree_util.tree_map_with_path(leaf, caches)


def quantize_caches(caches):
    """Quantize a bf16 prefill cache tree to the int8 layout decode expects.

    Prefill populates plain bf16 self-attention caches
    (``_fresh_attn_cache``); when the plan pins ``kv_cache_dtype="int8"``
    the decode path instead reads int8 k/v plus per-(token, head) f32
    scales.  This converts only self-attention {k, v, pos} dicts — the
    only caches with a quantized read/write path; MLA latents, SSM/mLSTM
    states, and pos-less cross-attention caches pass through unchanged.
    """
    from repro.models.layers import quantize_kv

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "pos" in node:
                kq, ks = quantize_kv(node["k"])
                vq, vs = quantize_kv(node["v"])
                out = dict(node, k=kq, v=vq, k_scale=ks, v_scale=vs)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(caches)
