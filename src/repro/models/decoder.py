"""Generic stacked decoder LM: dense / MoE / mLSTM / Mamba2 uniform stacks.

Provides init / forward(train|prefill) / decode over flat-dict params with
scan-over-layers + segmented remat (CKPT_i, AO_i) + logical-axis sharding.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.common import (Axes, ExecConfig, ParamBuilder, Params,
                                 StackedBuilder, name_act, segmented_layer_scan,
                                 shard_act, softmax_xent, subtree)

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------


def block_kind(cfg: ArchConfig) -> str:
    if cfg.family == "ssm" and cfg.xlstm_heads:
        return "mlstm"
    if cfg.family == "ssm":
        return "mamba2"
    return "attn"  # dense / moe / vlm


def init_block(b: StackedBuilder, cfg: ArchConfig):
    kind = block_kind(cfg)
    if kind == "attn":
        L.init_norm(b.scope("ln1"), cfg)
        L.init_attention(b.scope("attn"), cfg)
        L.init_norm(b.scope("ln2"), cfg)
        if cfg.is_moe:
            MOE.init_moe(b.scope("moe"), cfg)
        else:
            L.init_mlp(b.scope("mlp"), cfg)
    elif kind == "mamba2":
        L.init_norm(b.scope("ln1"), cfg)
        SSM.init_mamba2(b.scope("mixer"), cfg)
    elif kind == "mlstm":
        L.init_norm(b.scope("ln1"), cfg)
        XL.init_mlstm(b.scope("mixer"), cfg)
    else:
        raise ValueError(kind)


def apply_block(p: Params, h: jax.Array, cfg: ArchConfig, ec: ExecConfig,
                cache: Optional[Dict] = None, mask_kind: str = "causal",
                return_cache: bool = False
                ) -> Tuple[jax.Array, jax.Array, Any]:
    """Returns (h, aux_loss, new_cache)."""
    kind = block_kind(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = name_act(h, "layer_in")
    if kind == "attn":
        hn = L.norm(subtree(p, "ln1"), h, cfg)
        a, new_cache = L.attention(subtree(p, "attn"), hn, cfg, ec,
                                   cache=cache, mask_kind=mask_kind)
        if return_cache and cache is None and new_cache is None:
            new_cache = _fresh_attn_cache(subtree(p, "attn"), hn, cfg)
        h = h + a
        h = shard_act(h, ("dp", "sp", None))
        hn = L.norm(subtree(p, "ln2"), h, cfg)
        if cfg.is_moe:
            m, aux = MOE.moe(subtree(p, "moe"), hn, cfg, ec)
        else:
            m = L.mlp(subtree(p, "mlp"), hn, cfg)
        h = h + m
    elif kind == "mamba2":
        hn = L.norm(subtree(p, "ln1"), h, cfg)
        m, new_cache = SSM.mamba2_mixer(
            subtree(p, "mixer"), hn, cfg, ec, cache=cache,
            return_state=return_cache and cache is None)
        h = h + m
    else:  # mlstm
        hn = L.norm(subtree(p, "ln1"), h, cfg)
        m, new_cache = XL.mlstm_mixer(
            subtree(p, "mixer"), hn, cfg, ec, cache=cache,
            return_state=return_cache and cache is None)
        h = h + m
    h = shard_act(h, ("dp", "sp", None))
    h = name_act(h, "resid")
    return h, aux, new_cache


def _fresh_attn_cache(p_attn: Params, hn: jax.Array, cfg: ArchConfig) -> Dict:
    """Build a populated KV cache from a prefill pass (for serving handoff)."""
    b_, s, _ = hn.shape
    if cfg.attention_type == "mla":
        kv = hn @ p_attn["wkv_down"]
        latent = L._rms(kv[..., :cfg.kv_lora_rank], p_attn["kv_norm"])
        k_rope = kv[..., cfg.kv_lora_rank:]
        cos, sin = L.rope_freqs(jnp.arange(s)[None, :], cfg.qk_rope_head_dim,
                                cfg.rope_theta)
        k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
        return {"latent": latent, "k_rope": k_rope,
                "pos": jnp.asarray(s, jnp.int32)}
    k = jnp.einsum("bsd,dhk->bshk", hn, p_attn["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hn, p_attn["wv"])
    if cfg.qkv_bias:
        k, v = k + p_attn["bk"], v + p_attn["bv"]
    cos, sin = L.rope_freqs(jnp.arange(s)[None, :], cfg.head_dim,
                            cfg.rope_theta)
    k = L.apply_rope(k, cos, sin)
    return {"k": k, "v": v, "pos": jnp.asarray(s, jnp.int32)}


def init_block_cache(cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Dict:
    kind = block_kind(cfg)
    if kind == "attn":
        return L.init_self_kv_cache(cfg, batch, max_len, dtype)
    if kind == "mamba2":
        return SSM.init_mamba2_cache(cfg, batch, dtype)
    return XL.init_mlstm_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# LM assembly
# ---------------------------------------------------------------------------


def init_lm(rng: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16,
            abstract: bool = False) -> Tuple[Params, Axes]:
    pb = ParamBuilder(rng, dtype, abstract=abstract)
    pb.add("embed/w", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
           scale=0.02)
    sb = StackedBuilder(pb, "layers", cfg.num_layers)
    init_block(sb, cfg)
    L.init_norm(pb.scope("final_norm"), cfg)
    if not cfg.tie_embeddings:
        pb.add("lm_head/w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
               scale=1.0 / math.sqrt(cfg.d_model))
    return pb.params, pb.axes


def embed_tokens(params: Params, tokens: jax.Array, cfg: ArchConfig,
                 ec: ExecConfig) -> jax.Array:
    x = jnp.take(params["embed/w"], tokens, axis=0).astype(ec.compute_dtype)
    return shard_act(x, ("dp", "sp", None))


def unembed_matrix(params: Params, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed/w"].T
    return params["lm_head/w"]


def run_layers(params: Params, x: jax.Array, cfg: ArchConfig, ec: ExecConfig
               ) -> Tuple[jax.Array, jax.Array]:
    """Scan all layers (train/prefill).  Returns (h, aux_loss)."""
    stacked = subtree(params, "layers")

    def body(carry, lp):
        h, aux = carry
        h, a, _ = apply_block(lp, h, cfg, ec)
        return (h, aux + a)

    h, aux = segmented_layer_scan(body, (x, jnp.zeros((), jnp.float32)),
                                  stacked, cfg.num_layers, ec)
    return L.norm(subtree(params, "final_norm"), h, cfg), aux


def chunked_xent(h: jax.Array, w_out: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None, chunk: int = 512
                 ) -> jax.Array:
    """Cross-entropy without materializing full (B,S,V) logits: scan over
    sequence chunks, recomputing chunk logits in bwd (checkpointed)."""
    b_, s, d = h.shape
    c = min(chunk, s)
    nc = s // c
    if nc * c != s:  # fall back for ragged smoke shapes
        logits = (h @ w_out).astype(jnp.float32)
        logits = shard_act(logits, ("dp", None, "tp"))
        return softmax_xent(logits, labels, mask)
    hc = h.reshape(b_, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b_, nc, c).transpose(1, 0, 2)
    mc = (mask.reshape(b_, nc, c).transpose(1, 0, 2) if mask is not None
          else jnp.ones((nc, b_, c), jnp.float32))

    @jax.checkpoint
    def chunk_fn(carry, xs):
        tot, cnt = carry
        hh, ll, mm = xs
        logits = (hh @ w_out).astype(jnp.float32)
        logits = shard_act(logits, ("dp", None, "tp"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (tot + nll.sum(), cnt + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk_fn, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


AUX_COEF = 0.01


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            ec: ExecConfig) -> jax.Array:
    x = embed_tokens(params, batch["tokens"], cfg, ec)
    h, aux = run_layers(params, x, cfg, ec)
    loss = chunked_xent(h, unembed_matrix(params, cfg), batch["labels"],
                        batch.get("loss_mask"))
    return loss + AUX_COEF * aux / cfg.num_layers


def lm_prefill(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig,
               ec: ExecConfig, return_cache: bool = False):
    """Forward over the prompt; returns last-position logits (+ caches)."""
    x = embed_tokens(params, batch["tokens"], cfg, ec)
    if not return_cache:
        h, _ = run_layers(params, x, cfg, ec)
        logits = (h[:, -1:] @ unembed_matrix(params, cfg)).astype(jnp.float32)
        return shard_act(logits, ("dp", None, "tp"))
    # cache-populating path (no scan-remat; used by serving examples/tests)
    stacked = subtree(params, "layers")

    def body(carry, lp):
        h, = carry
        h, _, nc = apply_block(lp, h, cfg, ec, return_cache=True)
        return (h,), nc

    (h,), caches = jax.lax.scan(body, (x,), stacked)
    h = L.norm(subtree(params, "final_norm"), h, cfg)
    logits = (h[:, -1:] @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), caches


def lm_decode(params: Params, tokens: jax.Array, caches, cfg: ArchConfig,
              ec: ExecConfig):
    """One decode step: tokens (B,1) + stacked caches -> (logits, new caches)."""
    x = embed_tokens(params, tokens, cfg, ec)
    stacked = subtree(params, "layers")

    def body(h, xs):
        lp, lc = xs
        h, _, nc = apply_block(lp, h, cfg, ec, cache=lc)
        return h, nc

    h, new_caches = jax.lax.scan(body, x, (stacked, caches))
    h = L.norm(subtree(params, "final_norm"), h, cfg)
    logits = (h @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), new_caches


def init_lm_caches(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    one = init_block_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape), one)
