"""InternVL2-style VLM: LM decoder backbone with a stubbed ViT frontend.
``input_specs()`` supplies precomputed patch embeddings which are projected
and prepended to the token embeddings.  [arXiv:2404.16821]
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.common import (Axes, ExecConfig, ParamBuilder, Params,
                                 shard_act, subtree)
from repro.models import decoder as DEC


def init_vlm(rng: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16,
             abstract: bool = False) -> Tuple[Params, Axes]:
    params, axes = DEC.init_lm(rng, cfg, dtype, abstract=abstract)
    pb = ParamBuilder(None if abstract else jax.random.fold_in(rng, 1), dtype,
                      abstract=abstract)
    pb.add("vis_proj/w", (cfg.d_model, cfg.d_model), ("embed", None),
           scale=1.0 / math.sqrt(cfg.d_model))
    params.update(pb.params)
    axes.update(pb.axes)
    return params, axes


def _fuse(params: Params, batch: Dict, cfg: ArchConfig, ec: ExecConfig):
    patches = batch["patch_embeds"].astype(ec.compute_dtype) @ params["vis_proj/w"]
    tok = DEC.embed_tokens(params, batch["tokens"], cfg, ec)
    x = jnp.concatenate([patches, tok], axis=1)
    return shard_act(x, ("dp", "sp", None))


def vlm_loss(params: Params, batch: Dict, cfg: ArchConfig, ec: ExecConfig
             ) -> jax.Array:
    x = _fuse(params, batch, cfg, ec)
    h, aux = DEC.run_layers(params, x, cfg, ec)
    h_text = h[:, cfg.num_patches:]  # loss over text positions only
    loss = DEC.chunked_xent(h_text, DEC.unembed_matrix(params, cfg),
                            batch["labels"], batch.get("loss_mask"))
    return loss + DEC.AUX_COEF * aux / cfg.num_layers


def vlm_prefill(params: Params, batch: Dict, cfg: ArchConfig, ec: ExecConfig,
                return_cache: bool = False):
    x = _fuse(params, batch, cfg, ec)
    if not return_cache:
        h, _ = DEC.run_layers(params, x, cfg, ec)
        logits = (h[:, -1:] @ DEC.unembed_matrix(params, cfg)
                  ).astype(jnp.float32)
        return shard_act(logits, ("dp", None, "tp"))
    stacked = subtree(params, "layers")

    def body(carry, lp):
        h, = carry
        h, _, nc = DEC.apply_block(lp, h, cfg, ec, return_cache=True)
        return (h,), nc

    (h,), caches = jax.lax.scan(body, (x,), stacked)
    h = L.norm(subtree(params, "final_norm"), h, cfg)
    logits = (h[:, -1:] @ DEC.unembed_matrix(params, cfg)).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), caches


# decode is identical to the plain LM (patches live in the cache already)
vlm_decode = DEC.lm_decode
init_vlm_caches = DEC.init_lm_caches
