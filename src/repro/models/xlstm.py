"""xLSTM mLSTM block: exponential-gated matrix-memory recurrence, exact
chunkwise-parallel training form (log-space stabilized) + recurrent decode.
[arXiv:2405.04517]
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ExecConfig, Params, ScopedBuilder, shard_act

NEG = -1e30


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mlstm(b: ScopedBuilder, cfg: ArchConfig):
    d, di, nh = cfg.d_model, d_inner(cfg), cfg.xlstm_heads
    kw = 4
    b.add("up_proj", (d, 2 * di), ("embed", "inner"), scale=1.0 / math.sqrt(d))
    b.add("conv_w", (kw, di), (None, "inner"), scale=1.0 / math.sqrt(kw))
    b.add("conv_b", (di,), ("inner",), init="zeros")
    b.add("wq", (di, di), ("inner", "inner2"), scale=1.0 / math.sqrt(di))
    b.add("wk", (di, di), ("inner", "inner2"), scale=1.0 / math.sqrt(di))
    b.add("wv", (di, di), ("inner", "inner2"), scale=1.0 / math.sqrt(di))
    b.add("wi", (di, nh), ("inner", "heads"), scale=1.0 / math.sqrt(di))
    b.add("bi", (nh,), ("heads",), init="zeros")
    b.add("wf", (di, nh), ("inner", "heads"), scale=1.0 / math.sqrt(di))
    b.add("bf", (nh,), ("heads",), init="ones")
    b.add("out_norm", (di,), ("inner",), init="ones")
    b.add("down_proj", (di, d), ("inner", "embed"), scale=1.0 / math.sqrt(di))


def _causal_conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mlstm_chunked(q, k, v, li, lf, chunk: int,
                  state: Optional[Dict] = None):
    """Exact chunkwise mLSTM.  q/k/v (B,S,NH,HD); li/lf (B,S,NH) f32
    (log input gate preact, log-sigmoid forget gate).  Returns
    (h (B,S,NH,HD), final state {"c","n","m"})."""
    bsz, s, nh, hd = q.shape
    qn = min(chunk, s)
    nc = s // qn
    assert nc * qn == s
    shp = (bsz, nc, qn, nh)
    qc = q.reshape(bsz, nc, qn, nh, hd)
    kc = k.reshape(bsz, nc, qn, nh, hd)
    vc = v.reshape(bsz, nc, qn, nh, hd)
    lic = li.reshape(shp)
    lfc = lf.reshape(shp)

    f_cum = jnp.cumsum(lfc, axis=2)                        # (B,C,Q,NH) inclusive
    # D(t,s) = F_t - F_s + i_s  for t >= s
    dmat = f_cum[:, :, :, None, :] - f_cum[:, :, None, :, :] \
        + lic[:, :, None, :, :]                            # (B,C,Qt,Qs,NH)
    tri = jnp.tril(jnp.ones((qn, qn), bool))
    dmat = jnp.where(tri[None, None, :, :, None], dmat, NEG)
    m_intra = jnp.max(dmat, axis=3)                        # (B,C,Qt,NH)

    if state is None:
        c0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((bsz, nh, hd), jnp.float32)
        m0 = jnp.full((bsz, nh), NEG, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    # inter-chunk recurrence over chunk boundaries
    f_sum = f_cum[:, :, -1, :]                             # (B,C,NH)
    g_in = f_cum[:, :, -1:, :] - f_cum + lic               # (B,C,Q,NH) to chunk end

    scale = 1.0 / math.sqrt(hd)

    def step(carry, inp):
        c, n, m = carry
        kcc, vcc, g, fs = inp                              # per chunk (k pre-scaled)
        m_new = jnp.maximum(fs + m, jnp.max(g, axis=1))    # (B,NH)
        scale_old = jnp.exp(fs + m - m_new)                # (B,NH)
        w_in = jnp.exp(g - m_new[:, None, :])              # (B,Q,NH)
        c_new = c * scale_old[..., None, None] + jnp.einsum(
            "bqhd,bqhe->bhde", (kcc * w_in[..., None]).astype(jnp.float32),
            vcc.astype(jnp.float32))
        n_new = n * scale_old[..., None] + jnp.einsum(
            "bqhd,bqh->bhd", kcc.astype(jnp.float32), w_in)
        return (c_new, n_new, m_new), (c, n, m)

    xs = ((kc * scale).transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
          g_in.transpose(1, 0, 2, 3), f_sum.transpose(1, 0, 2))
    (cT, nT, mT), (c_prev, n_prev, m_prev) = jax.lax.scan(step, (c0, n0, m0), xs)
    c_prev = c_prev.transpose(1, 0, 2, 3, 4)               # (B,C,NH,HD,HD)
    n_prev = n_prev.transpose(1, 0, 2, 3)                  # (B,C,NH,HD)
    m_prev = m_prev.transpose(1, 0, 2)                     # (B,C,NH)

    m_inter = f_cum + m_prev[:, :, None, :]                # (B,C,Q,NH)
    m_t = jnp.maximum(m_intra, m_inter)
    w_intra = jnp.exp(dmat - m_t[:, :, :, None, :])        # (B,C,Qt,Qs,NH)
    w_inter = jnp.exp(m_inter - m_t)                       # (B,C,Q,NH)

    qk = jnp.einsum("bcqhd,bcshd->bcqsh", qc, kc,
                    preferred_element_type=jnp.float32) * scale
    num = jnp.einsum("bcqsh,bcshd->bcqhd", (qk * w_intra).astype(v.dtype), vc)
    num = num + jnp.einsum("bcqhd,bchde->bcqhe",
                           (qc * w_inter[..., None]).astype(v.dtype),
                           c_prev.astype(v.dtype))
    den = (qk * w_intra).sum(axis=3)                       # (B,C,Q,NH)
    den_inter = jnp.einsum("bcqhd,bchd->bcqh",
                           qc.astype(jnp.float32), n_prev) * w_inter
    den_t = den + den_inter
    denom = jnp.maximum(jnp.abs(den_t), jnp.exp(-m_t))
    h = num / denom[..., None].astype(num.dtype)
    h = h.reshape(bsz, s, nh, hd)
    return h, {"c": cT, "n": nT, "m": mT}


def mlstm_mixer(p: Params, x: jax.Array, cfg: ArchConfig, ec: ExecConfig,
                cache: Optional[Dict] = None, return_state: bool = False
                ) -> Tuple[jax.Array, Optional[Dict]]:
    bsz, s, _ = x.shape
    di, nh = d_inner(cfg), cfg.xlstm_heads
    hd = di // nh
    uz = x @ p["up_proj"]
    u, z = uz[..., :di], uz[..., di:]
    u = shard_act(u, ("dp", None, "tp"))

    if cache is None:
        cu = _causal_conv(u, p["conv_w"], p["conv_b"])
        new_cache = None
        conv_cache = None
    else:
        conv_st = jnp.concatenate([cache["conv"], u], axis=1)
        cu = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_st, p["conv_w"])
                         + p["conv_b"])[:, None, :]
        conv_cache = conv_st[:, 1:]

    q = (cu @ p["wq"]).reshape(bsz, s, nh, hd)
    k = (cu @ p["wk"]).reshape(bsz, s, nh, hd)
    v = (u @ p["wv"]).reshape(bsz, s, nh, hd)
    li = (cu @ p["wi"] + p["bi"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid((cu @ p["wf"] + p["bf"]).astype(jnp.float32))

    if cache is None:
        h, st = mlstm_chunked(q, k, v, li, lf, ec.mlstm_chunk)
        if return_state:
            new_cache = {**st, "conv": u[:, -3:]}
    else:
        # recurrent decode step
        c, n, m = cache["c"], cache["n"], cache["m"]
        li1, lf1 = li[:, 0], lf[:, 0]                       # (B,NH)
        m_new = jnp.maximum(lf1 + m, li1)
        fp = jnp.exp(lf1 + m - m_new)
        ip = jnp.exp(li1 - m_new)
        k1 = k[:, 0].astype(jnp.float32) / math.sqrt(hd)
        v1 = v[:, 0].astype(jnp.float32)
        q1 = q[:, 0].astype(jnp.float32)
        c = c * fp[..., None, None] + ip[..., None, None] * \
            k1[..., :, None] * v1[..., None, :]
        n = n * fp[..., None] + ip[..., None] * k1
        num = jnp.einsum("bhde,bhd->bhe", c, q1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q1)),
                          jnp.exp(-m_new))
        h = (num / den[..., None])[:, None].astype(x.dtype)
        new_cache = {"c": c, "n": n, "m": m_new, "conv": conv_cache}

    hf = h.reshape(bsz, s, di).astype(jnp.float32)
    hf = hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
    hf = hf * p["out_norm"].astype(jnp.float32)
    out = (hf.astype(x.dtype) * jax.nn.silu(z)) @ p["down_proj"]
    return out, new_cache


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    di, nh = d_inner(cfg), cfg.xlstm_heads
    hd = di // nh
    return {
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), NEG, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype),
    }
