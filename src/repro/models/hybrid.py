"""Zamba2-style hybrid: Mamba2 backbone in groups, with one *shared*
attention+MLP block applied at the start of every group, fed by a
per-group projection of concat(hidden, original embedding).
[arXiv:2411.15242; per-application LoRA simplified to a per-group in-proj,
see DESIGN.md §4]
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models.common import (Axes, ExecConfig, ParamBuilder, Params,
                                 StackedBuilder, name_act,
                                 segmented_layer_scan, shard_act, subtree)
from repro.models.decoder import chunked_xent, unembed_matrix


def group_shape(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.shared_attn_every
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, per


def init_hybrid(rng: jax.Array, cfg: ArchConfig, dtype=jnp.bfloat16,
                abstract: bool = False) -> Tuple[Params, Axes]:
    pb = ParamBuilder(rng, dtype, abstract=abstract)
    d = cfg.d_model
    ng, per = group_shape(cfg)
    pb.add("embed/w", (cfg.vocab_size, d), ("vocab", "embed"), scale=0.02)
    # per-group input projection for the shared block: concat(h, x0) -> d
    gb = StackedBuilder(pb, "groups", ng)
    gb.add("in_proj", (2 * d, d), ("embed", None), scale=1.0 / math.sqrt(2 * d))
    # shared attention + MLP block (one set of weights, applied ng times)
    sb = pb.scope("shared")
    L.init_norm(sb.scope("ln1"), cfg)
    L.init_attention(sb.scope("attn"), cfg)
    L.init_norm(sb.scope("ln2"), cfg)
    L.init_mlp(sb.scope("mlp"), cfg)
    # mamba backbone, stacked (groups, per-group)
    mb = StackedBuilder(pb, "mamba", (ng, per))
    L.init_norm(mb.scope("ln"), cfg)
    SSM.init_mamba2(mb.scope("mixer"), cfg)
    L.init_norm(pb.scope("final_norm"), cfg)
    pb.add("lm_head/w", (d, cfg.vocab_size), ("embed", "vocab"),
           scale=1.0 / math.sqrt(d))
    return pb.params, pb.axes


def _shared_block(shared: Params, gin: jax.Array, h: jax.Array,
                  x0: jax.Array, cfg: ArchConfig, ec: ExecConfig,
                  cache=None, return_cache=False):
    """Apply the shared attention block; gin is this group's in-proj."""
    z = jnp.concatenate([h, x0], axis=-1) @ gin
    zn = L.norm(subtree(shared, "ln1"), z, cfg)
    a, new_cache = L.attention(subtree(shared, "attn"), zn, cfg, ec,
                               cache=cache)
    if return_cache and cache is None:
        from repro.models.decoder import _fresh_attn_cache
        new_cache = _fresh_attn_cache(subtree(shared, "attn"), zn, cfg)
    z = z + a
    zn = L.norm(subtree(shared, "ln2"), z, cfg)
    z = z + L.mlp(subtree(shared, "mlp"), zn, cfg)
    return h + z, new_cache


def _mamba_layer(lp: Params, h: jax.Array, cfg: ArchConfig, ec: ExecConfig,
                 cache=None, return_state=False):
    hn = L.norm(subtree(lp, "ln"), h, cfg)
    m, nc = SSM.mamba2_mixer(subtree(lp, "mixer"), hn, cfg, ec, cache=cache,
                             return_state=return_state)
    return h + m, nc


def run_hybrid_layers(params: Params, x: jax.Array, cfg: ArchConfig,
                      ec: ExecConfig) -> jax.Array:
    """Train/prefill forward over all groups (remat-segmented at group level)."""
    ng, per = group_shape(cfg)
    shared = subtree(params, "shared")
    mamba = subtree(params, "mamba")
    gproj = subtree(params, "groups")
    x0 = x

    # remat segmentation quantized to groups: ckpt_layers -> groups
    ec_g = ec.replace(
        ckpt_layers=-(-min(ec.ckpt_layers, cfg.num_layers) // per),
        offload_layers=-(-min(ec.offload_layers, cfg.num_layers) // per))

    def group_body(carry, gp):
        h, = carry
        gproj_g, mamba_g = gp["in_proj"], {k: v for k, v in gp.items()
                                           if k != "in_proj"}
        h, _ = _shared_block(shared, gproj_g, h, x0, cfg, ec)
        h = shard_act(h, ("dp", "sp", None))

        def layer_body(hh, lp):
            hh, _ = _mamba_layer(lp, hh, cfg, ec)
            return hh, None

        h, _ = jax.lax.scan(layer_body, h, mamba_g)
        h = name_act(h, "resid")
        return (h,)

    stacked = dict(mamba, in_proj=gproj["in_proj"])
    (h,) = segmented_layer_scan(group_body, (x,), stacked, ng, ec_g)
    return L.norm(subtree(params, "final_norm"), h, cfg)


def hybrid_loss(params: Params, batch: Dict, cfg: ArchConfig, ec: ExecConfig
                ) -> jax.Array:
    x = jnp.take(params["embed/w"], batch["tokens"], axis=0
                 ).astype(ec.compute_dtype)
    x = shard_act(x, ("dp", "sp", None))
    h = run_hybrid_layers(params, x, cfg, ec)
    return chunked_xent(h, params["lm_head/w"], batch["labels"],
                        batch.get("loss_mask"))


def hybrid_prefill(params: Params, batch: Dict, cfg: ArchConfig,
                   ec: ExecConfig, return_cache: bool = False):
    x = jnp.take(params["embed/w"], batch["tokens"], axis=0
                 ).astype(ec.compute_dtype)
    x = shard_act(x, ("dp", "sp", None))
    if not return_cache:
        h = run_hybrid_layers(params, x, cfg, ec)
        logits = (h[:, -1:] @ params["lm_head/w"]).astype(jnp.float32)
        return shard_act(logits, ("dp", None, "tp"))

    ng, per = group_shape(cfg)
    shared = subtree(params, "shared")
    gproj = subtree(params, "groups")
    mamba = subtree(params, "mamba")
    x0, h = x, x

    def group_body(carry, gp):
        h, = carry
        h, attn_c = _shared_block(shared, gp["in_proj"], h, x0, cfg, ec,
                                  return_cache=True)

        def layer_body(hh, lp):
            hh, st = _mamba_layer(lp, hh, cfg, ec, return_state=True)
            return hh, st

        h, mamba_c = jax.lax.scan(layer_body, h,
                                  {k: v for k, v in gp.items()
                                   if k != "in_proj"})
        return (h,), {"attn": attn_c, "mamba": mamba_c}

    stacked = dict(mamba, in_proj=gproj["in_proj"])
    (h,), caches = jax.lax.scan(group_body, (h,), stacked)
    h = L.norm(subtree(params, "final_norm"), h, cfg)
    logits = (h[:, -1:] @ params["lm_head/w"]).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), caches


def hybrid_decode(params: Params, tokens: jax.Array, caches, cfg: ArchConfig,
                  ec: ExecConfig):
    x = jnp.take(params["embed/w"], tokens, axis=0).astype(ec.compute_dtype)
    x0 = x
    shared = subtree(params, "shared")
    gproj = subtree(params, "groups")
    mamba = subtree(params, "mamba")

    def group_body(h, xs):
        gp, gc = xs
        h, attn_c = _shared_block(shared, gp["in_proj"], h, x0, cfg, ec,
                                  cache=gc["attn"])

        def layer_body(hh, xs2):
            lp, lc = xs2
            hh, nc = _mamba_layer(lp, hh, cfg, ec, cache=lc)
            return hh, nc

        h, mamba_c = jax.lax.scan(
            layer_body, h, ({k: v for k, v in gp.items() if k != "in_proj"},
                            gc["mamba"]))
        return h, {"attn": attn_c, "mamba": mamba_c}

    stacked = dict(mamba, in_proj=gproj["in_proj"])
    h, new_caches = jax.lax.scan(group_body, x, (stacked, caches))
    h = L.norm(subtree(params, "final_norm"), h, cfg)
    logits = (h @ params["lm_head/w"]).astype(jnp.float32)
    return shard_act(logits, ("dp", None, "tp")), new_caches


def init_hybrid_caches(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    ng, per = group_shape(cfg)
    attn_c = L.init_self_kv_cache(cfg, batch, max_len, dtype)
    mamba_c = SSM.init_mamba2_cache(cfg, batch, dtype)
    return {
        "attn": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (ng,) + v.shape), attn_c),
        "mamba": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None, None], (ng, per) + v.shape),
            mamba_c),
    }
