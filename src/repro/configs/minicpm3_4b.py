"""MiniCPM3-4B [dense]: Multi-head Latent Attention (MLA).  [hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import ArchConfig, register

MINICPM3_4B = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_head_dim=32,
    qk_nope_head_dim=64,
    v_head_dim=64,
    head_dim=96,  # nope + rope
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
