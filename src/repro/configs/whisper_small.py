"""Whisper-small [audio]: enc-dec backbone; conv frontend is a stub supplying
precomputed frame embeddings.  [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, register

WHISPER_SMALL = register(ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_seq=1500,       # frames after the (stubbed) conv1d stem
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm_type="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
