"""Qwen2-72B [dense]: GQA kv=8, QKV bias.  [arXiv:2407.10671]"""
from repro.configs.base import ArchConfig, register

QWEN2_72B = register(ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    # pure full attention -> long_500k skipped (see DESIGN.md §4)
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
