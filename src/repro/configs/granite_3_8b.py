"""Granite-3-8B [dense]: GQA kv=8.  [hf:ibm-granite/granite-3.0-8b-base]"""
from repro.configs.base import ArchConfig, register

GRANITE_3_8B = register(ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
