"""Zamba2-2.7B [hybrid]: Mamba2 backbone + shared attention block.  [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, register

ZAMBA2_2P7B = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    shared_attn_every=6,       # one shared full-attn+MLP block applied every 6 layers
    norm_type="rmsnorm",
    act="gelu",
    mlp_gated=True,
    # hybrid/SSM: sub-quadratic decode -> long_500k applies
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
