"""Qwen2-MoE-A2.7B [moe]: 60 routed top-4 + 4 shared experts.  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ArchConfig, register

QWEN2_MOE_A2P7B = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,             # routed-expert hidden dim per assignment
    vocab_size=151936,
    qkv_bias=True,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,  # shared path = 4 x 1408 = 5632 hidden
    moe_d_ff=1408,
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
