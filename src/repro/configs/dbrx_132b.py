"""DBRX-132B [moe]: 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, register

DBRX_132B = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    num_experts_per_tok=4,
    moe_d_ff=10752,
    norm_type="layernorm",
    act="silu",
    mlp_gated=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
