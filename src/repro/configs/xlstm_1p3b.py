"""xLSTM-1.3B [ssm]: mLSTM blocks (matrix memory, chunkwise-parallel).  [arXiv:2405.04517]"""
from repro.configs.base import ArchConfig, register

XLSTM_1P3B = register(ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                # mLSTM blocks carry their own 2x up-projection; no separate FFN
    vocab_size=50304,
    xlstm_heads=4,
    ssm_expand=2,
    norm_type="rmsnorm",
    act="gelu",
    mlp_gated=False,
    # recurrent-state decode -> long_500k applies
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
