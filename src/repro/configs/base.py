"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; each arch declares its
applicable input-shape set.  ``reduced()`` yields the small smoke-test variant
of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (seq_len, global_batch) + which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention flavour
    attention_type: str = "gqa"  # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10_000.0

    # MLA (MiniCPM3 / DeepSeek-V2 style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4

    # xLSTM
    xlstm_heads: int = 0

    # hybrid (Zamba2): one shared attention block applied every N backbone layers
    shared_attn_every: int = 0

    # enc-dec (Whisper backbone)
    encoder_layers: int = 0
    encoder_seq: int = 0  # frames after (stubbed) conv frontend

    # VLM (InternVL2): stubbed ViT frontend supplies patch embeddings
    num_patches: int = 0

    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    mlp_gated: bool = True  # SwiGLU-style gated MLP
    tie_embeddings: bool = False

    # which of the 4 shape cells apply (per spec: long_500k only for
    # sub-quadratic archs; encoder-only archs would skip decode — none here)
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived ------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def decoder_layers(self) -> int:
        return self.num_layers

    def shape_cells(self) -> Tuple[ShapeConfig, ...]:
        return tuple(SHAPES[s] for s in self.shapes)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for MODEL_FLOPS = 6*N*D) ---------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; active_only counts only routed-active experts."""
        from repro.core import costmodel_params  # local import to avoid cycle

        return costmodel_params.param_count(self, active_only=active_only)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.shared_attn_every == 0 else 8),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // max(1, self.num_heads))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.attention_type == "mla":
            kw.update(q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                      qk_nope_head_dim=16, v_head_dim=32)
        if self.is_moe:
            kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
                      num_shared_experts=min(1, self.num_shared_experts))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
        if self.xlstm_heads:
            kw.update(xlstm_heads=2)
        if self.shared_attn_every:
            kw.update(shared_attn_every=4)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=64)
        if self.num_patches:
            kw.update(num_patches=16)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import all config modules exactly once
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        zamba2_2p7b, qwen2_72b, minicpm3_4b, granite_3_8b, qwen15_32b,
        dbrx_132b, qwen2_moe_a2p7b, xlstm_1p3b, internvl2_1b, whisper_small,
    )
