"""Qwen1.5-32B [dense]: MHA (kv=40), QKV bias.  [hf:Qwen/Qwen1.5-32B]"""
from repro.configs.base import ArchConfig, register

QWEN15_32B = register(ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
