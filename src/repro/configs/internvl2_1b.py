"""InternVL2-1B [vlm]: Qwen2-0.5B-class LM backbone; InternViT frontend is a stub
supplying precomputed patch embeddings.  [arXiv:2404.16821]"""
from repro.configs.base import ArchConfig, register

INTERNVL2_1B = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    num_patches=256,       # stubbed ViT: 256 patch embeddings prepended to tokens
    norm_type="rmsnorm",
    act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
