"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO dot FLOPs / peak_FLOP/s          (per device)
    memory term     = HLO kernel-boundary bytes / HBM_bw   (per device)
    collective term = collective wire bytes / ICI link bw  (per device)

All inputs are per-device (post-SPMD HLO).  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) checks how much of compiled compute is useful (remat /
redundancy waste shows up as HLO/MODEL > 1 per device share).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.core.hardware import V5E, HardwareSpec
from repro.perf.hloanalysis import HLOStats, analyze


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device seconds
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # raw terms
    hlo_dot_flops: float          # per device
    hlo_bytes: float              # per device
    collective_wire_bytes: float  # per device
    collective_by_kind: Dict[str, float]
    # usefulness
    model_flops_global: float     # 6*N*D (or 6*N_act*D), x3 set by caller
    useful_ratio: float           # model_flops/(chips*hlo_dot_flops)
    # roofline fraction: useful work / (bound * peak)
    roofline_fraction: float
    # raw xla numbers for cross-checking
    xla_flops: Optional[float] = None
    xla_bytes: Optional[float] = None
    # TPU-target analytic memory term (the artifact's HBM bytes reflect
    # XLA:CPU fusion boundaries + f32 legalization)
    t_memory_analytic: Optional[float] = None
    t_collective_tpu: Optional[float] = None  # bf16-promotion corrected
    roofline_fraction_tpu: Optional[float] = None

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2)


def report_from_stats(stats: HLOStats, *, arch: str, shape: str, mesh: str,
                      chips: int, model_flops_global: float,
                      hw: HardwareSpec = V5E,
                      xla_cost: Optional[dict] = None,
                      hbm_bytes_analytic: Optional[float] = None
                      ) -> RooflineReport:
    t_c = stats.dot_flops / hw.peak_flops_bf16
    t_m = stats.hbm_bytes / hw.hbm_bw
    t_x = stats.collective_wire_bytes / hw.ici_bw_total
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_global / max(1.0, stats.dot_flops * chips)
    # achievable step time >= max(terms); usable fraction of peak compute:
    t_bound = max(t_c, t_m, t_x)
    frac = (model_flops_global / chips / hw.peak_flops_bf16) / max(t_bound,
                                                                   1e-12)
    t_m_tpu = None
    frac_tpu = None
    t_x_tpu = (stats.collective_wire_bytes_tpu / hw.ici_bw_total
               if stats.collective_wire_bytes_tpu else t_x)
    if hbm_bytes_analytic is not None:
        t_m_tpu = hbm_bytes_analytic / hw.hbm_bw
        bound_tpu = max(t_c, t_m_tpu, t_x_tpu)
        frac_tpu = min(1.0, (model_flops_global / chips
                             / hw.peak_flops_bf16) / max(bound_tpu, 1e-12))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        hlo_dot_flops=stats.dot_flops, hlo_bytes=stats.hbm_bytes,
        collective_wire_bytes=stats.collective_wire_bytes,
        collective_by_kind=dict(stats.collective_by_kind),
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        roofline_fraction=min(frac, 1.0),
        xla_flops=(xla_cost or {}).get("flops"),
        xla_bytes=(xla_cost or {}).get("bytes accessed"),
        t_memory_analytic=t_m_tpu,
        t_collective_tpu=t_x_tpu,
        roofline_fraction_tpu=frac_tpu,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS for one step of this cell (6*N*D for training;
    2*N*D for prefill; 2*N*new_tokens*D-style for decode)."""
    n = cfg.param_count(active_only=True)
    # exclude embedding table from the 6ND rule-of-thumb? Common practice
    # keeps full N; we keep full N and note it in EXPERIMENTS.md.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
