"""Trip-count-aware HLO analysis.

``compiled.cost_analysis()`` does NOT multiply loop-body costs by trip count
(verified empirically: an 80-layer scan reports one layer's FLOPs), so the
roofline must be derived by walking the post-SPMD HLO call graph: while-loop
bodies are weighted by their trip counts, fusions are treated as single
kernels (operand+output bytes = HBM traffic), dots contribute MXU FLOPs, and
collectives contribute per-device wire bytes using ring-algorithm factors.

All quantities are PER DEVICE (post-SPMD HLO is the per-device program).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"\s*([a-z][a-z0-9\-]*)\((.*)$", re.S)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _parse_op_line(line: str):
    """'%name = TYPE opcode(operands), attrs' -> (name, type, opcode, rest).

    TYPE may be a tuple spanning '( ... )' with layout braces and
    '/*index=k*/' comments, so it is extracted by paren matching, not regex.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        # ops can appear without % in some printers
        if not re.match(r"[\w.\-]+ = ", s):
            return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%")
    rem = s[eq + 3:]
    if rem.startswith("("):
        depth = 0
        for i, ch in enumerate(rem):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, rem2 = rem[:i + 1], rem[i + 1:]
                    break
        else:
            return None
    else:
        sp = rem.find(" ")
        if sp < 0:
            return None
        type_str, rem2 = rem[:sp], rem[sp:]
    m = _OPCODE_RE.match(rem2)
    if not m:
        return None
    return name, type_str, m.group(1), m.group(2)

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    rest: str  # text after the opening paren (operands + attributes)

    def operand_names(self) -> List[str]:
        # operands are up to the matching close paren; attrs follow after ")"
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    inner = self.rest[:i]
                    break
        else:
            inner = self.rest
        names = re.findall(r"%([\w.\-]+)", inner)
        return names

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=((\{[^}]*\})|(\[[^\]]*\](<=\[[\d,]+\])?)|([\w.\-%]+))",
                      self.rest)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    root: Optional[str] = None

    def accessed_input_bytes(self, operand_types: List[str]) -> int:
        """Bytes actually read from the fusion's operands: a parameter
        consumed only by dynamic-slice ops contributes the slice bytes, not
        the full buffer (models HBM traffic of scan-sliced stacked params)."""
        # parameter index -> param op name
        by_idx: Dict[int, str] = {}
        for nm in self.order:
            op = self.ops[nm]
            if op.opcode == "parameter":
                m = re.match(r"(\d+)", op.rest)
                if m:
                    by_idx[int(m.group(1))] = nm
        total = 0
        for i, t in enumerate(operand_types):
            pname = by_idx.get(i)
            full = _shape_bytes(t)
            if pname is None:
                total += full
                continue
            uses = [self.ops[nm] for nm in self.order
                    if pname in self.ops[nm].operand_names()
                    and self.ops[nm].opcode != "parameter"]
            if uses and all(u.opcode in ("dynamic-slice", "gather")
                            for u in uses):
                total += sum(_shape_bytes(u.out_type) for u in uses)
            else:
                total += full
        return total

    def written_output_bytes(self) -> int:
        """Bytes actually written: a dynamic-update-slice root writes only
        the update slice (in-place)."""
        r = self.ops.get(self.root or "")
        if r is None:
            return -1
        if r.opcode == "dynamic-update-slice":
            names = r.operand_names()
            if len(names) >= 2:
                upd = self.ops.get(names[1])
                if upd is not None:
                    return _shape_bytes(upd.out_type)
        if r.opcode == "tuple":
            total = 0
            for nm in r.operand_names():
                o = self.ops.get(nm)
                if o is None:
                    continue
                if o.opcode == "dynamic-update-slice":
                    upds = o.operand_names()
                    u = self.ops.get(upds[1]) if len(upds) > 1 else None
                    total += _shape_bytes(u.out_type) if u is not None \
                        else _shape_bytes(o.out_type)
                else:
                    total += _shape_bytes(o.out_type)
            return total
        return -1


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode, rest = parsed
            op = Op(name=name, out_type=type_str, opcode=opcode, rest=rest)
            cur.ops[op.name] = op
            cur.order.append(op.name)
            if line.lstrip().startswith("ROOT"):
                cur.root = op.name
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from the constant feeding the root compare of the loop
    condition (fallback: largest s32 constant anywhere in the condition)."""
    def const_val(name: str):
        op = cond.ops.get(name)
        if op is not None and op.opcode == "constant" \
                and op.out_type.startswith("s32"):
            m = re.match(r"(\d+)", op.rest)
            if m:
                return int(m.group(1))
        return None

    root = cond.ops.get(cond.root or "")
    if root is not None and root.opcode in ("compare", "fusion"):
        for nm in root.operand_names():
            v = const_val(nm)
            if v is not None:
                return max(1, v)
    best = 1
    for opn in cond.order:
        v = const_val(opn)
        if v is not None:
            best = max(best, v)
    return best


def _group_size(op: Op, default: int) -> int:
    rg = op.attr("replica_groups")
    if not rg:
        return default
    if rg.startswith("{{"):
        first = rg[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    m = re.match(r"\[([\d,]+)\]", rg)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        return dims[-1] if dims else default
    return default


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * prod(output dims) * prod(contracting dims of lhs)."""
    out = _shape_dims(op.out_type)
    if out is None:
        return 0.0
    _, odims = out
    names = op.operand_names()
    if not names:
        return 0.0
    lhs = comp.ops.get(names[0])
    if lhs is None:
        return 0.0
    lshape = _shape_dims(lhs.out_type)
    if lshape is None:
        return 0.0
    _, ldims = lshape
    cd = op.attr("lhs_contracting_dims")
    contract = 1
    if cd:
        for i in re.findall(r"\d+", cd):
            ii = int(i)
            if ii < len(ldims):
                contract *= ldims[ii]
    return 2.0 * math.prod(odims) * contract


@dataclass
class HLOStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0             # fusion-boundary operand+output bytes
    copy_bytes: float = 0.0            # loop-state copies (XLA:CPU artifact;
    #                                    elided by buffer aliasing on TPU —
    #                                    excluded from the memory term)
    collective_wire_bytes: float = 0.0  # per-device, ring-adjusted
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_msg_bytes: float = 0.0  # raw operand bytes (un-adjusted)
    n_collectives: int = 0
    # dtype-corrected wire bytes: XLA:CPU promotes bf16 collectives to f32
    # (AllReducePromotion / FloatNormalization); collectives that are
    # convert-wrapped (bf16 -> f32 -> coll -> bf16) count at half width,
    # matching the native-bf16 TPU target
    collective_wire_bytes_tpu: float = 0.0

    def add(self, other: "HLOStats", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.copy_bytes += other.copy_bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        self.collective_msg_bytes += other.collective_msg_bytes * mult
        self.collective_wire_bytes_tpu += \
            other.collective_wire_bytes_tpu * mult
        self.n_collectives += int(other.n_collectives * mult)
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = \
                self.collective_by_kind.get(k, 0.0) + v * mult


# opcodes whose called computations are "applies" (tiny), not control flow
_APPLY_ATTRS = ("to_apply", "called_computations")


def analyze(text: str, default_group: int = 1) -> HLOStats:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return HLOStats()
    memo: Dict[str, HLOStats] = {}

    def comp_stats(comp: Computation) -> HLOStats:
        if comp.name in memo:
            return memo[comp.name]
        st = HLOStats()
        for opn in comp.order:
            op = comp.ops[opn]
            oc = op.opcode
            if oc == "while":
                body_n = op.attr("body")
                cond_n = op.attr("condition")
                body = comps.get((body_n or "").lstrip("%"))
                cond = comps.get((cond_n or "").lstrip("%"))
                trips = _trip_count(cond) if cond else 1
                if body:
                    st.add(comp_stats(body), trips)
                if cond:
                    st.add(comp_stats(cond), trips)
                continue
            if oc == "conditional":
                for bn in re.findall(r"%([\w.\-]+)",
                                     op.attr("branch_computations") or ""):
                    b = comps.get(bn)
                    if b:
                        st.add(comp_stats(b), 1.0)
                continue
            if oc == "call":
                tgt = comps.get((op.attr("to_apply") or "").lstrip("%"))
                if tgt:
                    st.add(comp_stats(tgt), 1.0)
                continue
            # kernel-boundary bytes: operands + output
            ob = _shape_bytes(op.out_type)
            operand_types = []
            inb = 0
            for nm in op.operand_names():
                d = comp.ops.get(nm)
                if d is not None:
                    operand_types.append(d.out_type)
                    inb += _shape_bytes(d.out_type)
            if oc == "fusion":
                # count dots *inside* the fused computation for FLOPs, and
                # slice-aware accessed bytes instead of full buffer sizes
                tgt = comps.get((op.attr("calls") or "").lstrip("%"))
                if tgt:
                    inner = comp_stats(tgt)
                    st.dot_flops += inner.dot_flops
                    inb = tgt.accessed_input_bytes(operand_types)
                    wb = tgt.written_output_bytes()
                    if wb >= 0:
                        ob = wb
                st.hbm_bytes += ob + inb
                continue
            if oc == "dot":
                st.dot_flops += _dot_flops(op, comp)
                st.hbm_bytes += ob + inb
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                g = _group_size(op, default_group)
                msg = inb if base != "all-gather" else inb
                if base == "all-reduce":
                    wire = 2.0 * inb * (g - 1) / max(g, 1)
                elif base == "all-gather":
                    wire = inb * (g - 1)
                elif base in ("reduce-scatter", "all-to-all"):
                    wire = inb * (g - 1) / max(g, 1)
                else:  # collective-permute
                    wire = inb
                st.collective_wire_bytes += wire
                # bf16-promotion detection: f32 collective whose operand is
                # (or fuses) a convert from bf16 counts at half width on the
                # native-bf16 TPU target
                wire_tpu = wire
                if "f32[" in op.out_type:
                    # AllReducePromotion marks its reducer "*_promoted";
                    # FloatNormalization feeds collectives through convert
                    # fusions — both are CPU-only bf16 legalizations that a
                    # native-bf16 TPU target does not emit
                    promoted = "promoted" in op.rest or any(
                        "convert" in nm for nm in op.operand_names())
                    if promoted:
                        wire_tpu = wire / 2.0
                st.collective_wire_bytes_tpu += wire_tpu
                st.collective_msg_bytes += msg
                st.n_collectives += 1
                st.collective_by_kind[base] = \
                    st.collective_by_kind.get(base, 0.0) + wire
                st.hbm_bytes += ob + inb
                continue
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "done", "all-gather-done", "all-reduce-done",
                      "collective-permute-done", "copy-done", "async-done"):
                continue
            if oc in ("copy", "copy-start"):
                # loop-state / resharding copies: real on CPU, elided by
                # buffer aliasing on TPU -> tracked separately
                st.copy_bytes += ob + inb
                continue
            # plain (unfused) compute op: counts as its own kernel
            st.hbm_bytes += ob + inb
        memo[comp.name] = st
        return st

    return comp_stats(entry)


# ---------------------------------------------------------------------------
# Perf-iteration tooling: where do the collective bytes come from?
# ---------------------------------------------------------------------------


def collective_histogram(text: str, top: int = 20):
    """Trip-count-weighted (kind, operand-shape) histogram of collective
    wire bytes — the profile the §Perf hillclimb iterates on."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    hist: Dict[Tuple[str, str], float] = {}
    count: Dict[Tuple[str, str], int] = {}

    def walk(comp: Computation, mult: float):
        for opn in comp.order:
            op = comp.ops[opn]
            oc = op.opcode
            if oc == "while":
                body = comps.get((op.attr("body") or "").lstrip("%"))
                cond = comps.get((op.attr("condition") or "").lstrip("%"))
                trips = _trip_count(cond) if cond else 1
                if body:
                    walk(body, mult * trips)
                continue
            if oc == "call":
                tgt = comps.get((op.attr("to_apply") or "").lstrip("%"))
                if tgt:
                    walk(tgt, mult)
                continue
            if oc == "fusion":
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                inb = 0
                shapes = []
                for nm in op.operand_names():
                    d = comp.ops.get(nm)
                    if d is not None:
                        inb += _shape_bytes(d.out_type)
                        shapes.append(d.out_type.split("{")[0])
                g = _group_size(op, 1)
                if base == "all-reduce":
                    wire = 2.0 * inb * (g - 1) / max(g, 1)
                elif base == "all-gather":
                    wire = inb * (g - 1)
                elif base in ("reduce-scatter", "all-to-all"):
                    wire = inb * (g - 1) / max(g, 1)
                else:
                    wire = inb
                key = (base, ",".join(shapes[:2]) + f" g={g}")
                hist[key] = hist.get(key, 0.0) + wire * mult
                count[key] = count.get(key, 0) + int(mult)

    walk(entry, 1.0)
    rows = sorted(hist.items(), key=lambda kv: -kv[1])[:top]
    return [(k[0], k[1], v, count[k]) for k, v in rows]
