"""Fault-tolerant training loop.

Production behaviours implemented (and simulated where this container has no
cluster):

  * checkpoint/restart: async sharded checkpoints every `ckpt_every` steps;
    on *any* step failure the loop restores the latest checkpoint and
    replays — the stateless data pipeline guarantees the identical token
    stream (tests inject faults to exercise this path);
  * validate-and-update (zero-bubble style, paper §5.1): instead of a
    synchronous per-step NaN/inf check stalling the pipeline, the loss/grad
    norm is validated one step *behind*; a non-finite step triggers a
    rollback to the pre-step snapshot kept on host;
  * straggler mitigation: per-step wall time is tracked against an EMA; a
    step slower than `straggler_factor`x the EMA is logged and counted — on
    a real multi-host cluster the hook re-shards the slow host's data shard
    (here: surfaced in metrics; see DESIGN.md §5);
  * elastic scaling: `resume(mesh')` restores the newest checkpoint onto a
    different mesh via the checkpointer's elastic re-shard.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import Checkpointer


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 2.0
    ema_beta: float = 0.9
    validate_delay: bool = True     # zero-bubble delayed NaN check
    max_restarts: int = 3


@dataclass
class LoopStats:
    steps_done: int = 0
    restarts: int = 0
    rollbacks: int = 0
    straggler_events: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)


class TrainLoop:
    def __init__(self, step_fn: Callable, state: Any, batches: Iterator,
                 *, ckpt_dir: str, cfg: LoopConfig = LoopConfig(),
                 state_shardings: Any = None,
                 meta: Optional[Dict] = None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        """step_fn(state, batch) -> (state, metrics dict with 'loss').

        `fault_hook(step)` (tests) may raise to simulate a node failure.
        """
        self.step_fn = step_fn
        self.state = state
        self.batches = batches
        self.cfg = cfg
        self.ckpt = Checkpointer(ckpt_dir, keep=cfg.keep_ckpts)
        self.shardings = state_shardings
        self.meta = meta or {}
        self.fault_hook = fault_hook
        self.stats = LoopStats()
        self._step = 0
        self._ema_time: Optional[float] = None
        self._prev_snapshot: Any = None      # host copy for rollback
        self._prev_loss: Optional[float] = None

    # -- core ------------------------------------------------------------------
    def run(self) -> LoopStats:
        restarts = 0
        while self._step < self.cfg.total_steps:
            try:
                self._run_segment()
            except KeyboardInterrupt:
                raise
            except Exception as e:                     # node failure path
                restarts += 1
                self.stats.restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from e
                self._restore_latest()
        self.ckpt.wait()
        return self.stats

    def _run_segment(self):
        cfg = self.cfg
        while self._step < cfg.total_steps:
            batch = self.batches(self._step) if callable(self.batches) \
                else next(self.batches)
            if self.fault_hook is not None:
                self.fault_hook(self._step)
            t0 = time.time()
            if cfg.validate_delay:
                # keep a cheap host snapshot to roll back a bad step
                snapshot = None
                if self._step % cfg.ckpt_every == 0:
                    snapshot = jax.tree.map(np.asarray, self.state)
            new_state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            # delayed validation (zero-bubble validate-and-update)
            if not np.isfinite(loss):
                self.stats.rollbacks += 1
                if cfg.validate_delay and self._prev_snapshot is not None:
                    self.state = self._place(self._prev_snapshot)
                    self._step = self._snapshot_step
                    continue
                raise FloatingPointError(f"non-finite loss at {self._step}")
            if cfg.validate_delay and self._step % cfg.ckpt_every == 0 \
                    and snapshot is not None:
                self._prev_snapshot = snapshot
                self._snapshot_step = self._step

            self.state = new_state
            self._track(loss, dt)
            self._step += 1
            if self._step % cfg.ckpt_every == 0:
                self.ckpt.save_async(self._step, self.state,
                                     {"meta": self.meta})
        return self.stats

    # -- helpers -----------------------------------------------------------------
    def _track(self, loss: float, dt: float):
        st, cfg = self.stats, self.cfg
        st.steps_done += 1
        st.losses.append(loss)
        st.step_times.append(dt)
        if self._ema_time is None:
            self._ema_time = dt
        else:
            if dt > cfg.straggler_factor * self._ema_time:
                st.straggler_events += 1
            self._ema_time = (cfg.ema_beta * self._ema_time
                              + (1 - cfg.ema_beta) * dt)

    def _place(self, host_state):
        if self.shardings is not None:
            return jax.device_put(host_state, self.shardings)
        return jax.tree.map(jax.numpy.asarray, host_state)

    def _restore_latest(self):
        self.ckpt.wait()
        step = self.ckpt.latest_step()
        if step is None:
            self._step = 0
            return
        step, state, _ = self.ckpt.restore(step, shardings=self.shardings)
        self.state = state
        self._step = step

    # -- elastic resume ------------------------------------------------------------
    @staticmethod
    def resume(ckpt_dir: str, state_shardings: Any):
        """Restore the newest checkpoint onto (possibly different) shardings."""
        ck = Checkpointer(ckpt_dir)
        return ck.restore(shardings=state_shardings)
