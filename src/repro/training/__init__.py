from repro.training.optimizer import AdamConfig, adam_update, init_state  # noqa: F401
from repro.training.step import (  # noqa: F401
    make_prefill_step, make_serve_step, make_train_step, init_sharded_state,
)
