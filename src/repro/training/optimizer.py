"""Mixed-precision AdamW with ZeRO-partitionable, host-offloadable state.

State layout (flat dicts keyed by param name):
  state = {"step": i32, "params": bf16, "master": f32, "mu": f32, "nu": f32}

Any of master/mu/nu may be *split* along the stacked-layer dim into
``{"host": arr[:k], "dev": arr[k:]}`` to realize Mist's WO/OO offload ratios:
the host part carries a ``pinned_host`` memory-kind sharding, and XLA's
latency-hiding scheduler streams it through HBM during the (per-layer-
decoupled) optimizer update — the TPU analogue of Mist's repositioned
optimizer steps (paper §5.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.plan import StageConfig
from repro.models.common import Axes, Params
from repro.parallel.sharding import LAYER_AXES


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def is_split(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"host", "dev"}


def split_k(name: str, shape, axes_table: Axes, ratio: float) -> int:
    """How many leading (stacked-layer) slices go to host for this tensor."""
    if ratio <= 0.0 or not shape:
        return 0
    axes = axes_table.get(name, ())
    if not axes or axes[0] not in LAYER_AXES:
        return 0
    return int(round(ratio * shape[0]))


def _split(x, k):
    return {"host": x[:k], "dev": x[k:]} if k else x


def _join(leaf):
    if is_split(leaf):
        return jnp.concatenate([leaf["host"], leaf["dev"]], axis=0)
    return leaf


# ---------------------------------------------------------------------------
# state init (concrete + abstract) and shardings
# ---------------------------------------------------------------------------


def init_opt_entry(params: Params, axes_table: Axes, ratio: float,
                   like: str) -> Dict[str, Any]:
    """like: 'master' copies params to f32; 'zeros' makes f32 zeros."""
    out = {}
    for name, p in params.items():
        k = split_k(name, p.shape, axes_table, ratio)
        if like == "master":
            v = p.astype(jnp.float32) if not isinstance(p, jax.ShapeDtypeStruct) \
                else jax.ShapeDtypeStruct(p.shape, jnp.float32)
        else:
            v = jnp.zeros(p.shape, jnp.float32) if not isinstance(
                p, jax.ShapeDtypeStruct) else \
                jax.ShapeDtypeStruct(p.shape, jnp.float32)
        if k and isinstance(p, jax.ShapeDtypeStruct):
            out[name] = {"host": jax.ShapeDtypeStruct((k,) + p.shape[1:],
                                                      jnp.float32),
                         "dev": jax.ShapeDtypeStruct((p.shape[0] - k,)
                                                     + p.shape[1:],
                                                     jnp.float32)}
        else:
            out[name] = _split(v, k)
    return out


def init_state(params: Params, axes_table: Axes, stage: StageConfig
               ) -> Dict[str, Any]:
    return {
        "step": jnp.zeros((), jnp.int32) if not isinstance(
            next(iter(params.values())), jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct((), jnp.int32),
        "params": dict(params),
        "master": init_opt_entry(params, axes_table, stage.wo, "master"),
        "mu": init_opt_entry(params, axes_table, stage.oo, "zeros"),
        "nu": init_opt_entry(params, axes_table, stage.oo, "zeros"),
    }


# NOTE: the NamedSharding tree mirroring this state layout is produced by
# ``repro.lowering.LoweredPlan.state_shardings()`` — the single
# plan-interpretation pass (docs/plan-lowering.md).


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adam_update(state: Dict[str, Any], grads: Params, acfg: AdamConfig,
                shardings: Optional[Dict[str, Any]] = None,
                ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  grads: f32 flat dict (same keys as params).

    ``shardings`` (same structure as state) is required when any state leaf
    is host-offloaded: host slices are explicitly staged through device
    memory for the update, then placed back (XLA's latency-hiding scheduler
    overlaps these per-tensor transfers — the decoupled optimizer step)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, acfg.grad_clip / (gnorm + 1e-12))
    c1 = 1.0 - acfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - acfg.b2 ** step.astype(jnp.float32)

    _hk = compat.host_memory_kind()

    def to_dev(x, entry, name):
        if _hk is None:         # no host memory space: already resident
            return x
        sh = shardings[entry][name]["host"].with_memory_kind("device")
        return jax.device_put(x, sh)

    def to_host(x, entry, name):
        if _hk is None:
            return x
        return jax.device_put(x, shardings[entry][name]["host"])

    new_params, new_master, new_mu, new_nu = {}, {}, {}, {}
    for name, g in grads.items():
        g = g.astype(jnp.float32) * clip

        def upd(m, mu, nu, gg):
            mu = acfg.b1 * mu + (1 - acfg.b1) * gg
            nu = acfg.b2 * nu + (1 - acfg.b2) * gg * gg
            upd_ = (mu / c1) / (jnp.sqrt(nu / c2) + acfg.eps)
            m = m - acfg.lr * (upd_ + acfg.weight_decay * m)
            return m, mu, nu

        m, mu, nu = state["master"][name], state["mu"][name], state["nu"][name]
        if is_split(m) or is_split(mu):
            kh = (m["host"].shape[0] if is_split(m)
                  else mu["host"].shape[0])

            def part(leaf, entry, lo, hi):
                if is_split(leaf):
                    return (to_dev(leaf["host"], entry, name) if lo == 0
                            else leaf["dev"])
                return leaf[lo:hi]

            L_ = g.shape[0]
            mh, muh, nuh = upd(part(m, "master", 0, kh),
                               part(mu, "mu", 0, kh),
                               part(nu, "nu", 0, kh), g[:kh])
            md, mud, nud = upd(part(m, "master", kh, L_),
                               part(mu, "mu", kh, L_),
                               part(nu, "nu", kh, L_), g[kh:])

            def pack(leaf, entry, h, d):
                if is_split(leaf):
                    return {"host": to_host(h, entry, name), "dev": d}
                return jnp.concatenate([h, d], axis=0)

            new_master[name] = pack(m, "master", mh, md)
            new_mu[name] = pack(mu, "mu", muh, mud)
            new_nu[name] = pack(nu, "nu", nuh, nud)
            full_m = jnp.concatenate([mh, md], axis=0)
        else:
            full_m, new_mu[name], new_nu[name] = upd(m, mu, nu, g)
            new_master[name] = full_m
        new_params[name] = full_m.astype(state["params"][name].dtype)

    new_state = {"step": step, "params": new_params, "master": new_master,
                 "mu": new_mu, "nu": new_nu}
    metrics = {"grad_norm": gnorm}
    return new_state, metrics
