"""Step builders: LoweredPlan -> jitted train_step / serve_step.

``make_train_step`` realizes a single-stage plan (DP x TP x SP, ZeRO-0..3,
CKPT/AO remat segmentation, WO/OO host offload, optional int8 gradient
compression, gradient accumulation).  Pipeline (S>1) plans go through
``repro.parallel.pipeline``.

Every builder takes an optional pre-computed ``lowered`` (the output of
``repro.lowering.lower_plan``) and lowers the plan itself otherwise; all
mesh-axis mapping, sharding tables, and exec-config derivation live in
that one pass — nothing here interprets the plan directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro import compat
from repro.core.plan import Plan
from repro.lowering import LoweredPlan, lower_plan
from repro.models.common import ExecConfig, use_rules
from repro.models.zoo import Model
from repro.training import optimizer as OPT


@dataclass
class CompiledStep:
    fn: Callable                       # jitted
    state_shardings: Any
    batch_shardings: Any
    exec_cfg: ExecConfig


def _is_host_leaf(s) -> bool:
    hk = compat.host_memory_kind()
    return hk is not None and getattr(s, "memory_kind", None) == hk


def _constrain_device_leaves(tree, shardings):
    """Pin device-memory leaves to their planned shardings (host leaves are
    already placed by device_put inside the optimizer)."""
    def leaf(x, s):
        if isinstance(s, NamedSharding) and not _is_host_leaf(s):
            return jax.lax.with_sharding_constraint(x, s)
        return x
    return jax.tree.map(leaf, tree, shardings)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(model: Model, plan: Plan, mesh: Mesh,
                    adam: OPT.AdamConfig = OPT.AdamConfig(),
                    donate: bool = True,
                    lowered: Optional[LoweredPlan] = None) -> CompiledStep:
    assert plan.num_stages == 1, "use parallel.pipeline for S>1 plans"
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    ec = low.stages[0].exec_cfg
    rules = low.shard_rules()
    params_sds = low.params_sds
    state_abs = OPT.init_state(params_sds, low.axes_table, plan.stages[0])
    st_shardings = low.state_shardings()
    g_shardings = low.grad_shardings()

    G = plan.grad_accum

    def train_step(state, batch):
        with use_rules(rules):
            params = state["params"]

            def loss_of(p, mb):
                return model.loss_fn(p, mb, ec)

            if G == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
                grads = {n: g.astype(jnp.float32) for n, g in grads.items()}
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((G, x.shape[0] // G) + x.shape[1:]),
                    batch)
                zero_g = {n: jnp.zeros(s.shape, jnp.float32)
                          for n, s in params_sds.items()}
                zero_g = jax.lax.with_sharding_constraint(zero_g, g_shardings)

                def micro(acc, mb):
                    l, g = jax.value_and_grad(loss_of)(params, mb)
                    acc = {n: acc[n] + g[n].astype(jnp.float32) for n in acc}
                    acc = jax.lax.with_sharding_constraint(acc, g_shardings)
                    return acc, l

                grads, losses = jax.lax.scan(micro, zero_g, mbs)
                grads = {n: g / G for n, g in grads.items()}
                loss = jnp.mean(losses)

            grads = jax.lax.with_sharding_constraint(grads, g_shardings)
            if plan.grad_compression:
                from repro.parallel.compression import fake_compress
                grads = fake_compress(grads)
            new_state, om = OPT.adam_update(state, grads, adam, st_shardings)
            new_state = _constrain_device_leaves(new_state, st_shardings)
            metrics = {"loss": loss, **om, "step": new_state["step"]}
            return new_state, metrics

    batch_sh = None  # filled by caller via batch_shardings fn
    # NOTE: no explicit out_shardings — XLA's SPMD partitioner rejects them
    # when any output lives in pinned_host.  Host-offloaded slices are moved
    # back out *outside* the jit boundary (the post-step swap-out; a no-op
    # when nothing is offloaded).
    jit_fn = jax.jit(
        train_step,
        in_shardings=(st_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    has_host = any(_is_host_leaf(s) for s in jax.tree.leaves(st_shardings))
    if has_host:
        def fn(state, batch):
            new_state, metrics = jit_fn(state, batch)
            return jax.device_put(new_state, st_shardings), metrics
        fn.lower = jit_fn.lower  # type: ignore[attr-defined]  # dry-run lowers the jitted core
    else:
        fn = jit_fn
    return CompiledStep(fn=fn, state_shardings=st_shardings,
                        batch_shardings=batch_sh, exec_cfg=ec)


def init_sharded_state(model: Model, plan: Plan, mesh: Mesh, rng: jax.Array,
                       lowered: Optional[LoweredPlan] = None
                       ) -> Tuple[Dict[str, Any], Any]:
    """Materialize a sharded TrainState on the mesh."""
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    stage = plan.stages[0]
    axes_table = low.axes_table
    shardings = low.state_shardings()

    def build():
        params, _ = model.init(rng)
        return OPT.init_state(params, axes_table, stage)

    # jit-init with device-memory shardings (XLA SPMD rejects host memory
    # kinds on freshly-created values), then move host-offloaded slices out.
    dev_shardings = jax.tree.map(
        lambda s: NamedSharding(s.mesh, s.spec) if isinstance(
            s, NamedSharding) else s, shardings,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    state = jax.jit(build, out_shardings=dev_shardings)()
    needs_move = any(_is_host_leaf(s) for s in jax.tree.leaves(shardings))
    if needs_move:
        state = jax.device_put(state, shardings)
    return state, shardings


# ---------------------------------------------------------------------------
# serve steps (prefill / decode)
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, plan: Optional[Plan] = None,
                      mesh: Optional[Mesh] = None,
                      return_cache: bool = False,
                      lowered: Optional[LoweredPlan] = None) -> CompiledStep:
    if lowered is None and (plan is None or mesh is None):
        raise ValueError("make_prefill_step needs either lowered= or "
                         "(plan, mesh)")
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    ec = low.serve_exec_cfg
    rules = low.shard_rules()

    def prefill(params, batch):
        with use_rules(rules):
            return model.prefill_fn(params, batch, ec, return_cache)

    return CompiledStep(fn=jax.jit(prefill), state_shardings=None,
                        batch_shardings=None, exec_cfg=ec)


def make_serve_step(model: Model, plan: Optional[Plan] = None,
                    mesh: Optional[Mesh] = None,
                    batch: int = 1, max_len: int = 1, donate: bool = True,
                    lowered: Optional[LoweredPlan] = None) -> CompiledStep:
    """One-token decode against caches of length max_len."""
    if lowered is None and (plan is None or mesh is None):
        raise ValueError("make_serve_step needs either lowered= or "
                         "(plan, mesh)")
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    rules = low.shard_rules()

    kv_dtype = low.plan.kv_cache_dtype
    cache_dtype = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    caches_sds = jax.eval_shape(
        lambda: model.init_caches(batch, max_len, cache_dtype))
    cache_sh, update_mode = low.cache_shardings(caches_sds, batch)
    ec = low.serve_exec_cfg.replace(cache_update=update_mode)

    def serve(params, tokens, caches):
        with use_rules(rules):
            logits, new_caches = model.decode_fn(params, tokens, caches, ec)
            return logits, new_caches

    jit_fn = jax.jit(serve, in_shardings=(None, None, cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(2,) if donate else ())
    return CompiledStep(fn=jit_fn, state_shardings=None,
                        batch_shardings=cache_sh, exec_cfg=ec)


def make_paged_serve_step(model: Model, plan: Optional[Plan] = None,
                          mesh: Optional[Mesh] = None, *, slots: int,
                          max_len: int, page_size: int, donate: bool = True,
                          lowered: Optional[LoweredPlan] = None
                          ) -> CompiledStep:
    """One-token decode for the continuous-batching engine
    (docs/continuous-batching.md): KV lives in page pools, gathered into
    dense per-slot views through a block table, decoded with per-request
    position vectors, and the written row scattered back.

    Token identity with the contiguous path is BY CONSTRUCTION: gathered
    rows below each slot's position are the exact pages the contiguous
    cache would hold, rows at-or-beyond are masked to the zeros a fresh
    contiguous cache holds — so the dense tree entering ``decode_fn`` is
    bitwise the contiguous cache state, and per-row batch invariance does
    the rest.  Inactive slots carry pos = 0 / all-trash block tables:
    their masked rows are all-zero, their scattered writes land on the
    shared trash page, their logits are ignored by the engine.
    """
    from repro.serving.pages import classify_cache_tree
    if lowered is None and (plan is None or mesh is None):
        raise ValueError("make_paged_serve_step needs either lowered= or "
                         "(plan, mesh)")
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    rules = low.shard_rules()
    if max_len % page_size:
        raise ValueError(f"page_size {page_size} must divide max_len "
                         f"{max_len}")
    npp = max_len // page_size

    kv_dtype = low.plan.kv_cache_dtype
    cache_dtype = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    specs = classify_cache_tree(model.init_caches, slots, max_len,
                                cache_dtype)
    caches_sds = jax.eval_shape(
        lambda: model.init_caches(slots, max_len, cache_dtype))
    treedef = jax.tree.structure(caches_sds)
    # vector positions force cache_write's one-hot branch regardless of
    # the mode, so the base serve exec config is used as-is
    ec = low.serve_exec_cfg

    pos_ix = next((i for i, s in enumerate(specs) if s.is_pos), None)

    def _pos_vec(flat):
        # all pos leaves broadcast ONE per-request vector (engine
        # invariant); read it off the first one
        return flat[pos_ix].reshape(-1, slots)[0]

    def _gather(pool, block_table, pos_vec):
        lead, tail = pool.shape[0], pool.shape[3:]
        g = jnp.take(pool, block_table, axis=1)        # (lead,B,npp,ps,*t)
        g = g.reshape((lead, slots, max_len) + tail)
        # rows at-or-beyond each request's position read as the zeros a
        # fresh contiguous cache holds — page recycling and the trash
        # page never leak garbage into the dense view
        valid = jnp.arange(max_len)[None, :] < pos_vec[:, None]  # (B,S)
        valid = valid.reshape((1, slots, max_len) + (1,) * len(tail))
        return jnp.where(valid, g, jnp.zeros((), pool.dtype))

    def _scatter(pool, dense_new, block_table, pos_vec):
        # the decode step wrote exactly row pos_vec[b] of slot b; copy it
        # into the owning page (inactive/overflowing slots hit the trash
        # page via the block-table fill and the page-index clamp)
        lead, tail = pool.shape[0], pool.shape[3:]
        row = jnp.clip(pos_vec, 0, max_len - 1)                    # (B,)
        idx = row.reshape((1, slots, 1) + (1,) * len(tail))
        rows = jnp.take_along_axis(dense_new, idx, axis=2)
        rows = jnp.squeeze(rows, axis=2)                  # (lead,B,*tail)
        page = jnp.minimum(row // page_size, npp - 1)
        tgt = (block_table[jnp.arange(slots), page] * page_size
               + row % page_size)                                  # (B,)
        flat = pool.reshape((lead, pool.shape[1] * page_size) + tail)
        return flat.at[:, tgt].set(rows).reshape(pool.shape)

    def step(params, tokens, state, block_table):
        with use_rules(rules):
            flat = jax.tree.leaves(state)
            pos_vec = _pos_vec(flat) if pos_ix is not None else None
            dense = [
                _gather(leaf, block_table, pos_vec) if spec.paged else leaf
                for leaf, spec in zip(flat, specs)]
            caches = jax.tree.unflatten(treedef, dense)
            logits, new_caches = model.decode_fn(params, tokens, caches, ec)
            new_flat = jax.tree.leaves(new_caches)
            out = [
                _scatter(leaf, new, block_table, pos_vec) if spec.paged
                else new
                for leaf, new, spec in zip(flat, new_flat, specs)]
            return logits, jax.tree.unflatten(treedef, out)

    jit_fn = jax.jit(step, donate_argnums=(2,) if donate else ())
    return CompiledStep(fn=jit_fn, state_shardings=None,
                        batch_shardings=None, exec_cfg=ec)
