"""Step builders: Plan -> jitted train_step / serve_step with shardings.

``make_train_step`` realizes a single-stage plan (DP x TP x SP, ZeRO-0..3,
CKPT/AO remat segmentation, WO/OO host offload, optional int8 gradient
compression, gradient accumulation).  Pipeline (S>1) plans go through
``repro.parallel.pipeline``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.plan import Plan, StageConfig
from repro.models.common import ExecConfig, use_rules
from repro.models.zoo import Model, abstract_params, input_specs
from repro.parallel import sharding as SH
from repro.training import optimizer as OPT


def stage_exec_config(plan: Plan, stage: StageConfig, cfg: ArchConfig
                      ) -> ExecConfig:
    lyr = stage.layers
    return ExecConfig(
        ckpt_layers=min(stage.ckpt_layers, lyr),
        offload_layers=int(round(stage.ao * min(stage.ckpt_layers, lyr))),
        remat_policy=plan.remat_policy,
        attn_impl=plan.attn_impl,
        use_pallas=plan.use_pallas,
        sequence_parallel=plan.sequence_parallel,
    )


@dataclass
class CompiledStep:
    fn: Callable                       # jitted
    state_shardings: Any
    batch_shardings: Any
    exec_cfg: ExecConfig


def _is_host_leaf(s) -> bool:
    hk = compat.host_memory_kind()
    return hk is not None and getattr(s, "memory_kind", None) == hk


def _constrain_device_leaves(tree, shardings):
    """Pin device-memory leaves to their planned shardings (host leaves are
    already placed by device_put inside the optimizer)."""
    def leaf(x, s):
        if isinstance(s, NamedSharding) and not _is_host_leaf(s):
            return jax.lax.with_sharding_constraint(x, s)
        return x
    return jax.tree.map(leaf, tree, shardings)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(model: Model, plan: Plan, mesh: Mesh,
                    adam: OPT.AdamConfig = OPT.AdamConfig(),
                    donate: bool = True) -> CompiledStep:
    assert plan.num_stages == 1, "use parallel.pipeline for S>1 plans"
    cfg = model.cfg
    stage = plan.stages[0]
    ma = SH.MeshAxes.for_plan(mesh, stage.tp)
    ec = stage_exec_config(plan, stage, cfg)
    rules = SH.make_shard_rules(mesh, ma, plan.sequence_parallel)

    params_sds, axes_table = abstract_params(cfg)
    state_abs = OPT.init_state(params_sds, axes_table, stage)
    st_shardings = OPT.state_shardings(state_abs, axes_table, cfg, mesh, ma,
                                       stage)
    ep_ok = cfg.num_experts > 0 and (
        cfg.num_experts % mesh.shape.get(ma.tp, 1) == 0 if ma.tp else False)
    gspecs = {n: SH.grad_spec(n, s.shape, axes_table[n], mesh, ma,
                              zero=stage.zero, ep_ok=ep_ok)
              for n, s in params_sds.items()}
    g_shardings = {n: NamedSharding(mesh, sp) for n, sp in gspecs.items()}

    G = plan.grad_accum

    def train_step(state, batch):
        with use_rules(rules):
            params = state["params"]

            def loss_of(p, mb):
                return model.loss_fn(p, mb, ec)

            if G == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
                grads = {n: g.astype(jnp.float32) for n, g in grads.items()}
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((G, x.shape[0] // G) + x.shape[1:]),
                    batch)
                zero_g = {n: jnp.zeros(s.shape, jnp.float32)
                          for n, s in params_sds.items()}
                zero_g = jax.lax.with_sharding_constraint(zero_g, g_shardings)

                def micro(acc, mb):
                    l, g = jax.value_and_grad(loss_of)(params, mb)
                    acc = {n: acc[n] + g[n].astype(jnp.float32) for n in acc}
                    acc = jax.lax.with_sharding_constraint(acc, g_shardings)
                    return acc, l

                grads, losses = jax.lax.scan(micro, zero_g, mbs)
                grads = {n: g / G for n, g in grads.items()}
                loss = jnp.mean(losses)

            grads = jax.lax.with_sharding_constraint(grads, g_shardings)
            if plan.grad_compression:
                from repro.parallel.compression import fake_compress
                grads = fake_compress(grads)
            new_state, om = OPT.adam_update(state, grads, adam, st_shardings)
            new_state = _constrain_device_leaves(new_state, st_shardings)
            metrics = {"loss": loss, **om, "step": new_state["step"]}
            return new_state, metrics

    batch_sh = None  # filled by caller via batch_shardings fn
    # NOTE: no explicit out_shardings — XLA's SPMD partitioner rejects them
    # when any output lives in pinned_host.  Host-offloaded slices are moved
    # back out *outside* the jit boundary (the post-step swap-out; a no-op
    # when nothing is offloaded).
    jit_fn = jax.jit(
        train_step,
        in_shardings=(st_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    has_host = any(_is_host_leaf(s) for s in jax.tree.leaves(st_shardings))
    if has_host:
        def fn(state, batch):
            new_state, metrics = jit_fn(state, batch)
            return jax.device_put(new_state, st_shardings), metrics
        fn.lower = jit_fn.lower  # type: ignore[attr-defined]  # dry-run lowers the jitted core
    else:
        fn = jit_fn
    return CompiledStep(fn=fn, state_shardings=st_shardings,
                        batch_shardings=batch_sh, exec_cfg=ec)


def init_sharded_state(model: Model, plan: Plan, mesh: Mesh, rng: jax.Array
                       ) -> Tuple[Dict[str, Any], Any]:
    """Materialize a sharded TrainState on the mesh."""
    cfg = model.cfg
    stage = plan.stages[0]
    ma = SH.MeshAxes.for_plan(mesh, stage.tp)
    params_sds, axes_table = abstract_params(cfg)
    state_abs = OPT.init_state(params_sds, axes_table, stage)
    shardings = OPT.state_shardings(state_abs, axes_table, cfg, mesh, ma,
                                    stage)

    def build():
        params, _ = model.init(rng)
        return OPT.init_state(params, axes_table, stage)

    # jit-init with device-memory shardings (XLA SPMD rejects host memory
    # kinds on freshly-created values), then move host-offloaded slices out.
    dev_shardings = jax.tree.map(
        lambda s: NamedSharding(s.mesh, s.spec) if isinstance(
            s, NamedSharding) else s, shardings,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    state = jax.jit(build, out_shardings=dev_shardings)()
    needs_move = any(_is_host_leaf(s) for s in jax.tree.leaves(shardings))
    if needs_move:
        state = jax.device_put(state, shardings)
    return state, shardings


# ---------------------------------------------------------------------------
# serve steps (prefill / decode)
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, plan: Plan, mesh: Mesh,
                      return_cache: bool = False) -> CompiledStep:
    cfg = model.cfg
    stage = plan.stages[0]
    ma = SH.MeshAxes.for_plan(mesh, stage.tp)
    ec = stage_exec_config(plan, stage, cfg).replace(remat_policy="none",
                                                     ckpt_layers=0,
                                                     offload_layers=0)
    rules = SH.make_shard_rules(mesh, ma, plan.sequence_parallel)

    def prefill(params, batch):
        with use_rules(rules):
            return model.prefill_fn(params, batch, ec, return_cache)

    return CompiledStep(fn=jax.jit(prefill), state_shardings=None,
                        batch_shardings=None, exec_cfg=ec)


def make_serve_step(model: Model, plan: Plan, mesh: Mesh,
                    batch: int, max_len: int, donate: bool = True
                    ) -> CompiledStep:
    """One-token decode against caches of length max_len."""
    cfg = model.cfg
    stage = plan.stages[0]
    ma = SH.MeshAxes.for_plan(mesh, stage.tp)
    ec = stage_exec_config(plan, stage, cfg).replace(remat_policy="none",
                                                     ckpt_layers=0,
                                                     offload_layers=0)
    rules = SH.make_shard_rules(mesh, ma, plan.sequence_parallel)

    cache_dtype = jnp.int8 if plan.kv_cache_dtype == "int8" else jnp.bfloat16
    caches_sds = jax.eval_shape(
        lambda: model.init_caches(batch, max_len, cache_dtype))
    lead = 2 if cfg.family == "hybrid" else 1
    cache_sh = SH.cache_specs(caches_sds, mesh, ma, batch, lead_dims=1)
    ec = ec.replace(cache_update=SH.cache_update_mode(cache_sh, ma))

    def serve(params, tokens, caches):
        with use_rules(rules):
            logits, new_caches = model.decode_fn(params, tokens, caches, ec)
            return logits, new_caches

    jit_fn = jax.jit(serve, in_shardings=(None, None, cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(2,) if donate else ())
    return CompiledStep(fn=jit_fn, state_shardings=None,
                        batch_shardings=cache_sh, exec_cfg=ec)
