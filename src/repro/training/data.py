"""Deterministic, elastic data pipeline.

Batches are generated *statelessly* from (seed, step, shard): any worker can
reproduce any batch, so
  * restart-from-checkpoint resumes the exact token stream (no data loss or
    repeat) — the checkpoint only needs the step counter;
  * elastic rescaling (different dp size after restore) re-partitions the
    same global stream deterministically;
  * there is no shared iterator state to lose on a node failure.

Two sources:
  * `SyntheticLM` — zipf-ish synthetic token stream (benchmarks, smoke);
  * `PackedCorpus` — document packing with BOS/EOS + loss-mask over padding,
    for token files on disk (examples use a tiny embedded corpus).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import pathlib
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    # stable, collision-resistant per-(seed, step, shard) stream
    h = hashlib.blake2b(f"{seed}/{step}/{shard}".encode(), digest_size=8)
    return np.random.default_rng(int.from_bytes(h.digest(), "little"))


@dataclass(frozen=True)
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab_size: int
    n_shards: int = 1      # dp size; batch dim is split across shards
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class SyntheticLM:
    """Zipf-distributed synthetic LM tokens; (tokens, labels) next-token."""

    def __init__(self, spec: BatchSpec, seed: int = 0, zipf_a: float = 1.2):
        self.spec, self.seed, self.zipf_a = spec, seed, zipf_a

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        sp = self.spec
        rng = _rng_for(self.seed, step, sp.shard)
        v = sp.vocab_size
        toks = rng.zipf(self.zipf_a, size=(sp.local_batch, sp.seq_len + 1))
        toks = np.minimum(toks, v - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PackedCorpus:
    """Greedy document packing into fixed-length rows.

    Documents are arrays of token ids; rows are built by concatenating
    documents with EOS separators, padding the tail.  The loss mask zeroes
    padding.  Row assignment is stateless in (seed, step, shard).
    """

    def __init__(self, docs, spec: BatchSpec, seed: int = 0,
                 eos: int = 0, pad: int = 0):
        self.docs = [np.asarray(d, np.int32) for d in docs]
        assert self.docs, "empty corpus"
        self.spec, self.seed = spec, seed
        self.eos, self.pad = eos, pad

    def _pack_row(self, rng: np.random.Generator) -> Tuple[np.ndarray,
                                                           np.ndarray]:
        sp = self.spec
        L = sp.seq_len + 1
        row = np.full((L,), self.pad, np.int32)
        mask = np.zeros((L,), np.float32)
        pos = 0
        while pos < L:
            d = self.docs[int(rng.integers(len(self.docs)))]
            take = min(len(d), L - pos)
            row[pos:pos + take] = d[:take]
            mask[pos:pos + take] = 1.0
            pos += take
            if pos < L:
                row[pos] = self.eos
                mask[pos] = 1.0
                pos += 1
        return row, mask

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        sp = self.spec
        rng = _rng_for(self.seed, step, sp.shard)
        rows, masks = zip(*[self._pack_row(rng)
                            for _ in range(sp.local_batch)])
        rows = np.stack(rows)
        masks = np.stack(masks)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:],
                "loss_mask": masks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def microbatched(batch: Dict[str, np.ndarray], grad_accum: int
                 ) -> Dict[str, np.ndarray]:
    """(B, ...) -> (G, B/G, ...) stream layout for the pipeline step."""
    def rs(x):
        return x.reshape((grad_accum, x.shape[0] // grad_accum)
                         + x.shape[1:])
    return {k: rs(v) for k, v in batch.items()}
