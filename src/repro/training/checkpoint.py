"""Sharded, async, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_000042/
        MANIFEST.json        step, arch, plan, mesh shape, leaf index
        <flat-key>.npy       one file per state leaf (host-local full array
                             here; per-shard files on a multi-host runtime —
                             the manifest records the sharding so either
                             layout restores)

Fault-tolerance properties:
  * atomic: written to `<dir>/.tmp_<step>` then renamed — a crash mid-save
    never corrupts the latest checkpoint;
  * async: `save_async` snapshots device arrays to host (blocking only on
    the device->host copy) and writes in a background thread, double-
    buffered so at most one save is in flight;
  * elastic: `restore` takes *target* shardings — restoring onto a
    different mesh / plan re-shards via jax.device_put (elastic scaling,
    e.g. resume a 256-chip run on 512 chips);
  * self-describing: the manifest stores the Plan so a restarted job can
    rebuild the exact step function.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "::"   # flat-key separator (param names already contain '/')


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{SEP}{k}" if prefix else k))
        return out
    out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(SEP)
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[Dict] = None
             ) -> pathlib.Path:
        host = jax.tree.map(np.asarray, state)    # device->host snapshot
        return self._write(step, host, meta or {})

    def save_async(self, step: int, state: Any, meta: Optional[Dict] = None
                   ) -> None:
        """Snapshot synchronously (cheap D2H), write in the background."""
        self.wait()                                # double-buffer: one in flight
        host = jax.tree.map(np.asarray, state)
        meta = dict(meta or {})

        def work():
            try:
                self._write(step, host, meta)
            except BaseException as e:             # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def _write(self, step: int, host_state: Any, meta: Dict) -> pathlib.Path:
        flat = _flatten(host_state)
        tmp = self.dir / f".tmp_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = {}
        for key, arr in flat.items():
            arr = np.asarray(arr)
            fname = f"{abs(hash(key)) & 0xFFFFFFFF:08x}_{len(index):05d}.npy"
            dtype = str(arr.dtype)
            if dtype == "bfloat16":      # not a native numpy dtype: store
                np.save(tmp / fname, arr.view(np.uint16))   # raw bits
            else:
                np.save(tmp / fname, arr)
            index[key] = {"file": fname, "shape": list(arr.shape),
                          "dtype": dtype}
        manifest = {"step": step, "time": time.time(), "leaves": index,
                    **meta}
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=2))
        final = self.dir / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> Dict:
        p = self.dir / f"step_{step:09d}" / "MANIFEST.json"
        return json.loads(p.read_text())

    def restore(self, step: Optional[int] = None, *,
                shardings: Any = None) -> Tuple[int, Any, Dict]:
        """Load a checkpoint; `shardings` (a pytree of NamedShardings
        mirroring the state) re-shards elastically onto the current mesh.

        Returns (step, state, manifest).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        flat = {}
        for key, ent in manifest["leaves"].items():
            arr = np.load(d / ent["file"])
            if ent["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            flat[key] = arr
        state = _unflatten(flat)
        if shardings is not None:
            state = _reshard(state, shardings)
        return step, state, manifest


def _reshard(state: Any, shardings: Any) -> Any:
    """Elastic re-shard: place host arrays per target shardings (which may
    belong to a different mesh than the one that saved them)."""
    flat_s = _flatten(state)
    flat_h = _flatten(shardings)
    out = {}
    for k, arr in flat_s.items():
        sh = flat_h.get(k)
        if sh is None:
            out[k] = jax.numpy.asarray(arr)
        else:
            out[k] = jax.device_put(arr, sh)
    return _unflatten(out)
