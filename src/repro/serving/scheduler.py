"""Per-decode-step scheduling policy for the continuous-batching engine
(docs/continuous-batching.md).

Pure python — no jax: the scheduler decides WHAT happens each step
(admit / extend / preempt / retire) and the engine applies it to device
arrays.  Policy, in the order the engine runs it every step:

1. **Admit** (FCFS, bounded): while a decode slot is free, the waiting
   queue is non-empty, and the allocator clears its watermark for the
   head request's coverage, admit — at most ``max_admits_per_step``
   prefills per decode step, so long prompt bursts interleave with
   in-flight decodes instead of stalling them (chunked prefill).
2. **Extend**: every active request's page coverage grows to
   ``pos + 1`` before the step (the decode writes row ``pos``).  On
   pool exhaustion the YOUNGEST active request is preempted —
   restart-from-scratch: pages released, slot freed, request requeued
   at the queue head with its progress cleared (greedy decode is
   deterministic, so the replay emits identical tokens).
3. **Retire**: a request that has emitted ``max_new`` tokens releases
   its pages and slot immediately — no head-of-line blocking on the
   longest request in the batch.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

from repro.serving.pages import PagedKvAllocator, pages_for


@dataclass
class ServeRequest:
    """One generation request.  ``prompt`` is the model's batch-1 prefill
    batch dict ({"tokens": (1, plen), ...frontend stubs...}); the engine
    learns the true cache-row count from the prefill output (VLMs fuse a
    patch prefix into the cache)."""
    rid: Any
    prompt: Dict[str, Any]
    max_new: int
    # engine-managed (cleared on preemption)
    prefilled: Optional[tuple] = field(default=None, repr=False)


@dataclass
class SlotState:
    """Engine-side record of one active decode slot."""
    rid: Any
    req: ServeRequest
    pos: int          # cache rows written so far
    emitted: int      # tokens emitted so far (incl. the prefill token)
    max_new: int
    admit_seq: int    # monotone admission stamp (preemption picks max)


class ContinuousScheduler:
    """Slot + queue bookkeeping around a :class:`PagedKvAllocator`."""

    def __init__(self, *, slots: int, allocator: PagedKvAllocator,
                 max_admits_per_step: int = 1):
        if slots <= 0:
            raise ValueError("slots must be positive")
        self.slots = int(slots)
        self.allocator = allocator
        self.max_admits_per_step = int(max_admits_per_step)
        self.waiting: Deque[ServeRequest] = deque()
        self.active: Dict[int, SlotState] = {}      # slot -> state
        self._free_slots: List[int] = list(range(slots))
        self._seq = itertools.count()

    # -- queries --------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    def can_try_admit(self) -> bool:
        return bool(self.waiting and self._free_slots)

    def active_slots(self) -> List[int]:
        return sorted(self.active)

    def youngest_slot(self) -> int:
        return max(self.active, key=lambda s: self.active[s].admit_seq)

    # -- transitions ----------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.waiting.append(req)

    def admit(self, req: ServeRequest, rows: int,
              ignore_watermark: bool = False) -> int:
        """Bind the queue head to a free slot with ``rows + 1`` coverage
        (the first decode writes row ``rows``).  Caller gates on
        ``allocator.can_admit(rows + 1)``."""
        assert self.waiting and self.waiting[0] is req
        self.waiting.popleft()
        slot = self._free_slots.pop(0)
        self.allocator.admit(req.rid, rows + 1, ignore_watermark)
        self.active[slot] = SlotState(rid=req.rid, req=req, pos=rows,
                                      emitted=0, max_new=req.max_new,
                                      admit_seq=next(self._seq))
        return slot

    def retire(self, slot: int) -> SlotState:
        st = self.active.pop(slot)
        self.allocator.release(st.rid)
        self._free_slots.append(slot)
        self._free_slots.sort()
        return st

    def preempt_youngest(self) -> int:
        """Restart-from-scratch preemption: release the youngest active
        request and requeue it at the HEAD of the waiting queue with
        progress cleared.  Returns the freed slot."""
        slot = self.youngest_slot()
        st = self.active.pop(slot)
        self.allocator.release(st.rid)
        self._free_slots.append(slot)
        self._free_slots.sort()
        st.req.prefilled = None   # drop the stashed prefill: full replay
        self.waiting.appendleft(st.req)
        return slot

    def ensure_coverage(self, slot: int) -> Optional[List[int]]:
        """Grow ``slot``'s pages to cover the row this step writes.
        Returns new page ids ([] if already covered) or None when the
        pool is exhausted — caller preempts and retries."""
        st = self.active[slot]
        return self.allocator.extend(st.rid, st.pos + 1)

    def peak_pages(self, rows: int, max_new: int) -> int:
        """Worst-case simultaneous pages one request needs: admission
        coverage ``rows + 1`` or final-step coverage ``rows + max_new -
        1``, whichever is larger.  Must fit the pool or the request can
        never complete (checked at admission)."""
        ps = self.allocator.page_size
        return max(pages_for(rows + 1, ps),
                   pages_for(rows + max_new - 1, ps))
