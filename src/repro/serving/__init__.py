"""Continuous-batching serve engine over a paged KV cache
(docs/continuous-batching.md).

- :mod:`repro.serving.pages` — page-pool allocator + cache-tree paging
- :mod:`repro.serving.scheduler` — per-step admit/extend/preempt/retire
- :mod:`repro.serving.engine` — the engine driving the paged decode step
"""
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.pages import PagedKvAllocator, pages_for
from repro.serving.scheduler import ContinuousScheduler, ServeRequest

__all__ = ["ContinuousBatchingEngine", "PagedKvAllocator",
           "ContinuousScheduler", "ServeRequest", "pages_for"]
