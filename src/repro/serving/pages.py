"""Paged KV-cache allocation (docs/continuous-batching.md).

Two halves, both jax-optional at import time:

* ``PagedKvAllocator`` — pure-python page accounting over ONE shared
  pool id space: a free list, per-request block lists, a watermark
  admission gate, and a high-water mark.  Invariants (property-tested in
  tests/test_serving_engine.py):

    - a page is owned by at most one live request (no aliasing);
    - ``release``/preemption returns every owned page to the free list;
    - ``used + free == total`` at every point;
    - a request's page count is exactly ``ceil(covered_rows / page_size)``.

* cache-tree classification and pool construction — the bridge between
  the abstract cache pytree (``model.init_caches``) and the paged engine
  state.  A leaf is PAGED iff it is a KV-sequence leaf
  (``cache_layout.SEQ_CACHE_KEYS``) whose sequence extent is the decode
  horizon — established by probing ``jax.eval_shape`` with batch and
  max_len perturbed separately, so leading stacked dims that happen to
  equal the batch size can never be mistaken for it.  Paged leaves
  (lead, B, S, tail) become pools (lead, B*npp + 1, page_size, tail)
  whose last page is a shared TRASH page (inactive slots' writes land
  there); ``pos`` leaves widen to per-request vectors (orig_shape + (B,));
  everything else (SSM/mLSTM state, conv windows, enc-dec cross KV) stays
  slot-resident at batch = slots.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.lowering.cache_layout import SEQ_CACHE_KEYS


def pages_for(rows: int, page_size: int) -> int:
    """Pages needed to cover ``rows`` cache rows."""
    if rows <= 0:
        return 0
    return -(-rows // page_size)


class PagedKvAllocator:
    """Fixed-size page-pool accounting with watermark admission.

    ``num_pages`` counts DATA pages only (the engine's shared trash page
    is outside this id space).  Pages are handed out lowest-id-first so
    traces replay deterministically.
    """

    def __init__(self, *, num_pages: int, page_size: int,
                 watermark: int = 0):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        if watermark < 0 or watermark >= num_pages:
            raise ValueError(f"watermark {watermark} must be in "
                             f"[0, num_pages)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.watermark = int(watermark)
        self._free: List[int] = list(range(num_pages))  # ascending
        self._owned: Dict[Any, List[int]] = {}
        self.highwater = 0  # max pages ever simultaneously owned

    # -- accounting ----------------------------------------------------------

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.num_pages - len(self._free)

    def pages(self, rid) -> Tuple[int, ...]:
        return tuple(self._owned[rid])

    def owners(self) -> Tuple[Any, ...]:
        return tuple(self._owned)

    # -- admission / growth / release ---------------------------------------

    def can_admit(self, rows: int, ignore_watermark: bool = False) -> bool:
        """Admission gate: granting ``rows`` of coverage must leave at
        least ``watermark`` pages free (headroom for in-flight decodes
        to extend without immediate preemption).  ``ignore_watermark``
        drops the reserve to zero — the engine uses it when it is
        otherwise idle, where holding a request back can only deadlock."""
        floor = 0 if ignore_watermark else self.watermark
        return self.free - pages_for(rows, self.page_size) >= floor

    def admit(self, rid, rows: int,
              ignore_watermark: bool = False) -> List[int]:
        """Allocate coverage for ``rows`` to a new request.  The caller
        must gate on :meth:`can_admit`; admitting past the watermark is
        a bug, not a preemption trigger."""
        if rid in self._owned:
            raise ValueError(f"request {rid!r} already admitted")
        if not self.can_admit(rows, ignore_watermark):
            raise RuntimeError(f"admit({rid!r}, rows={rows}) below "
                               f"watermark {self.watermark}")
        n = pages_for(rows, self.page_size)
        got = [self._free.pop(0) for _ in range(n)]
        self._owned[rid] = got
        self.highwater = max(self.highwater, self.used)
        return list(got)

    def extend(self, rid, rows: int) -> Optional[List[int]]:
        """Grow ``rid``'s coverage to ``rows`` total.  Extension may dip
        below the watermark (the watermark gates ADMISSION only); returns
        the newly granted page ids, or None when the pool is exhausted —
        the caller preempts and retries."""
        owned = self._owned[rid]
        need = pages_for(rows, self.page_size) - len(owned)
        if need <= 0:
            return []
        if need > self.free:
            return None
        got = [self._free.pop(0) for _ in range(need)]
        owned.extend(got)
        self.highwater = max(self.highwater, self.used)
        return list(got)

    def release(self, rid) -> List[int]:
        """Retire or preempt: every owned page returns to the free list."""
        pages = self._owned.pop(rid)
        self._free.extend(pages)
        self._free.sort()
        return list(pages)


# ---------------------------------------------------------------------------
# Cache-tree classification and paged engine state
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeafSpec:
    """How one cache leaf maps into the paged engine state."""
    key: str                       # trailing pytree key
    shape: Tuple[int, ...]         # dense shape at (slots, max_len)
    paged: bool                    # carved into a page pool
    is_pos: bool                   # widened to a per-request vector
    bdim: Optional[int]            # batch dim (probe-established)


def classify_cache_tree(init_caches, slots: int, max_len: int,
                        cache_dtype=None) -> List[LeafSpec]:
    """Probe ``init_caches`` under jax.eval_shape to classify every leaf,
    in ``jax.tree.leaves`` order.

    Batch and sequence dims are found by PERTURBING the respective
    argument and diffing shapes — immune to a stacked lead dim that
    happens to equal the batch size (the by-value hazard the symbolic
    layout tolerates but a real allocator cannot).
    """
    import jax
    import jax.numpy as jnp
    cdt = jnp.bfloat16 if cache_dtype is None else cache_dtype
    base = jax.eval_shape(lambda: init_caches(slots, max_len, cdt))
    bpro = jax.eval_shape(lambda: init_caches(slots + 1, max_len, cdt))
    spro = jax.eval_shape(lambda: init_caches(slots, max_len + 1, cdt))
    flat = jax.tree_util.tree_leaves_with_path(base)
    bflat = jax.tree_util.tree_leaves(bpro)
    sflat = jax.tree_util.tree_leaves(spro)
    specs = []
    for (path, leaf), lb, ls in zip(flat, bflat, sflat):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        bdims = [i for i, (a, b) in enumerate(zip(leaf.shape, lb.shape))
                 if a != b]
        sdims = [i for i, (a, b) in enumerate(zip(leaf.shape, ls.shape))
                 if a != b]
        bdim = bdims[0] if len(bdims) == 1 else None
        paged = (key in SEQ_CACHE_KEYS and bdim is not None
                 and (bdim + 1) in sdims)
        if paged and bdim != 1:
            raise NotImplementedError(
                f"paged leaf {key!r} has batch dim {bdim}; the paged "
                f"engine expects exactly one stacked lead dim")
        specs.append(LeafSpec(key=key, shape=tuple(int(d) for d in
                                                   leaf.shape),
                              paged=paged, is_pos=(key == "pos"),
                              bdim=bdim))
    return specs


def data_pages(slots: int, max_len: int, page_size: int) -> int:
    """Data pages in every pool: full coverage for every slot.  Page id
    ``data_pages`` (one past the end) is the shared trash page."""
    if max_len % page_size:
        raise ValueError(f"page_size {page_size} must divide max_len "
                         f"{max_len} (dense gathered length must equal "
                         f"the contiguous cache length)")
    return slots * (max_len // page_size)


def init_paged_state(init_caches, specs: List[LeafSpec], slots: int,
                     max_len: int, page_size: int, cache_dtype=None):
    """Concrete engine state: the ``init_caches`` tree with paged leaves
    replaced by zeroed pools and ``pos`` leaves widened to int32
    per-request vectors.  Non-paged leaves keep their REAL initial values
    (mLSTM's ``m`` stabilizer initializes to a large negative, not 0)."""
    import jax
    import jax.numpy as jnp
    cdt = jnp.bfloat16 if cache_dtype is None else cache_dtype
    dense = init_caches(slots, max_len, cdt)
    treedef = jax.tree.structure(dense)
    flat = jax.tree.leaves(dense)
    npp = max_len // page_size
    pool_pages = data_pages(slots, max_len, page_size) + 1  # + trash
    out = []
    for leaf, spec in zip(flat, specs):
        if spec.paged:
            lead, tail = leaf.shape[0], leaf.shape[3:]
            out.append(jnp.zeros((lead, pool_pages, page_size) + tail,
                                 leaf.dtype))
        elif spec.is_pos:
            out.append(jnp.zeros(leaf.shape + (slots,), jnp.int32))
        else:
            out.append(leaf)
    del dense, flat
    bt = jnp.full((slots, npp), pool_pages - 1, jnp.int32)  # all trash
    return jax.tree.unflatten(treedef, out), bt


def paged_state_bytes(state, block_table) -> int:
    """Exact bytes the engine allocated (pools + slot state + block
    table) — compared bitwise against ``concrete_paged_cache_bytes`` at
    dp == tp == 1 in the contract tests."""
    import jax
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(state))
               + block_table.nbytes)
