"""Continuous-batching serve engine over a paged KV cache
(docs/continuous-batching.md).

``ContinuousBatchingEngine`` turns the one-shot ``generate()`` path into
a per-step admit/decode/retire loop: requests of mixed prompt/output
lengths share a fixed pool of decode slots and KV pages, new requests
are prefilled (batch-1) and paged in the moment a slot and pages free
up, and finished requests release both immediately.

Token identity with the contiguous path is the load-bearing contract:
admission reuses the REAL prefill program (never prefill-as-decode),
page-in copies the exact prefill rows, and the paged decode step
(``make_paged_serve_step``) reconstructs bitwise the contiguous cache
state before every token — so each request's greedy tokens equal
``launch.serve.generate()`` run at the same ``max_len``, token for
token (asserted in tests/test_serving_engine.py and the --trace
benchmark headline).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving.pages import (PagedKvAllocator, classify_cache_tree,
                                 data_pages, init_paged_state, pages_for,
                                 paged_state_bytes)
from repro.serving.scheduler import ContinuousScheduler, ServeRequest


class ContinuousBatchingEngine:
    """Drive a paged decode step under the continuous scheduler.

    Parameters mirror ``make_serve_step`` plus the paged knobs:
    ``slots`` decode rows ride every step, ``max_len`` is the global
    decode horizon (every request's rows + max_new must fit it), and
    ``page_size`` (``plan.page_size`` when tuned) carves each slot's KV
    into ``max_len / page_size`` pages.  ``watermark`` reserves free
    pages at admission so in-flight decodes can extend without instant
    preemption (default: one page per slot, clamped to the pool).
    """

    def __init__(self, model, params, plan=None, mesh=None, *, slots: int,
                 max_len: int, page_size: int,
                 watermark: Optional[int] = None,
                 max_admits_per_step: int = 1, lowered=None):
        import jax.numpy as jnp
        from repro.lowering import lower_plan
        from repro.training.step import (make_paged_serve_step,
                                         make_prefill_step)
        if lowered is None and (plan is None or mesh is None):
            raise ValueError("ContinuousBatchingEngine needs either "
                             "lowered= or (plan, mesh)")
        low = lowered or lower_plan(model.cfg, None, plan, mesh)
        self.model, self.params, self.low = model, params, low
        self.slots, self.max_len = int(slots), int(max_len)
        self.page_size = int(page_size)
        self.kv8 = low.plan.kv_cache_dtype == "int8"
        self._cache_dtype = jnp.int8 if self.kv8 else jnp.bfloat16

        self.specs = classify_cache_tree(model.init_caches, self.slots,
                                         self.max_len, self._cache_dtype)
        self.npp = self.max_len // self.page_size
        self.n_data_pages = data_pages(self.slots, self.max_len,
                                       self.page_size)
        self.trash_page = self.n_data_pages
        wm = (min(self.slots, self.n_data_pages - 1) if watermark is None
              else watermark)
        self.allocator = PagedKvAllocator(num_pages=self.n_data_pages,
                                          page_size=self.page_size,
                                          watermark=wm)
        self.sched = ContinuousScheduler(
            slots=self.slots, allocator=self.allocator,
            max_admits_per_step=max_admits_per_step)

        self.state, bt = init_paged_state(
            model.init_caches, self.specs, self.slots, self.max_len,
            self.page_size, self._cache_dtype)
        self.block_table = np.array(bt)   # mutable host copy; (slots, npp)
        self._prefill = make_prefill_step(model, return_cache=True,
                                          lowered=low)
        self._step = make_paged_serve_step(
            model, slots=self.slots, max_len=self.max_len,
            page_size=self.page_size, lowered=low)
        self._tokens = np.zeros((self.slots, 1), np.int32)
        self.results: Dict[Any, List[int]] = {}
        self.steps_run = 0
        self._rid_seq = 0

    # -- public API -----------------------------------------------------------

    def submit(self, prompt: Dict[str, Any], max_new: int,
               rid: Any = None) -> Any:
        """Queue one request; returns its id."""
        if rid is None:
            rid = self._rid_seq
            self._rid_seq += 1
        self.sched.submit(ServeRequest(rid=rid, prompt=prompt,
                                       max_new=int(max_new)))
        return rid

    def run(self) -> Dict[Any, np.ndarray]:
        """Drive the loop until every submitted request retires; returns
        {rid: generated token ids} (length max_new each)."""
        while self.sched.has_work():
            admitted = self._admission_pass()
            self._coverage_pass()
            if not self.sched.active:
                if self.sched.waiting and not admitted:
                    head = self.sched.waiting[0]
                    raise RuntimeError(
                        f"request {head.rid!r} cannot be admitted with an "
                        f"idle engine: pool of {self.n_data_pages} pages x "
                        f"{self.page_size} rows (watermark "
                        f"{self.allocator.watermark}) is too small")
                continue   # everything retired at admission (max_new == 1)
            self._decode_step()
        return {rid: np.asarray(toks, np.int32)
                for rid, toks in self.results.items()}

    def memory_bytes(self) -> int:
        """Exact bytes of the engine's cache state (pools + slot tree +
        block table) — the contract tests compare this bitwise against
        ``concrete_paged_cache_bytes`` at dp == tp == 1."""
        import jax.numpy as jnp
        return paged_state_bytes(self.state,
                                 jnp.asarray(self.block_table))

    # -- scheduling passes ----------------------------------------------------

    def _admission_pass(self) -> int:
        admitted = 0
        while (admitted < self.sched.max_admits_per_step
               and self.sched.can_try_admit()):
            req = self.sched.waiting[0]
            if req.prefilled is None:
                req.prefilled = self._run_prefill(req)
            first_tok, caches, rows = req.prefilled
            if rows + req.max_new > self.max_len:
                raise ValueError(
                    f"request {req.rid!r}: {rows} prompt rows + "
                    f"{req.max_new} new tokens exceed max_len "
                    f"{self.max_len}")
            if self.sched.peak_pages(rows, req.max_new) > self.n_data_pages:
                raise ValueError(
                    f"request {req.rid!r} needs more pages than the "
                    f"whole pool at page_size {self.page_size}")
            # the watermark reserve only makes sense with decodes in
            # flight; an idle engine admits on raw free pages
            idle = not self.sched.active and not admitted
            if not self.allocator.can_admit(rows + 1,
                                            ignore_watermark=idle):
                break                      # pages below watermark: wait
            slot = self.sched.admit(req, rows, ignore_watermark=idle)
            req.prefilled = None           # drop the stashed cache tree
            self.results[req.rid] = []     # preemption replay starts over
            self._install(slot, req.rid, caches, rows)
            self._record_token(slot, first_tok)
            admitted += 1
        return admitted

    def _coverage_pass(self) -> None:
        for slot in self.sched.active_slots():
            if slot not in self.sched.active:
                continue                   # preempted below us this pass
            while True:
                got = self.sched.ensure_coverage(slot)
                if got is not None:
                    if got:
                        self._sync_block_row(slot)
                    break
                victim = self.sched.preempt_youngest()
                self._clear_slot(victim)
                if victim == slot:
                    break                  # we were the youngest: requeued

    def _decode_step(self) -> None:
        import jax.numpy as jnp
        logits, self.state = self._step.fn(
            self.params, jnp.asarray(self._tokens), self.state,
            jnp.asarray(self.block_table))
        # greedy argmax on device — the same op the static path runs
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                          np.int32)
        self.steps_run += 1
        for slot in self.sched.active_slots():
            st = self.sched.active[slot]
            st.pos += 1                    # mirrors the in-step pos + 1
            self._record_token(slot, int(toks[slot]))

    # -- device-state plumbing ------------------------------------------------

    def _run_prefill(self, req: ServeRequest):
        """Batch-1 prefill (+ int8 quantization under int8 plans); returns
        (first greedy token, cache tree, cache rows)."""
        import jax
        import jax.numpy as jnp
        from repro.models.zoo import quantize_caches
        logits, caches = self._prefill.fn(self.params, req.prompt)
        if self.kv8:
            caches = quantize_caches(caches)
        first = int(jnp.argmax(logits[0, -1]))
        rows = None
        flat = jax.tree.leaves(caches)
        for leaf, spec in zip(flat, self.specs):
            if spec.paged:
                rows = int(leaf.shape[spec.bdim + 1])
                break
            if rows is None and spec.is_pos:
                rows = int(np.asarray(leaf).reshape(-1)[0])
        if rows is None:                   # pure-state families (SSM)
            rows = int(req.prompt["tokens"].shape[1])
        return first, caches, rows

    def _install(self, slot: int, rid, caches, rows: int) -> None:
        """Page prefill KV into the owned pages and copy slot-resident
        state (+ per-request pos) into decode row ``slot``."""
        import jax
        import jax.numpy as jnp
        pages = self.allocator.pages(rid)
        flat = jax.tree.leaves(self.state)
        pflat = jax.tree.leaves(caches)
        out = []
        for leaf, pleaf, spec in zip(flat, pflat, self.specs):
            if spec.paged:
                out.append(self._page_in(leaf, pleaf, pages, rows))
            elif spec.is_pos:
                out.append(leaf.at[..., slot].set(rows))
            elif spec.bdim is not None:
                val = jnp.take(pleaf, 0, axis=spec.bdim)
                ix = (slice(None),) * spec.bdim + (slot,)
                out.append(leaf.at[ix].set(val.astype(leaf.dtype)))
            else:                                    # pragma: no cover
                out.append(leaf)
        self.state = jax.tree.unflatten(jax.tree.structure(self.state),
                                        out)
        self._sync_block_row(slot)

    def _page_in(self, pool, pleaf, pages, rows: int):
        import jax.numpy as jnp
        ps = self.page_size
        n_used = pages_for(rows, ps)
        if n_used == 0:
            return pool
        lead, tail = pool.shape[0], pool.shape[3:]
        x = jnp.take(pleaf, 0, axis=1)               # (lead, rows, *tail)
        pad = n_used * ps - rows
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((lead, pad) + tail, x.dtype)], axis=1)
        x = x.reshape((lead, n_used, ps) + tail)
        ids = jnp.asarray(pages[:n_used], jnp.int32)
        return pool.at[:, ids].set(x)

    def _sync_block_row(self, slot: int) -> None:
        st = self.sched.active[slot]
        pages = self.allocator.pages(st.rid)
        row = np.full((self.npp,), self.trash_page, np.int32)
        row[:len(pages)] = pages
        self.block_table[slot] = row

    def _clear_slot(self, slot: int) -> None:
        """Neutralize a freed slot: all-trash block table (its in-step
        writes land on the trash page), pos = 0 (its gathered rows mask
        to zero), token 0."""
        import jax
        self.block_table[slot] = self.trash_page
        self._tokens[slot] = 0
        flat = jax.tree.leaves(self.state)
        out = [leaf.at[..., slot].set(0) if spec.is_pos else leaf
               for leaf, spec in zip(flat, self.specs)]
        self.state = jax.tree.unflatten(jax.tree.structure(self.state),
                                        out)

    def _record_token(self, slot: int, tok: int) -> None:
        st = self.sched.active[slot]
        self.results[st.rid].append(tok)
        st.emitted += 1
        self._tokens[slot] = tok
        if st.emitted >= st.max_new:
            self.sched.retire(slot)
            self._clear_slot(slot)
