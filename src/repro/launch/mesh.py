"""Production mesh construction (defined as functions so importing this
module never touches jax device state).  Meshes are built through
`repro.compat` so both old (0.4.x) and current jax APIs work."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Spec mesh: 16x16 (data, model) per pod; 2x16x16 (pod, data, model)
    for the two-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_plan_mesh(dp: int, tp: int, *, stages: int = 1,
                   pod: Optional[int] = None):
    """Mesh view for an arbitrary plan: (stage?, pod?, data, model)."""
    shape: Tuple[int, ...] = ()
    axes: Tuple[str, ...] = ()
    if stages > 1:
        shape += (stages,)
        axes += ("stage",)
    if pod and pod > 1:
        shape += (pod,)
        axes += ("pod",)
    shape += (dp, tp)
    axes += ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n: Optional[int] = None, tp: int = 1):
    """Small CPU mesh for tests/examples."""
    n = n or len(jax.devices())
    dp = n // tp
    return compat.make_mesh((dp, tp), ("data", "model"))
