"""Training launcher.

    python -m repro.launch.train --arch granite-3-8b --steps 50 --smoke
    python -m repro.launch.train --arch qwen2-72b --tune --devices 256 \
        --seq 4096 --global-batch 256           # tune-only (prints the plan)

`--smoke` runs a reduced same-family config end-to-end on the host CPU
devices (the full configs are exercised via the dry-run); otherwise the
launcher tunes/loads a Plan for the production mesh and either executes
(when the mesh is available) or emits the plan + predicted throughput.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from repro import compat
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--space", default="mist")
    ap.add_argument("--tune", action="store_true",
                    help="run the Mist tuner and print the plan")
    ap.add_argument("--memo-dir", default=None,
                    help="persistent tuning memo store "
                         "(core/memo_store.py): warm (arch, mesh, batch) "
                         "queries answer in milliseconds, cold sweeps "
                         "persist their frontiers for future runs")
    ap.add_argument("--plan-json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config on host devices")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure this arch's reduced golden cells on the "
                         "host devices and fit a per-platform "
                         "CalibrationProfile (docs/calibration.md)")
    ap.add_argument("--calibration-out", default=None, metavar="PATH|auto",
                    help="with --calibrate: persist the fitted profile "
                         "(auto = the platform's default cache location)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs.base import ShapeConfig, get_arch
    from repro.core.plan import Plan

    cfg = get_arch(args.arch)
    shape = ShapeConfig("cli", args.seq, args.global_batch, "train")

    if args.calibrate:
        from repro.calibration.driver import format_table, run_calibration
        report = run_calibration(archs=(args.arch,),
                                 steps=min(args.steps, 6),
                                 write_profile=args.calibration_out)
        print(format_table(report))
        if report.get("error"):
            return 1
        return 0 if (report["mean_err_fitted"]
                     <= report["mean_err_uncalibrated"] + 1e-12) else 1

    plan = None
    if args.plan_json:
        plan = Plan.from_json(pathlib.Path(args.plan_json).read_text())
    elif args.tune:
        from repro.core.tuner import tune
        rep = tune(cfg, shape, args.devices, space=args.space,
                   memo_dir=args.memo_dir)
        if rep.plan is None:
            print("INFEASIBLE for this device count / batch")
            return 1
        print(f"# tuned in {rep.tune_seconds:.1f}s over {rep.n_points} "
              f"configs; predicted step {rep.objective:.3f}s "
              f"({rep.throughput_samples:.2f} samples/s)"
              + (" [memo-store hit]" if rep.from_memo else ""))
        print(rep.plan.to_json())
        plan = rep.plan

    if plan is not None:
        # tuner->runtime consistency: what the cost model predicted vs what
        # the lowered spec tables actually hold per device
        if args.space == "serve":
            # serving plans are priced by the serve cost model; check the
            # bitwise serve contract (docs/serving.md), not the training
            # memory model
            from repro.core.costmodel import estimate_serve_plan
            from repro.lowering import lower_plan
            st0 = plan.stages[0]
            sshape = ShapeConfig("cli", args.seq, args.global_batch,
                                 "decode")
            mesh = compat.abstract_mesh((st0.dp, st0.tp),
                                        ("data", "model"))
            rep = lower_plan(cfg, sshape, plan, mesh).memory_report()
            est = estimate_serve_plan(cfg, sshape, plan)
            print(f"# serve memory: predicted "
                  f"{est['mem_decode'] / 2**30:.2f} GiB lowered "
                  f"{rep.peak_bytes / 2**30:.2f} GiB "
                  f"(bitwise={est['mem_decode'] == rep.peak_bytes})")
        else:
            from repro.lowering import memory_consistency
            mc = memory_consistency(cfg, shape, plan)
            print(f"# memory: predicted "
                  f"{mc['predicted_bytes'] / 2**30:.2f} GiB "
                  f"lowered {mc['lowered_bytes'] / 2**30:.2f} GiB "
                  f"(rel err {mc['rel_error']:.3f}, "
                  f"within_tol={mc['within_tol']})")
        if args.tune and not args.smoke:
            return 0

    if not args.smoke:
        print("no --smoke and no executable mesh: use --tune to produce a "
              "plan, or repro.launch.dryrun to compile for the production "
              "mesh")
        return 0

    # ---- smoke training on host devices ------------------------------------
    from repro.core.plan import single_stage_plan
    from repro.launch.mesh import make_host_mesh
    from repro.lowering import lower_plan
    from repro.models.zoo import build_model
    from repro.training.data import BatchSpec, SyntheticLM
    from repro.training.loop import LoopConfig, TrainLoop
    from repro.training.step import init_sharded_state, make_train_step

    rcfg = cfg.reduced()
    model = build_model(rcfg)
    n = len(jax.devices())
    tp = 2 if n % 2 == 0 and rcfg.num_heads % 2 == 0 else 1
    dp = n // tp
    gbs = max(8, dp * 2)
    plan = single_stage_plan(rcfg.num_layers, dp=dp, tp=tp,
                             micro_batch=gbs // dp // 2 or 1, grad_accum=2,
                             zero=1, ckpt_layers=rcfg.num_layers // 2)
    mesh = make_host_mesh(n, tp)
    seq = 128
    smoke_shape = ShapeConfig("smoke", seq, gbs, "train")
    low = lower_plan(rcfg, smoke_shape, plan, mesh)
    rep = low.memory_report()
    print(f"# smoke plan lowered: peak {rep.peak_bytes / 2**30:.2f} GiB "
          f"per device (fits={rep.fits})")
    with compat.set_mesh(mesh):
        step = make_train_step(model, plan, mesh, lowered=low)
        state, shardings = init_sharded_state(model, plan, mesh,
                                              jax.random.PRNGKey(0),
                                              lowered=low)
        data = SyntheticLM(BatchSpec(global_batch=gbs, seq_len=seq,
                                     vocab_size=rcfg.vocab_size))

        def batches(step_idx):
            b = data.batch(step_idx)
            return {k: jnp.asarray(v) for k, v in b.items()}

        loop = TrainLoop(step.fn, state, batches, ckpt_dir=args.ckpt_dir,
                         cfg=LoopConfig(total_steps=args.steps,
                                        ckpt_every=args.ckpt_every),
                         state_shardings=shardings,
                         meta={"arch": rcfg.name})
        t0 = time.time()
        stats = loop.run()
        dt = time.time() - t0
    print(f"trained {stats.steps_done} steps in {dt:.1f}s "
          f"({dt / max(1, stats.steps_done):.2f}s/step); "
          f"loss {stats.losses[0]:.3f} -> {stats.losses[-1]:.3f}; "
          f"restarts={stats.restarts} rollbacks={stats.rollbacks}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
