import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes (16x16 single-pod, 2x16x16 multi-pod), record
memory_analysis / cost_analysis / trip-count-aware HLO stats, and emit the
roofline terms.

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod both|on|off]
    python -m repro.launch.dryrun --all --plan-json plan.json
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_arch, list_archs
from repro.core.plan import Plan, single_stage_plan
from repro.launch.mesh import make_production_mesh
from repro.lowering import LoweredPlan, lower_plan
from repro.lowering.memory import stage_state_bytes
from repro.models import build_model
from repro.models.zoo import abstract_params, input_specs
from repro.perf.hloanalysis import analyze
from repro.perf.roofline import model_flops_for, report_from_stats
from repro.training import optimizer as OPT
from repro.training.step import (make_prefill_step, make_serve_step,
                                 make_train_step)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def state_bytes_per_device(cfg: ArchConfig, mesh, zero: int) -> float:
    """EXACT model-state bytes per chip for a zero level: lowers a trial
    plan and walks every param's actual PartitionSpec (indivisible dims —
    MHA head counts, small norms — really do replicate, which naive
    N/(dp*tp) accounting misses)."""
    tp = mesh.shape.get("model", 1)
    n_dev = 1
    for s in mesh.shape.values():
        n_dev *= s
    trial = single_stage_plan(cfg.num_layers, dp=max(1, n_dev // tp), tp=tp,
                              micro_batch=1, grad_accum=1, zero=zero)
    return stage_state_bytes(lower_plan(cfg, None, trial, mesh))


def min_fitting_zero(cfg: ArchConfig, mesh,
                     budget: float = 0.6 * 16 * 2**30) -> int:
    """Smallest ZeRO level whose model-state bytes fit the per-chip budget.

    Megatron-LM's --use-distributed-optimizer corresponds to ZeRO>=1; the
    paper's point is that this knob must be co-tuned, so the *baseline* picks
    the smallest feasible level (what a careful engineer would hand-pick)."""
    for zero in (1, 2, 3):
        if state_bytes_per_device(cfg, mesh, zero) < budget:
            return zero
    return 3


def analytic_memory(lowered: LoweredPlan) -> Dict[str, Any]:
    """TPU-target memory estimate (bytes/chip), independent of the host
    compile artifact.  XLA:CPU's FloatNormalization legalizes bf16 compute
    through f32 buffers (whole-cache/param f32 copies visible in the host
    HLO), so the compiled `memory_analysis` OVERESTIMATES what the TPU
    (native-bf16 MXU) target allocates; this analytic estimate is the
    TPU-side number and EXPERIMENTS.md reports both.

    Train cells report BOTH sides of the lowering contract: the symbolic
    prediction (``analytic_bytes``, what the tuner believed) and the
    spec-walked ``lowered_bytes`` from ``LoweredPlan.memory_report`` (what
    the lowered program holds), plus their relative gap — the
    tuner->runtime consistency signal (docs/plan-lowering.md)."""
    cfg, shape, plan = lowered.cfg, lowered.shape, lowered.plan
    rep = lowered.memory_report()
    if shape.kind == "train":
        from repro.core.costmodel import estimate_plan
        est = estimate_plan(cfg, shape, plan)
        pred = float(est["mem_peak_max"])
        return {"analytic_bytes": pred,
                "fits_16GiB_analytic": bool(est["fits"]),
                "lowered_bytes": rep.peak_bytes,
                "fits_16GiB_lowered": bool(rep.fits),
                "predicted_vs_lowered_rel":
                    abs(rep.peak_bytes - pred) / max(pred, 1.0)}
    # serving: exact params-per-chip (+ cache-per-chip) + transient, all
    # from the lowered spec tables
    return {"analytic_bytes": rep.peak_bytes,
            "fits_16GiB_analytic": bool(rep.peak_bytes < 16 * 2**30),
            "lowered_bytes": rep.peak_bytes}


def analytic_hbm_traffic(cfg: ArchConfig, shape: ShapeConfig,
                         plan: Plan) -> Optional[float]:
    """TPU-target HBM bytes per chip per step (the artifact's byte count
    reflects XLA:CPU fusion boundaries + f32 legalization; see DESIGN §8).
    Train cells use the cost-model traffic expression; serve cells use
    weights+cache per token."""
    from repro.core.costmodel import StageCostModel
    from repro.core.schedule import Candidate
    st = plan.stages[0]
    try:
        if shape.kind == "train":
            scm = StageCostModel(cfg, shape.seq_len,
                                 sequence_parallel=plan.sequence_parallel)
            cand = Candidate(b=st.micro_batch, dp=st.dp, tp=st.tp,
                             zero=st.zero,
                             ckpt=min(st.ckpt_layers, st.layers), wo=st.wo,
                             go=st.go, oo=st.oo, ao=st.ao)
            env = scm._env(scm.env_from_candidates(
                [cand], layers=st.layers, grad_accum=plan.grad_accum))
            import numpy as np
            return float(np.asarray(
                scm.hbm_bytes_step.evaluate(env)).reshape(-1)[0])
        # serving: weights once + cache read(+write for decode)
        n = cfg.param_count()
        w = 2.0 * n / (st.tp * (st.dp if st.zero >= 3 else 1))
        if shape.kind == "prefill":
            tokens_local = shape.global_batch * shape.seq_len / st.dp
            from repro.core.costmodel import arch_stats
            stt = arch_stats(cfg)
            act = 4.0 * stt.act_coef_full * stt.d_model * tokens_local \
                / max(1, st.tp)
            return st.layers and w * 1.0 + act
        return None   # decode: cache-spec-dependent; artifact number kept
    except Exception:
        return None


def baseline_plan(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  overrides: Optional[Dict[str, Any]] = None) -> Plan:
    """Paper-faithful Megatron-style baseline: TP over the model axis, DP
    over data(+pod), minimum feasible ZeRO, full activation checkpointing,
    micro-batch 1, FlashAttention on (the paper's Fig. 11 setting)."""
    ov = dict(overrides or {})
    tp = ov.pop("tp", mesh.shape.get("model", 1))
    # a tp=1 plan folds the model axis into DP (lowering.plan_mesh_axes),
    # so dp always spans all chips divided by tp
    dp = ov.pop("dp", mesh.devices.size // tp)
    ov.setdefault("attn_impl", "blocked")
    if "zero" not in ov:
        if shape.kind == "train":
            ov["zero"] = min_fitting_zero(cfg, mesh)
        else:
            ov["zero"] = 0   # serving: replicated weights per TP group
            #                  (zero=3 override = weight-gathered serving)
    if shape.kind == "train":
        micro = ov.pop("micro_batch", 1)
        assert shape.global_batch % (dp * micro) == 0, (shape, dp, micro)
        grad_accum = ov.pop("grad_accum", shape.global_batch // (dp * micro))
    else:
        micro = max(1, shape.global_batch // dp)
        grad_accum = 1
    return single_stage_plan(cfg.num_layers, dp=dp, tp=tp, micro_batch=micro,
                             grad_accum=grad_accum, **ov)


def _attach(sds_tree, shardings):
    return jax.tree.map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        sds_tree, shardings)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               plan_overrides: Optional[Dict[str, Any]] = None,
               save_hlo: bool = False, hw_check: bool = True,
               view: Optional[str] = None) -> Dict[str, Any]:
    """view: 'DPxTP' reshapes the SAME chips into a different (data, model)
    mesh for an optimized plan (the spec mesh stays the baseline)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name not in cfg.shapes:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "shape not applicable (see DESIGN.md §4)"}
    if view:
        dpv, tpv = (int(x) for x in view.split("x"))
        mesh = compat.make_mesh((dpv, tpv), ("data", "model"))
        plan_overrides = dict(plan_overrides or {})
        plan_overrides.setdefault("dp", dpv)
        plan_overrides.setdefault("tp", tpv)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    model = build_model(cfg)
    plan = baseline_plan(cfg, shape, mesh, plan_overrides)
    low = lower_plan(cfg, shape, plan, mesh)
    params_sds, axes_table = low.params_sds, low.axes_table

    t0 = time.time()
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(model, plan, mesh, lowered=low)
            state_abs = OPT.init_state(params_sds, axes_table, plan.stages[0])
            state_sds = _attach(state_abs, step.state_shardings)
            batch = input_specs(cfg, shape)
            batch_sds = _attach(batch, low.batch_shardings(batch))
            program = step.fn.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, plan, mesh, lowered=low)
            p_sds = _attach(params_sds, low.param_shardings())
            batch = input_specs(cfg, shape)
            batch_sds = _attach(batch, low.batch_shardings(batch))
            program = step.fn.lower(p_sds, batch_sds)
        else:  # decode
            b, s = shape.global_batch, shape.seq_len
            step = make_serve_step(model, plan, mesh, b, s, lowered=low)
            p_sds = _attach(params_sds, low.param_shardings())
            cache_dtype = jnp.int8 if plan.kv_cache_dtype == "int8" \
                else jnp.bfloat16
            spec = input_specs(cfg, shape, cache_dtype=cache_dtype)
            tok_sds = spec["tokens"]
            cache_sds = _attach(spec["caches"], step.batch_shardings)
            program = step.fn.lower(p_sds, tok_sds, cache_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = program.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    stats = analyze(hlo_text)
    chips = mesh.devices.size
    mf = model_flops_for(cfg, shape)
    rep = report_from_stats(stats, arch=arch, shape=shape_name,
                            mesh=mesh_name, chips=chips,
                            model_flops_global=mf, xla_cost=cost,
                            hbm_bytes_analytic=analytic_hbm_traffic(
                                cfg, shape, plan))

    # donated state aliases its outputs: alias_size must not double count
    dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "plan": json.loads(plan.to_json()),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "host_argument_bytes": mem.host_argument_size_in_bytes,
            "host_temp_bytes": mem.host_temp_size_in_bytes,
            "device_total_bytes": dev_bytes,
            "fits_16GiB": bool(dev_bytes < 16 * 2**30),
            **analytic_memory(low),
        },
        "xla_cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "hlo_stats": {
            "dot_flops": stats.dot_flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_wire_bytes": stats.collective_wire_bytes,
            "collective_by_kind": stats.collective_by_kind,
            "n_collectives": stats.n_collectives,
        },
        "roofline": json.loads(rep.to_json()),
    }
    if save_hlo:
        import gzip
        RESULTS.mkdir(parents=True, exist_ok=True)
        with gzip.open(RESULTS / f"{arch}_{shape_name}_{mesh_name}.hlo.gz",
                       "wt") as f:
            f.write(hlo_text)
    return rec


def run(archs, shapes, pods, save_json=True, plan_overrides=None,
        tag="", save_hlo=False, view=None) -> list:
    out = []
    for arch in archs:
        cfg = get_arch(arch)
        for shape_name in shapes:
            if shape_name not in cfg.shapes:
                out.append({"arch": arch, "shape": shape_name,
                            "skipped": True})
                print(f"SKIP  {arch:18s} {shape_name:12s} (not applicable)")
                continue
            for mp in pods:
                try:
                    rec = lower_cell(arch, shape_name, multi_pod=mp,
                                     plan_overrides=plan_overrides,
                                     save_hlo=save_hlo, view=view)
                    rec["ok"] = True
                    r = rec["roofline"]
                    m = rec["memory"]
                    print(f"OK    {arch:18s} {shape_name:12s} "
                          f"mesh={rec['mesh']:9s} "
                          f"compile={rec['compile_s']:6.1f}s "
                          f"dev={m['device_total_bytes']/2**30:6.2f}GiB "
                          f"fit={m['fits_16GiB']} "
                          f"bottleneck={r['bottleneck']:10s} "
                          f"roofline={r['roofline_fraction']:.3f}")
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "multi_pod": mp, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"FAIL  {arch:18s} {shape_name:12s} multi_pod={mp} "
                          f"{type(e).__name__}: {str(e)[:120]}")
                out.append(rec)
                if save_json:
                    RESULTS.mkdir(parents=True, exist_ok=True)
                    mesh_name = rec.get("mesh", f"mp{int(mp)}")
                    p = RESULTS / f"{arch}_{shape_name}_{mesh_name}{tag}.json"
                    p.write_text(json.dumps(rec, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["both", "on", "off"],
                    default="both")
    ap.add_argument("--plan-json", default=None,
                    help="JSON dict of StageConfig/plan overrides")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--view", default=None,
                    help="'DPxTP' mesh view of the same 256 chips for an "
                         "optimized plan (e.g. 32x8)")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"both": [False, True], "on": [True], "off": [False]}[
        args.multi_pod]
    overrides = json.loads(pathlib.Path(args.plan_json).read_text()) \
        if args.plan_json else None
    recs = run(archs, shapes, pods, plan_overrides=overrides, tag=args.tag,
               save_hlo=args.save_hlo, view=args.view)
    n_ok = sum(1 for r in recs if r.get("ok"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    n_fail = sum(1 for r in recs if r.get("ok") is False)
    print(f"\n== dry-run summary: ok={n_ok} skipped={n_skip} fail={n_fail} ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
