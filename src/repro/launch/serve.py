"""Serving launcher: batched prefill + decode against the KV/state cache.

    python -m repro.launch.serve --arch granite-3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16

``--tune`` replaces the hand-written dp-only baseline with the winner of
the ``serve`` search space (docs/serving.md): the tuner prices (dp, tp,
zero, kv dtype) candidates on the symbolic KV-cache/decode cost model,
and the launcher cross-checks that model against the lowered plan's
``memory_report()`` — the predicted serve bytes must match bitwise.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from repro import compat
import numpy as np


def generate(model, params, prompts: jnp.ndarray, gen: int, mesh, plan,
             lowered=None, max_len=None):
    """Greedy decode `gen` tokens for a batch of fixed-length prompts.

    ``max_len`` pins the cache horizon (default: plen + gen).  Pass the
    engine's global horizon when comparing against the continuous-
    batching path: XLA may associate attention reductions differently at
    different cache lengths, so token-identity holds only like-for-like.
    """
    from repro.lowering import lower_plan
    from repro.models.zoo import pad_caches, quantize_caches
    from repro.training.step import make_prefill_step, make_serve_step

    b, plen = prompts.shape
    if max_len is None:
        max_len = plen + gen
    if plen + gen > max_len:
        raise ValueError(f"prompt {plen} + gen {gen} exceeds max_len "
                         f"{max_len}")
    # one lowering shared by the prefill and decode programs: both read the
    # same mesh-axis mapping / spec tables / serve exec config
    low = lowered or lower_plan(model.cfg, None, plan, mesh)
    prefill = make_prefill_step(model, return_cache=True, lowered=low)
    logits, caches = prefill.fn(params, {"tokens": prompts})
    if plan.kv_cache_dtype == "int8":
        # prefill emits bf16 caches; decode reads the int8+scales layout
        caches = quantize_caches(caches)
    caches = pad_caches(caches, max_len - plen)
    serve = make_serve_step(model, batch=b, max_len=max_len, donate=False,
                            lowered=low)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(gen - 1):
        logits, caches = serve.fn(params, tok, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def tuned_serve_plan(cfg, *, batch: int, max_len: int, n_devices: int,
                     page_grid=None):
    """Run the ``serve`` search space and return (plan, report)."""
    from repro.core.tuner import MistTuner, TuneSpec
    spec = TuneSpec(arch=cfg, seq_len=max_len, global_batch=batch,
                    n_devices=n_devices, space="serve",
                    page_grid=page_grid)
    report = MistTuner(spec).tune()
    if report.plan is None:
        raise SystemExit("serve tuner: no feasible plan "
                         f"(swept {report.n_swept} candidates)")
    return report.plan, report


def run_continuous(model, params, prompts, gens, mesh, plan, *,
                   slots: int, page_size: int, max_len: int, lowered=None):
    """Serve one request per prompt row (per-request output budgets
    ``gens``) through the continuous-batching engine; returns
    ({rid: tokens}, engine)."""
    from repro.serving import ContinuousBatchingEngine
    eng = ContinuousBatchingEngine(model, params, plan, mesh, slots=slots,
                                   max_len=max_len, page_size=page_size,
                                   lowered=lowered)
    for i in range(prompts.shape[0]):
        eng.submit({"tokens": prompts[i:i + 1]}, gens[i], rid=i)
    return eng.run(), eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tune", action="store_true",
                    help="pick the plan via the 'serve' search space "
                         "instead of the dp-only baseline")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(paged KV cache, docs/continuous-batching.md) "
                         "instead of one static batch")
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots for --continuous")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page rows for --continuous (must divide "
                         "prompt-len + gen); --tune sweeps {0, this}")
    args = ap.parse_args()

    from repro.configs.base import get_arch
    from repro.core.plan import single_stage_plan
    from repro.launch.mesh import make_host_mesh
    from repro.models.zoo import build_model

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    n = len(jax.devices())
    max_len = args.prompt_len + args.gen
    if args.continuous and max_len % args.page_size:
        raise SystemExit(f"--page-size {args.page_size} must divide "
                         f"prompt-len + gen = {max_len}")
    if args.tune:
        plan, report = tuned_serve_plan(
            cfg, batch=args.batch, max_len=max_len, n_devices=n,
            page_grid=(0, args.page_size) if args.continuous else None)
        st = plan.stages[0]
        mesh = make_host_mesh(st.dp, st.tp)
        print(f"# tuned serve plan: dp={st.dp} tp={st.tp} zero={st.zero} "
              f"kv={plan.kv_cache_dtype} page_size={plan.page_size} "
              f"(objective {report.objective:.4f}s, "
              f"{report.throughput_tokens:.1f} tok/s predicted)")
        print(plan.to_json())
    else:
        plan = single_stage_plan(cfg.num_layers, dp=n, tp=1, micro_batch=1,
                                 grad_accum=1, zero=0, ckpt_layers=0)
        mesh = make_host_mesh(n, 1)
    from repro.configs.base import ShapeConfig
    from repro.lowering import lower_plan
    shape = ShapeConfig("serve", max_len, args.batch, "decode")
    low = lower_plan(cfg, shape, plan, mesh)
    rep = low.memory_report()
    print(f"# lowered serve memory: {rep.peak_bytes / 2**30:.2f} GiB "
          f"per device (weights+cache+transient)")
    if args.tune:
        # the two-evaluation contract, asserted at launch: the symbolic
        # model the tuner ranked candidates with must equal the lowered
        # report on the chosen plan, bitwise
        from repro.core.costmodel import estimate_serve_plan
        est = estimate_serve_plan(cfg, shape, plan)
        assert est["mem_decode"] == rep.peak_bytes, \
            (est["mem_decode"], rep.peak_bytes)
        print("# predicted serve memory == memory_report(): bitwise OK")
    with compat.set_mesh(mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size).astype(jnp.int32)
        if args.continuous:
            # mixed output budgets (full / half, alternating) so retire +
            # re-admit actually fires; tokens must equal the static path
            # prefix by greedy determinism
            gens = [args.gen if i % 2 == 0 else max(1, args.gen // 2)
                    for i in range(args.batch)]
            ps = plan.page_size or args.page_size
            t0 = time.time()
            res, eng = run_continuous(model, params, prompts, gens, mesh,
                                      plan, slots=args.slots, page_size=ps,
                                      max_len=max_len, lowered=low)
            dt = time.time() - t0
            ref = generate(model, params, prompts, args.gen, mesh, plan,
                           lowered=low, max_len=max_len)
            for i, g in enumerate(gens):
                assert np.array_equal(res[i], np.asarray(ref[i])[:g]), \
                    f"continuous tokens diverged from static (request {i})"
            if n == 1:
                from repro.lowering.cache_layout import \
                    concrete_paged_cache_bytes
                want = int(concrete_paged_cache_bytes(
                    cfg, args.slots, max_len, ps, plan.kv_cache_dtype,
                    dp_size=1, tp_size=1))
                assert eng.memory_bytes() == want, \
                    (eng.memory_bytes(), want)
                print("# paged cache bytes == derived layout: bitwise OK")
            total = sum(gens)
            print(f"continuous: {total} tokens / {args.batch} requests in "
                  f"{dt:.2f}s ({total / dt:.1f} tok/s, {eng.steps_run} "
                  f"decode steps, {args.slots} slots, page_size {ps}); "
                  f"tokens match the static path")
            return 0
        t0 = time.time()
        toks = generate(model, params, prompts, args.gen, mesh, plan,
                        lowered=low)
        dt = time.time() - t0
    total = args.batch * args.gen
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); first row: {np.asarray(toks[0])[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
