"""Pipeline-parallel train step: shard_map over a 'stage' mesh axis with
jax.lax.ppermute microbatch handoff (GPipe fill/drain realized by AD).

TPU-native adaptation of Mist's pipeline executor (paper §5.1): instead of
per-rank torch programs with p2p sends, the stage axis is a mesh dimension.
The *stacked-layer* parameter layout (every backbone block's params carry a
leading L dim) makes stage partitioning a *sharding decision*: dim 0 of every
block param is sharded over 'stage', so each stage holds L/S layers, and XLA
SPMD continues to handle DP/TP/ZeRO *inside* each stage (the shard_map is
partial-manual: only 'stage' is manual, 'data'/'model' stay auto).

Heterogeneity notes (DESIGN.md §Arch-applicability):
 - per-stage CKPT_i is realized by a stage-indexed remat split
   (`jnp.where` over lax.axis_index) — heterogeneous recompute counts run
   in one SPMD program;
 - dp/tp/ZeRO must be uniform across stages in one SPMD program (XLA
   constraint); Mist plans tuned for execution set `uniform_shards=True`,
   while analysis-only plans may be fully heterogeneous;
 - the embed/unembed compute runs on every stage and is masked (SPMD
   uniformity); the waste is head_flops*(S-1) and is counted by the
   roofline analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.core.plan import Plan
from repro.lowering import LoweredPlan, lower_plan
from repro.models import layers as L
from repro.models.common import Params, subtree, use_rules
from repro.models.zoo import Model
from repro.training import optimizer as OPT

PIPELINE_FAMILIES = ("dense", "moe", "ssm")   # uniform-stack decoders


def supports_pipeline(cfg: ArchConfig) -> bool:
    return cfg.family in PIPELINE_FAMILIES


# The per-param sharding tables (stacked-layer dim 0 -> 'stage', remaining
# dims via the single-stage TP/ZeRO rules) and the shard_map manual specs
# are produced by ``repro.lowering`` (`LoweredPlan.pipeline_*`); this
# module only realizes the stage programs.


# ---------------------------------------------------------------------------
# the pipelined loss
# ---------------------------------------------------------------------------


def _stage_block_fn(model: Model, cfg: ArchConfig, low: LoweredPlan):
    """(stage-local stacked params, x, stage_idx) -> x after L/S layers.

    Heterogeneous per-stage CKPT_i/AO_i are realized by `lax.switch` over
    the stage index: each branch is the same layer stack with a *different*
    remat/offload segmentation.  XLA lowers this to conditional HLO whose
    selected branch executes at runtime — each stage runs only its own
    segmentation, at the cost of S copies of the stage program in the HLO
    (compile-time, not run-time, overhead)."""
    from repro.models.decoder import apply_block
    from repro.models.common import segmented_layer_scan
    plan = low.plan

    def branch_fn(ls):
        n_local = ls.stage.layers
        ec = ls.exec_cfg   # the lowered CKPT_i/AO_i segmentation

        def run(stacked, x, aux0):
            def body(carry, lp):
                h, aux = carry
                nh, a, _ = apply_block(lp, h, cfg, ec)
                return (nh, aux + a)
            return segmented_layer_scan(body, (x, aux0), stacked, n_local,
                                        ec)
        return run

    # dedupe identical stage configs into shared branches
    keyed = [(min(s.ckpt_layers, s.layers), s.ao) for s in plan.stages]
    uniq = sorted(set(keyed))
    branch_of_stage = jnp.asarray([uniq.index(k) for k in keyed], jnp.int32)
    branches = [branch_fn(low.stages[keyed.index(k)]) for k in uniq]

    def block(stacked: Params, x: jax.Array, stage_idx: jax.Array,
              aux0: jax.Array):
        if len(branches) == 1:
            return branches[0](stacked, x, aux0)
        return jax.lax.switch(branch_of_stage[stage_idx], branches,
                              stacked, x, aux0)

    return block


def make_pipeline_loss(model: Model, plan: Plan, mesh: Mesh,
                       lowered: Optional[LoweredPlan] = None) -> Callable:
    """(params, batch) -> mean loss, running the GPipe loop inside a
    partial-manual shard_map over the 'stage' axis."""
    cfg = model.cfg
    assert supports_pipeline(cfg), f"pipeline unsupported for {cfg.family}"
    if not compat.supports_pipeline_stage_mapping():
        # fail fast with a clear error: on jax 0.4.x the bundled XLA SPMD
        # partitioner aborts the whole process (CHECK failure) on
        # partial-manual scan+ppermute, so don't even build the program.
        raise NotImplementedError(
            "pipeline stage mapping needs partial-manual shard_map "
            "(jax.shard_map); this jax is too old — single-stage SPMD and "
            "all tuning/analysis paths remain available")
    low = lowered or lower_plan(cfg, None, plan, mesh)
    S = plan.num_stages
    G = plan.grad_accum
    block = _stage_block_fn(model, cfg, low)
    rules = low.shard_rules()
    from repro.models.decoder import embed_tokens, unembed_matrix, chunked_xent

    ec = low.plan_exec_cfg   # stage-agnostic embed/unembed compute

    def pipelined(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        """Runs per-stage (manual over 'stage'; auto over data/model)."""
        # stage-replicated (non-stacked) params cross the shard_map boundary
        # in f32: their gradients are psum'ed over 'stage' by shard_map AD,
        # and the f32 reduction (a) is exact and (b) avoids an XLA:CPU
        # AllReducePromotion crash on bf16 scalars (TPU unaffected).
        params = {n: (p.astype(_orig_dtype[n])
                      if p.dtype != _orig_dtype[n] else p)
                  for n, p in params.items()}
        stage = jax.lax.axis_index("stage")
        stacked = subtree(params, "layers")
        tokens, labels = batch["tokens"], batch["labels"]   # (G, b, s)
        b, s = tokens.shape[1], tokens.shape[2]
        d = cfg.d_model

        def embed_mb(i):
            x = embed_tokens(params, tokens[i], cfg, ec)
            return x

        T = G + S - 1
        zero_x = jnp.zeros((b, s, d), ec.compute_dtype)

        def step(carry, t):
            x_in, loss_sum, aux_sum = carry
            # stage 0 ingests microbatch t (if in range)
            mb = jnp.clip(t, 0, G - 1)
            fresh = embed_mb(mb)
            x = jnp.where(stage == 0, fresh, x_in)
            active = (t - stage >= 0) & (t - stage < G)
            x, aux = block(stacked, x, stage, jnp.zeros((), jnp.float32))
            # last stage: loss of microbatch (t - S + 1)
            out_mb = jnp.clip(t - S + 1, 0, G - 1)
            h = L.norm(subtree(params, "final_norm"), x, cfg)
            lo = chunked_xent(h, unembed_matrix(params, cfg),
                              labels[out_mb])
            is_out = (stage == S - 1) & (t >= S - 1)
            loss_sum = loss_sum + jnp.where(is_out, lo, 0.0)
            aux_sum = aux_sum + jnp.where(active, aux, 0.0)
            # hand off to next stage
            x = jnp.where(active, x, jnp.zeros_like(x))
            x_next = jax.lax.ppermute(
                x, "stage", [(i, (i + 1) % S) for i in range(S)])
            return (x_next, loss_sum, aux_sum), None

        (x_last, loss_sum, aux_sum), _ = jax.lax.scan(
            step, (zero_x, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), jnp.arange(T))
        # mean over microbatches; broadcast the last stage's loss to all
        loss = jax.lax.psum(loss_sum, "stage") / G
        from repro.models.decoder import AUX_COEF
        aux = jax.lax.psum(aux_sum, "stage") / jnp.maximum(G, 1)
        return loss + AUX_COEF * aux / cfg.num_layers

    params_sds, axes_table = low.params_sds, low.axes_table
    _orig_dtype = {n: sds.dtype for n, sds in params_sds.items()}
    _is_stacked = {n: bool(axes_table[n]) and axes_table[n][0] == "layers"
                   for n in params_sds}
    pspecs = low.pipeline_param_shardings()
    # partial-manual shard_map: specs mention ONLY the manual 'stage' axis;
    # DP/TP/ZeRO shardings over the auto axes ride through unchanged (set by
    # the outer jit in_shardings + with_sharding_constraint inside).
    manual_spec = dict(low.pipeline_manual_specs)
    in_specs = (manual_spec, {"tokens": P(), "labels": P()})
    manual = frozenset({"stage"})

    # check_vma=False: inner scans (chunked xent, layer scan) carry
    # stage-varying values from unvarying seeds; the loss output is made
    # replicated explicitly via the psum over 'stage'.
    smapped = compat.shard_map(pipelined, mesh=mesh, in_specs=in_specs,
                               out_specs=P(), axis_names=manual,
                               check_vma=False)

    def loss_fn(params, batch):
        with use_rules(rules):
            p32 = {n: (p.astype(jnp.float32) if not _is_stacked[n] else p)
                   for n, p in params.items()}
            return smapped(p32, batch)

    loss_fn.param_shardings = pspecs  # type: ignore[attr-defined]
    return loss_fn


# ---------------------------------------------------------------------------
# pipeline train step (loss + grads + AdamW), mirroring step.make_train_step
# ---------------------------------------------------------------------------


@dataclass
class PipelineStep:
    fn: Callable
    state_shardings: Any
    batch_shape: Tuple[int, ...]      # (G, b*dp, s) expected for tokens
    loss_fn: Callable


def make_pipeline_train_step(model: Model, plan: Plan, mesh: Mesh,
                             adam: OPT.AdamConfig = OPT.AdamConfig(),
                             donate: bool = True,
                             lowered: Optional[LoweredPlan] = None
                             ) -> PipelineStep:
    cfg = model.cfg
    S = plan.num_stages
    assert S > 1 and "stage" in mesh.axis_names
    st0 = plan.stages[0]
    low = lowered or lower_plan(cfg, None, plan, mesh)
    loss_fn = make_pipeline_loss(model, plan, mesh, lowered=low)

    # optimizer state mirrors the param shardings (master/mu/nu f32),
    # WO/OO splits included
    st_shardings = low.pipeline_state_shardings()

    def train_step(state, batch):
        params = state["params"]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch))(params)
        grads = {n: g.astype(jnp.float32) for n, g in grads.items()}
        new_state, om = OPT.adam_update(state, grads, adam, st_shardings)
        return new_state, {"loss": loss, **om, "step": new_state["step"]}

    jit_fn = jax.jit(train_step, in_shardings=(st_shardings, None),
                     donate_argnums=(0,) if donate else ())
    b_local = st0.micro_batch * st0.dp
    return PipelineStep(fn=jit_fn, state_shardings=st_shardings,
                        batch_shape=(plan.grad_accum, b_local, 0),
                        loss_fn=loss_fn)
