"""Sharding rules: logical param/activation axes -> physical mesh axes.

All parallelism in the runtime is *data, not code*: a Plan maps to
NamedShardings for params / optimizer states / gradients / caches, XLA's SPMD
partitioner inserts the collectives (TP all-reduce pairs, ZeRO all-gather /
reduce-scatter, sequence-parallel resharding).

This module is a *pure spec library*: it knows how to map one tensor's
logical axes to a PartitionSpec, but never interprets a Plan.  The only
runtime caller is ``repro.lowering`` (`lower_plan`), which assembles the
per-stage spec tables every entry point consumes; see
docs/plan-lowering.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ShardRules
# The logical-axis constants and physical-dim choosers are owned by the
# shared state-layout module (`repro.lowering.state_layout`), which both
# this spec library and the symbolic cost model evaluate — one
# implementation decides the runtime's PartitionSpecs AND the tuner's
# shard counts, so they cannot drift.  Re-exported here for callers.
from repro.lowering.state_layout import (LAYER_AXES,  # noqa: F401
                                         TP_PRIORITY, choose_fsdp_dim,
                                         choose_tp_dim)


@dataclass(frozen=True)
class MeshAxes:
    """Physical axis names of the active mesh."""
    dp: Tuple[str, ...] = ("data",)      # data parallelism (+ "pod" outer)
    tp: Optional[str] = "model"          # tensor parallelism
    fsdp: Tuple[str, ...] = ("data",)    # ZeRO sharding axis (== dp here)

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        dp = tuple(n for n in names if n in ("pod", "data", "replica"))
        tp = "model" if "model" in names else None
        return MeshAxes(dp=dp or (names[0],), tp=tp, fsdp=dp or (names[0],))


def axis_size(mesh: Mesh, axes) -> int:
    """Total device count of a MeshAxes role (None -> 1, tuples multiply)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_spec(name: str, shape: Sequence[int], axes: Sequence[Optional[str]],
               mesh: Mesh, ma: MeshAxes, *, zero3: bool, ep_ok: bool) -> P:
    tp_size = axis_size(mesh, ma.tp)
    spec: list = [None] * len(shape)
    ti = choose_tp_dim(axes, shape, tp_size, ep_ok)
    if ti is not None:
        spec[ti] = ma.tp
    if zero3:
        fi = choose_fsdp_dim(axes, shape, axis_size(mesh, ma.fsdp), ti)
        if fi is not None:
            spec[fi] = ma.fsdp if len(ma.fsdp) > 1 else ma.fsdp[0]
    return P(*spec)


def opt_spec(name: str, shape, axes, mesh: Mesh, ma: MeshAxes, *,
             zero: int, ep_ok: bool) -> P:
    """Optimizer-state / master-weight sharding (ZeRO>=1 shards over fsdp)."""
    return param_spec(name, shape, axes, mesh, ma, zero3=zero >= 1,
                      ep_ok=ep_ok)


def grad_spec(name: str, shape, axes, mesh: Mesh, ma: MeshAxes, *,
              zero: int, ep_ok: bool) -> P:
    """Gradient sharding: ZeRO>=2 reduce-scatters grads over fsdp."""
    return param_spec(name, shape, axes, mesh, ma, zero3=zero >= 2,
                      ep_ok=ep_ok)


def make_shard_rules(mesh: Mesh, ma: MeshAxes, sequence_parallel: bool
                     ) -> ShardRules:
    tp_size = axis_size(mesh, ma.tp)
    mapping: Dict[str, Any] = {
        "dp": ma.dp if len(ma.dp) > 1 else ma.dp[0],
        "tp": ma.tp,
        "sp": ma.tp if (sequence_parallel and tp_size > 1) else None,
        "expert": ma.tp,
    }
    return ShardRules(mapping=mapping, mesh=mesh)


# ---------------------------------------------------------------------------
# Cache (serving) shardings
# ---------------------------------------------------------------------------

_SEQ_LEAF_SEQ_DIM = {"k": 1, "v": 1, "latent": 1, "k_rope": 1,
                     "k_scale": 1, "v_scale": 1}


def cache_specs(caches, mesh: Mesh, ma: MeshAxes, batch: int,
                lead_dims: int = 1) -> Any:
    """Shardings for a stacked cache pytree.

    The batch dim is located by value (stacked lead dims vary per family).
    batch divisible by dp -> shard batch; else shard the KV sequence dim over
    dp (flash-decoding-style sequence-parallel KV for long_500k).
    Head/state dims shard over tp when divisible.
    """
    dp_size = axis_size(mesh, ma.dp)
    tp_size = axis_size(mesh, ma.tp)
    dp_name = ma.dp if len(ma.dp) > 1 else ma.dp[0]
    shard_batch = batch % dp_size == 0 and dp_size > 1

    def leaf_spec(path, sds):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(sds.shape)
        spec: list = [None] * nd
        # locate the batch dim by value (first exact match)
        bdim = next((i for i, d in enumerate(sds.shape) if d == batch), None)
        if bdim is None:
            return P(*spec)
        if shard_batch:
            spec[bdim] = dp_name
        elif key in _SEQ_LEAF_SEQ_DIM and nd > bdim + 1:
            spec[bdim + 1] = dp_name   # sequence-parallel KV
        # tp on the canonical head/state dim
        if tp_size > 1:
            if key in ("k", "v") and nd >= bdim + 3 \
                    and sds.shape[nd - 2] % tp_size == 0:
                spec[nd - 2] = ma.tp       # (…,B,S,KV,hd) -> KV heads
            elif key in ("k", "v") and nd > bdim + 1 \
                    and spec[bdim + 1] is None \
                    and sds.shape[bdim + 1] % tp_size == 0:
                # GQA/MHA head count not divisible by tp: shard the KV
                # SEQUENCE over 'model' instead (flash-decoding style) —
                # the dominant store at decode_32k/long_500k scale
                spec[bdim + 1] = ma.tp
            elif key in ("ssm", "c", "n", "m") and nd > bdim + 1 \
                    and sds.shape[bdim + 1] % tp_size == 0:
                spec[bdim + 1] = ma.tp     # state heads
            elif key == "conv" and sds.shape[nd - 1] % tp_size == 0:
                spec[nd - 1] = ma.tp       # conv channels
            elif key in ("latent", "k_rope") and spec[bdim + 1] is None \
                    and nd > bdim + 1 \
                    and sds.shape[bdim + 1] % tp_size == 0:
                spec[bdim + 1] = ma.tp     # MLA latent: sequence over tp
            elif key in ("k_scale", "v_scale"):
                # mirror the k/v decision: kv-head dim (last) if divisible,
                # else the sequence dim
                if sds.shape[nd - 1] % tp_size == 0:
                    spec[nd - 1] = ma.tp
                elif spec[bdim + 1] is None and nd > bdim + 1 \
                        and sds.shape[bdim + 1] % tp_size == 0:
                    spec[bdim + 1] = ma.tp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda p, s: NamedSharding(mesh, leaf_spec(p, s)), caches)


def cache_update_mode(cache_sh, ma: MeshAxes) -> str:
    """'onehot' when any KV/latent cache leaf has its sequence dim sharded
    over the model axis (a DUS there would be replicated by GSPMD)."""
    def seq_sharded(path, sh):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key not in _SEQ_LEAF_SEQ_DIM or not hasattr(sh, "spec"):
            return False
        return any(ax == ma.tp for ax in sh.spec if ax is not None)

    leaves = jax.tree_util.tree_leaves_with_path(
        cache_sh, is_leaf=lambda x: hasattr(x, "spec"))
    return "onehot" if any(seq_sharded(p, s) for p, s in leaves) else "dus"


def batch_specs(batch, mesh: Mesh, ma: MeshAxes) -> Any:
    """Input batch: leading (global) batch dim over dp."""
    dp_name = ma.dp if len(ma.dp) > 1 else ma.dp[0]

    def leaf(sds):
        spec = [None] * len(sds.shape)
        if len(sds.shape) >= 1:
            spec[0] = dp_name
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch)
