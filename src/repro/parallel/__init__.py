from repro.parallel.sharding import (  # noqa: F401
    MeshAxes, batch_specs, cache_specs, make_shard_rules, param_spec,
)
