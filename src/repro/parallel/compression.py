"""Gradient compression: int8 quantize/dequantize with per-tensor scale and
(optional) error-feedback residual — a distributed-optimization companion for
ZeRO-2 reduce-scatter at DCI-bound multi-pod scale.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_compress(grads: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Quantize-dequantize pass (simulates on-the-wire int8 gradients).
    XLA places the quantize before and dequantize after the cross-replica
    reduction when grads are produced sharded, cutting DCI bytes 4x."""
    out = {}
    for n, g in grads.items():
        q, s = quantize_int8(g)
        out[n] = dequantize_int8(q, s).astype(g.dtype)
    return out


def compress_with_feedback(grads: Dict[str, jax.Array],
                           residual: Dict[str, jax.Array]
                           ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Error-feedback int8 compression: residual carries quantization error
    into the next step (Karimireddy et al.-style EF-SGD)."""
    new_g, new_r = {}, {}
    for n, g in grads.items():
        corrected = g + residual[n]
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        new_g[n] = deq.astype(g.dtype)
        new_r[n] = (corrected - deq).astype(g.dtype)
    return new_g, new_r
