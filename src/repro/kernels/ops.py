"""Jitted kernel wrappers with implementation dispatch.

``attention(q, k, v, impl=...)``:
  - "naive":   full S^2 softmax (ref.py) — the no-FlashAttention baseline.
  - "blocked": flash-style online softmax over KV blocks in pure jnp with a
               custom-VJP blocked backward (O(block) intermediates) — the
               lowering-compatible stand-in for the Pallas kernel (used by
               the dry-run on the CPU host platform).
  - "pallas":  the Pallas TPU kernel forward (interpret=True off-TPU) with
               the same blocked backward.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

DEFAULT_BLOCK = 512


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# blocked flash attention (jnp, custom VJP)
# ---------------------------------------------------------------------------


def _blocked_fwd(q, k, v, causal: bool, scale: float, block: int):
    """q/k/v (BH,S,D) -> out, lse.  Scan over KV blocks, online softmax."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    nk = sk // block
    kb = k.reshape(bh, nk, block, d).transpose(1, 0, 2, 3)
    vb = v.reshape(bh, nk, block, d).transpose(1, 0, 2, 3)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        acc, m, l = carry
        kcur, vcur, ki = inp
        s = jnp.einsum("bqd,bkd->bqk", q, kcur,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = ki * block + jnp.arange(block)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, ref.NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqk,bkd->bqd", p.astype(v.dtype), vcur).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((bh, sq, d), jnp.float32)
    m0 = jnp.full((bh, sq), ref.NEG_INF, jnp.float32)
    l0 = jnp.zeros((bh, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nk)))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


def _blocked_bwd(q, k, v, out, lse, dout, causal: bool, scale: float,
                 block: int):
    bh, sq, d = q.shape
    sk = k.shape[1]
    nk = sk // block
    kb = k.reshape(bh, nk, block, d).transpose(1, 0, 2, 3)
    vb = v.reshape(bh, nk, block, d).transpose(1, 0, 2, 3)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    qpos = jnp.arange(sq)

    def body(dq, inp):
        kcur, vcur, ki = inp
        s = jnp.einsum("bqd,bkd->bqk", q, kcur,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = ki * block + jnp.arange(block)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, ref.NEG_INF)
        p = jnp.exp(s - lse[..., None])                      # (BH,Sq,blk)
        dv = jnp.einsum("bqk,bqd->bkd", p.astype(dout.dtype), dout)
        dp = jnp.einsum("bqd,bkd->bqk", dout, vcur,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqk,bkd->bqd", ds.astype(q.dtype), kcur
                             ).astype(jnp.float32)
        dk = jnp.einsum("bqk,bqd->bkd", ds.astype(q.dtype), q)
        return dq, (dk, dv)

    dq0 = jnp.zeros((bh, sq, d), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nk)))
    dk = dkb.transpose(1, 0, 2, 3).reshape(bh, sk, d)
    dv = dvb.transpose(1, 0, 2, 3).reshape(bh, sk, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal: bool, scale: float, q_block: int, kv_block: int,
           use_pallas: bool):
    if use_pallas:
        return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                                   q_block=min(q_block, q.shape[1]),
                                   kv_block=min(kv_block, k.shape[1]),
                                   interpret=not _on_tpu())
    out, _ = _blocked_fwd(q, k, v, causal, scale,
                          min(kv_block, k.shape[1]))
    return out


def _flash_fwd_rule(q, k, v, causal, scale, q_block, kv_block, use_pallas):
    if use_pallas:
        out = flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                                  q_block=min(q_block, q.shape[1]),
                                  kv_block=min(kv_block, k.shape[1]),
                                  interpret=not _on_tpu())
        # lse recomputed cheaply for the bwd (flash-style recompute)
        _, lse = _blocked_fwd(q, k, v, causal, scale,
                              min(kv_block, k.shape[1]))
    else:
        out, lse = _blocked_fwd(q, k, v, causal, scale,
                                min(kv_block, k.shape[1]))
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, scale, q_block, kv_block, use_pallas, res, dout):
    q, k, v, out, lse = res
    dq, dk, dv = _blocked_bwd(q, k, v, out, lse, dout, causal, scale,
                              min(kv_block, k.shape[1]))
    return dq, dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _fit_block(blk: int, size: int) -> int:
    blk = min(blk, size)
    while size % blk:
        blk //= 2
    return blk


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, impl: str = "blocked",
              block: int = DEFAULT_BLOCK,
              q_block: Optional[int] = None,
              kv_block: Optional[int] = None,
              scale: Optional[float] = None) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,KV,hd) with H = KV*G (GQA) -> (B,Sq,H,hd).

    ``q_block``/``kv_block`` set the flash tiles independently (the tuned
    kernel-config dimension); ``block`` is the legacy shared default for
    callers that don't distinguish them.  Blocks that don't divide the
    sequence are halved until they do (legality is best-effort here; the
    tuner only emits divisible configs)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # expand KV over the group dim (vjp of broadcast sums dk/dv correctly)
    kx = jnp.broadcast_to(k[:, :, :, None, :], (b, k.shape[1], kv, g, hd))
    vx = jnp.broadcast_to(v[:, :, :, None, :], (b, v.shape[1], kv, g, hd))
    qf = q.reshape(b, sq, kv, g, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * h, sq, hd)
    kf = kx.transpose(0, 2, 3, 1, 4).reshape(b * h, k.shape[1], hd)
    vf = vx.transpose(0, 2, 3, 1, 4).reshape(b * h, v.shape[1], hd)
    if impl == "naive":
        of = ref.naive_attention(qf, kf, vf, causal=causal, scale=scale)
    else:
        qblk = _fit_block(q_block if q_block is not None else block, sq)
        kblk = _fit_block(kv_block if kv_block is not None else block,
                          kf.shape[1])
        of = _flash(qf, kf, vf, causal, scale, qblk, kblk, impl == "pallas")
    return of.reshape(b, kv, g, sq, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# rmsnorm / ssd
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, *, impl: str = "pallas",
            eps: float = 1e-6, block: int = 256) -> jax.Array:
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps=eps, row_block=block,
                              interpret=not _on_tpu())
    return ref.rmsnorm_ref(x, scale, eps)


def ssd_scan(xh, dt, a, bb, cc, *, chunk: int = 256
             ) -> Tuple[jax.Array, None]:
    """Pallas SSD chunk scan; returns (y, None) — final state is produced by
    the reference path when a serving handoff needs it."""
    y = ssd_scan_pallas(xh, dt, a, bb, cc, chunk=chunk,
                        interpret=not _on_tpu())
    return y, None
