"""Pallas TPU Mamba2 SSD chunk scan.

Grid: (batch, heads, num_chunks) with the chunk dim innermost (sequential on
TPU); the running state h (P x N, f32) lives in VMEM scratch and carries
across chunk iterations.  Per chunk the kernel computes the intra-chunk
(diagonal-block) contribution, the inter-chunk contribution from the carried
state, and the state update — one fused pass instead of the multi-einsum
reference (ref.py / models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hstate_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        hstate_ref[...] = jnp.zeros_like(hstate_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (Q,)
    a = a_ref[0]                               # scalar
    bb = b_ref[0, 0, 0].astype(jnp.float32)    # (Q, N)
    cc = c_ref[0, 0, 0].astype(jnp.float32)    # (Q, N)

    da = dt * a                                # (Q,)
    cum = jnp.cumsum(da)                       # (Q,)
    # intra-chunk
    rel = cum[:, None] - cum[None, :]          # (Qt, Qs)
    q = x.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask BEFORE exp: above the diagonal rel > 0 can overflow (and the
    # where-after-exp pattern NaNs the backward via inf*0)
    lmat = jnp.exp(jnp.where(tri, rel, -jnp.inf))
    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * lmat * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk from carried state: y += (C * exp(cum)) @ h
    h = hstate_ref[...]                        # (N, P)
    cdec = cc * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(cdec, h, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update: h' = exp(sum da) * h + sum_s exp(cum_Q - cum_s) dt_s B_s x_s^T
    w = jnp.exp(cum[-1] - cum) * dt            # (Q,)
    new_state = jax.lax.dot_general(bb * w[:, None], x,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    hstate_ref[...] = h * jnp.exp(cum[-1]) + new_state
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(xh: jax.Array, dt: jax.Array, a: jax.Array,
                    bb: jax.Array, cc: jax.Array, *, chunk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """xh (B,S,H,P); dt (B,S,H) f32; a (H,) f32; bb/cc (B,S,H,N).

    Returns y (B,S,H,P).  (Final state is recomputed by the reference path
    when needed for serving handoff.)
    """
    bsz, s, h, p = xh.shape
    n = bb.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    # layout: (B, H, C, Q, .)
    xt = xh.transpose(0, 2, 1, 3).reshape(bsz, h, nc, q, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz, h, nc, q).astype(jnp.float32)
    bt = bb.transpose(0, 2, 1, 3).reshape(bsz, h, nc, q, n)
    ct = cc.transpose(0, 2, 1, 3).reshape(bsz, h, nc, q, n)

    grid = (bsz, h, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b, hh, c: (b, hh, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, 1, 1, q, n), lambda b, hh, c: (b, hh, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda b, hh, c: (b, hh, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p),
                               lambda b, hh, c: (b, hh, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, q, p), xh.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a.astype(jnp.float32), bt, ct)
    return out.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
