"""Kernel-config autotuning: legality, bench-and-cache, calibration.

This is the concrete half of the kernel-config plan dimension
(docs/kernel-tuning.md).  The symbolic half lives in the cost model:
``core/costmodel.py`` compiles the shared roofline formulas
(``core/costmodel_params.kernel_time_terms`` / ``kernel_vmem_terms``)
over the ``qb``/``kvb``/``rnb``/``sch`` knob symbols so the candidate
grid prices tile choices by tape.  This module:

* **enumerates the legal grid** (`legal_kernel_grid`): power-of-two
  tiles from a fixed menu, sequence-length divisibility, MXU-alignment
  by construction, per-op VMEM working set within the budget (floored
  at the default config's own working set, exactly like the cost
  model's feasibility mask), ranked by the concrete roofline and capped
  so the joint kernel dimension stays a small multiplier on the
  candidate grid;
* **benches real kernels** (`bench_config`): instantiates the Pallas
  kernels (``interpret=True`` off-TPU) at the requested tiles and times
  them, memoized in a JSON cache keyed by (op, shape, tiles, backend);
* **verifies selections** (`verify_config`): every tuner-selected
  config must compile and produce finite output through the actual
  ``pallas_call`` — the acceptance gate for a tuned plan;
* **calibrates the roofline** (`calibrate`): anchors the per-kernel
  ``*_scale`` coefficients so predicted(default) == measured(default).
  Because the cost model prices kernels as a *delta* against the
  default config, calibration reshapes the sweep without moving any
  frozen-default plan (golden fixtures are invariant to it).

Everything except the bench/verify functions is pure python + math —
importable from the numpy-only sweep workers without touching jax.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import TYPE_CHECKING, Dict, Optional, Tuple

from repro.core.costmodel_params import (KERNEL_CONCRETE_OPS, KernelCoeffs,
                                         kernel_time_terms, kernel_vmem_terms,
                                         ssd_dims)
from repro.core.hardware import V5E, HardwareSpec
from repro.core.plan import DEFAULT_KERNEL_CONFIG, KernelConfig

if TYPE_CHECKING:
    from repro.configs.base import ArchConfig

# tile menus: powers of two, >= the MXU lane width for the matmul tiles
# (validate_plan additionally enforces power-of-two >= 8 on any plan)
ATTN_BLOCKS: Tuple[int, ...] = (128, 256, 512, 1024)
RMS_BLOCKS: Tuple[int, ...] = (128, 256, 512)
SSD_CHUNKS: Tuple[int, ...] = (64, 128, 256, 512)

KernelTuple = Tuple[int, int, int, int]   # (qb, kvb, rnb, sch)


# ---------------------------------------------------------------------------
# concrete roofline prediction (shared formulas, float ops)
# ---------------------------------------------------------------------------


def predict_times(cfg: "ArchConfig", *, seq_len: int,
                  config: KernelConfig = DEFAULT_KERNEL_CONFIG,
                  b: float = 1.0, tp: float = 1.0, sp_div: float = 1.0,
                  hw: HardwareSpec = V5E,
                  kc: Optional[KernelCoeffs] = None) -> Dict[str, float]:
    """Per-layer per-microbatch kernel seconds by op, evaluated with the
    SAME formulas (same arithmetic order) the cost model tapes — over
    floats instead of ``Expr``s, so symbolic and concrete evaluation
    agree bitwise at equal bindings (tests/test_kernel_tuning.py)."""
    kc = kc if kc is not None else KernelCoeffs()
    sd_h, sd_p, sd_n = ssd_dims(cfg)
    qb, kvb, rnb, sch = (float(v) for v in config.astuple())
    terms = kernel_time_terms(
        seq=seq_len, b=float(b), tp=float(tp), sp_div=float(sp_div),
        qb=qb, kvb=kvb, rnb=rnb, sch=sch,
        num_heads=cfg.num_heads, head_dim=cfg.head_dim, d_model=cfg.d_model,
        ssd_heads=sd_h, ssd_head_dim=sd_p, ssd_state=sd_n,
        hbm_bw=hw.hbm_bw, peak_flops=hw.peak_flops_bf16, kc=kc,
        ops=KERNEL_CONCRETE_OPS)
    attn_frac = _attn_frac(cfg)
    total = terms["rms"]
    if attn_frac:
        total = total + attn_frac * terms["attn"]
    if sd_h:
        total = total + terms["ssd"]
    return {"attn": terms["attn"], "rms": terms["rms"], "ssd": terms["ssd"],
            "total": total}


def _attn_frac(cfg: "ArchConfig") -> float:
    # mirrors core/costmodel.arch_stats gating without importing it (that
    # module pulls in the model zoo; workers want this import-light)
    if cfg.family == "hybrid":
        return 1.0 / cfg.shared_attn_every if cfg.shared_attn_every else 0.0
    if cfg.family == "ssm":
        return 0.0
    return 1.0


def predict_vmem(cfg: "ArchConfig",
                 config: KernelConfig = DEFAULT_KERNEL_CONFIG
                 ) -> Dict[str, float]:
    """Per-op VMEM working set (bytes) — the concrete twin of the cost
    model's ``vmem_peak`` tape output."""
    sd_h, sd_p, sd_n = ssd_dims(cfg)
    qb, kvb, rnb, sch = (float(v) for v in config.astuple())
    return kernel_vmem_terms(qb=qb, kvb=kvb, rnb=rnb, sch=sch,
                             head_dim=cfg.head_dim, d_model=cfg.d_model,
                             ssd_head_dim=sd_p, ssd_state=sd_n,
                             ops=KERNEL_CONCRETE_OPS)


# ---------------------------------------------------------------------------
# legal grid enumeration
# ---------------------------------------------------------------------------


def legal_kernel_grid(cfg: "ArchConfig", *, seq_len: int,
                      hw: HardwareSpec = V5E, cp=None,
                      max_tuples: int = 8) -> Tuple[KernelTuple, ...]:
    """The (qb, kvb, rnb, sch) tuples the tuner sweeps jointly with every
    candidate.  Legality: menu tiles (powers of two, MXU-friendly),
    sequence divisibility per op, per-op VMEM working set within
    ``max(hw.vmem_bytes, vmem(default))`` — the same floored budget the
    cost model's feasibility mask uses, so the default tuple is always
    legal.  The joint product is ranked by the concrete roofline (the
    identical formula the tapes compile) and capped at ``max_tuples``
    with the default tuple always first, keeping the kernel dimension a
    small constant factor on the candidate grid.  Deterministic — sweep
    workers recompute it from the pickled spec and must agree."""
    kc = cp.kernels if cp is not None else KernelCoeffs()
    d = DEFAULT_KERNEL_CONFIG
    sd_h, _sd_p, _sd_n = ssd_dims(cfg)
    attn_frac = _attn_frac(cfg)

    vdef = predict_vmem(cfg, d)
    budget = {op: max(float(hw.vmem_bytes), v) for op, v in vdef.items()}

    def _ok_attn(qb: int, kvb: int) -> bool:
        if seq_len % qb or seq_len % kvb:
            return False
        v = predict_vmem(cfg, d.replace(attn_q_block=qb, attn_kv_block=kvb))
        return v["attn"] <= budget["attn"]

    def _ok_rms(rnb: int) -> bool:
        if seq_len % rnb:
            return False
        return predict_vmem(cfg, d.replace(rmsnorm_block=rnb))["rms"] \
            <= budget["rms"]

    def _ok_ssd(sch: int) -> bool:
        if seq_len % sch:
            return False
        return predict_vmem(cfg, d.replace(ssd_chunk=sch))["ssd"] \
            <= budget["ssd"]

    attn_pairs = ([(qb, kvb) for qb in ATTN_BLOCKS for kvb in ATTN_BLOCKS
                   if _ok_attn(qb, kvb)] if attn_frac
                  else [(d.attn_q_block, d.attn_kv_block)])
    rms_blocks = [rb for rb in RMS_BLOCKS if _ok_rms(rb)] \
        or [d.rmsnorm_block]
    ssd_chunks = ([sc for sc in SSD_CHUNKS if _ok_ssd(sc)] if sd_h
                  else [d.ssd_chunk])
    if not attn_pairs:
        attn_pairs = [(d.attn_q_block, d.attn_kv_block)]
    if sd_h and not ssd_chunks:
        ssd_chunks = [d.ssd_chunk]

    scored = []
    for qb, kvb in attn_pairs:
        for rnb in rms_blocks:
            for sch in ssd_chunks:
                t = predict_times(cfg, seq_len=seq_len, hw=hw, kc=kc,
                                  config=KernelConfig(qb, kvb, rnb, sch)
                                  )["total"]
                scored.append((t, (qb, kvb, rnb, sch)))
    scored.sort(key=lambda e: (e[0], e[1]))

    default = d.astuple()
    grid: list = [default]
    for _t, tup in scored:
        if tup != default and len(grid) < max(1, int(max_tuples)):
            grid.append(tup)
    return tuple(grid)


# ---------------------------------------------------------------------------
# bench-and-cache (real Pallas kernels, interpret=True off-TPU)
# ---------------------------------------------------------------------------

_DEF_CACHE = "~/.cache/repro/kernel_bench.json"


def _cache_path(path=None) -> Path:
    p = path or os.environ.get("REPRO_KERNEL_BENCH_CACHE", _DEF_CACHE)
    return Path(p).expanduser()


def _load_cache(path: Path) -> Dict[str, float]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store_cache(path: Path, cache: Dict[str, float]) -> None:
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                      # cache is an accelerator, never a gate


def _time_fn(fn, *args, reps: int = 3) -> float:
    """Median wall time of a jitted call, post-warmup."""
    import time as _time

    import jax
    fn_j = jax.jit(fn)
    jax.block_until_ready(fn_j(*args))          # compile + warm
    ts = []
    for _ in range(max(1, reps)):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn_j(*args))
        ts.append(_time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _bench_shapes(cfg: "ArchConfig", seq_len: int):
    """Small-but-representative bench shapes: a few heads/rows is enough
    to rank tiles (measurements calibrate per-op *scales*, not absolute
    device throughput; interpret-mode timings scale with grid steps)."""
    seq = min(int(seq_len), 2048)
    heads = min(max(1, cfg.num_heads), 4)
    return seq, heads


def bench_config(cfg: "ArchConfig", *, seq_len: int,
                 config: KernelConfig = DEFAULT_KERNEL_CONFIG,
                 reps: int = 3, cache_path=None,
                 refresh: bool = False) -> Dict[str, float]:
    """Measured seconds per op for one kernel config, through the real
    kernels (``interpret=True`` off-TPU), memoized in a JSON cache."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas
    from repro.kernels.ssd_scan import ssd_scan_pallas

    backend = jax.default_backend()
    interp = backend != "tpu"
    seq, heads = _bench_shapes(cfg, seq_len)
    sd_h, sd_p, sd_n = ssd_dims(cfg)
    attn_frac = _attn_frac(cfg)

    path = _cache_path(cache_path)
    cache = {} if refresh else _load_cache(path)
    out: Dict[str, float] = {}
    dirty = False

    def measure(key: str, thunk) -> float:
        nonlocal dirty
        if not refresh and key in cache:
            return float(cache[key])
        val = thunk()
        cache[key] = val
        dirty = True
        return val

    rng = jax.random.PRNGKey(0)

    if attn_frac:
        qb = min(config.attn_q_block, seq)
        kvb = min(config.attn_kv_block, seq)
        hd = max(cfg.head_dim, 1)
        key = f"attn:{backend}:bh{heads}:s{seq}:d{hd}:q{qb}:k{kvb}"
        kq, kk, kv_ = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (heads, seq, hd), jnp.bfloat16)
        k = jax.random.normal(kk, (heads, seq, hd), jnp.bfloat16)
        v = jax.random.normal(kv_, (heads, seq, hd), jnp.bfloat16)
        out["attn"] = measure(key, lambda: _time_fn(
            lambda a, b_, c: flash_attention_fwd(
                a, b_, c, causal=True, q_block=qb, kv_block=kvb,
                interpret=interp),
            q, k, v, reps=reps))

    rnb = min(config.rmsnorm_block, seq)
    key = f"rms:{backend}:r{seq}:d{cfg.d_model}:b{rnb}"
    x = jax.random.normal(rng, (seq, cfg.d_model), jnp.bfloat16)
    scale = jnp.ones((cfg.d_model,), jnp.bfloat16)
    out["rms"] = measure(key, lambda: _time_fn(
        lambda a, s: rmsnorm_pallas(a, s, row_block=rnb, interpret=interp),
        x, scale, reps=reps))

    if sd_h:
        sch = min(config.ssd_chunk, seq)
        hs = min(sd_h, 4)
        key = f"ssd:{backend}:s{seq}:h{hs}:p{sd_p}:n{sd_n}:c{sch}"
        ks = jax.random.split(rng, 4)
        xh = jax.random.normal(ks[0], (1, seq, hs, sd_p), jnp.bfloat16)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, seq, hs)))
        a = -jnp.ones((hs,), jnp.float32)
        bb = jax.random.normal(ks[2], (1, seq, hs, sd_n), jnp.bfloat16)
        cc = jax.random.normal(ks[3], (1, seq, hs, sd_n), jnp.bfloat16)
        out["ssd"] = measure(key, lambda: _time_fn(
            lambda *args: ssd_scan_pallas(*args, chunk=sch,
                                          interpret=interp),
            xh, dt, a, bb, cc, reps=reps))

    if dirty:
        _store_cache(path, cache)
    return out


def verify_config(cfg: "ArchConfig", *, seq_len: int,
                  config: KernelConfig) -> bool:
    """Compile-and-run gate for a tuner-selected config: every kernel the
    arch uses must instantiate through the real ``pallas_call``
    (``interpret=True`` off-TPU) at the chosen tiles and produce finite
    output of the right shape.  Raises on failure; returns True."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas
    from repro.kernels.ssd_scan import ssd_scan_pallas

    interp = jax.default_backend() != "tpu"
    seq = min(int(seq_len), 1024)
    sd_h, sd_p, sd_n = ssd_dims(cfg)
    rng = jax.random.PRNGKey(1)

    def check(name, arr, shape):
        if tuple(arr.shape) != tuple(shape):
            raise AssertionError(f"{name}: shape {arr.shape} != {shape}")
        if not bool(jnp.all(jnp.isfinite(arr.astype(jnp.float32)))):
            raise AssertionError(f"{name}: non-finite output at {config}")

    if _attn_frac(cfg):
        hd = max(cfg.head_dim, 1)
        q = jax.random.normal(rng, (2, seq, hd), jnp.bfloat16)
        o = flash_attention_fwd(q, q, q, causal=True,
                                q_block=min(config.attn_q_block, seq),
                                kv_block=min(config.attn_kv_block, seq),
                                interpret=interp)
        check("attn", o, q.shape)

    x = jax.random.normal(rng, (seq, cfg.d_model), jnp.bfloat16)
    o = rmsnorm_pallas(x, jnp.ones((cfg.d_model,), jnp.bfloat16),
                       row_block=min(config.rmsnorm_block, seq),
                       interpret=interp)
    check("rms", o, x.shape)

    if sd_h:
        hs = min(sd_h, 2)
        ks = jax.random.split(rng, 4)
        xh = jax.random.normal(ks[0], (1, seq, hs, sd_p), jnp.bfloat16)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, seq, hs)))
        a = -jnp.ones((hs,), jnp.float32)
        bb = jax.random.normal(ks[2], (1, seq, hs, sd_n), jnp.bfloat16)
        cc = jax.random.normal(ks[3], (1, seq, hs, sd_n), jnp.bfloat16)
        y = ssd_scan_pallas(xh, dt, a, bb, cc,
                            chunk=min(config.ssd_chunk, seq),
                            interpret=interp)
        check("ssd", y, xh.shape)
    return True


# ---------------------------------------------------------------------------
# calibration: anchor the roofline scales on measured defaults
# ---------------------------------------------------------------------------


def calibrate(cfg: "ArchConfig", *, seq_len: int, hw: HardwareSpec = V5E,
              kc: Optional[KernelCoeffs] = None, reps: int = 3,
              cache_path=None) -> KernelCoeffs:
    """Anchor each kernel's ``*_scale`` so predicted(default config) ==
    measured(default config) on the bench shapes.  The relative shape of
    the roofline across tiles is untouched (the other coefficients set
    it); the scales just pin its absolute level to this host's
    measurements.  Frozen-default plans are invariant to calibration —
    the cost model prices kernels as a delta that is 0 at the default."""
    kc = kc if kc is not None else KernelCoeffs()
    measured = bench_config(cfg, seq_len=seq_len, reps=reps,
                            cache_path=cache_path)
    seq, heads = _bench_shapes(cfg, seq_len)
    # predict on the BENCH shapes (b scaled so head/row counts match)
    sd_h, _p, _n = ssd_dims(cfg)
    pred = predict_times(cfg, seq_len=seq, hw=hw, kc=kc,
                         b=max(1, heads) / max(1, cfg.num_heads))
    upd = {}
    if "attn" in measured and pred["attn"] > 0:
        upd["attn_scale"] = kc.attn_scale * measured["attn"] / pred["attn"]
    if "rms" in measured and pred["rms"] > 0:
        upd["rms_scale"] = kc.rms_scale * measured["rms"] / pred["rms"]
    if sd_h and "ssd" in measured and pred["ssd"] > 0:
        upd["ssd_scale"] = kc.ssd_scale * measured["ssd"] / pred["ssd"]
    return kc.replace(**upd)
