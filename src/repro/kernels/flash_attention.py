"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

Grid: (batch*kv_heads*q_per_kv, num_q_blocks, num_kv_blocks) with the KV
block dim innermost (sequential on TPU), carrying (acc, m, l) in VMEM
scratch across KV iterations.  Block shapes are MXU-aligned (q_block x
head_dim and kv_block x head_dim tiles, head_dim padded to >=128 by the
wrapper when needed).

The backward pass recomputes attention via the blocked-jnp path under
``jax.custom_vjp`` (flash-style recompute; see ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, q_block: int, kv_block: int,
               kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * q_block
    k_start = ki * kv_block

    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = k_start <= q_start + q_block - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (q_block, d)
        k = k_ref[0].astype(jnp.float32)            # (kv_block, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_block, kv_block), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (q_block, kv_block), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                         # (q_block, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, q_block: int = 256,
                        kv_block: int = 256, scale: float | None = None,
                        interpret: bool = True) -> jax.Array:
    """q (BH, Sq, D), k/v (BH, Sk, D) -> (BH, Sq, D).

    BH = batch * heads (GQA expansion done by the wrapper in ops.py).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, sk, q_block, kv_block)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // q_block, sk // kv_block)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, q_block=q_block,
        kv_block=kv_block, kv_len=sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            # VMEM carries across the sequential kv grid dim
            pltpu.VMEM((q_block, d), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
