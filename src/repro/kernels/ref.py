"""Pure-jnp oracles for every kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    scale: float | None = None) -> jax.Array:
    """q/k/v (BH, S, D) -> (BH, Sq, D); full S^2 softmax in f32."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6
                ) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_ref(xh: jax.Array, dt: jax.Array, a: jax.Array, bb: jax.Array,
            cc: jax.Array) -> jax.Array:
    """Exact sequential SSD recurrence (the strongest oracle — independent
    of any chunking algebra).  xh (B,S,H,P), dt (B,S,H) f32, a (H,) f32,
    bb/cc (B,S,H,N) -> y (B,S,H,P)."""
    bsz, s, h, p = xh.shape
    n = bb.shape[-1]

    def step(hstate, inp):
        x_t, dt_t, b_t, c_t = inp             # (B,H,P),(B,H),(B,H,N),(B,H,N)
        dec = jnp.exp(dt_t * a)               # (B,H)
        hstate = hstate * dec[..., None, None] + \
            (dt_t[..., None] * x_t.astype(jnp.float32))[..., None] \
            * b_t[..., None, :].astype(jnp.float32)
        y_t = jnp.einsum("bhpn,bhn->bhp", hstate,
                         c_t.astype(jnp.float32))
        return hstate, y_t

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bb.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xh.dtype)
