"""Pallas TPU fused RMSNorm (+optional scale) over the last dim.

Grid over row blocks; each block computes mean-of-squares in f32 VMEM and
writes the normalized output in one pass (one HBM read + one write vs. the
unfused 3-pass lowering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            row_block: int = 256, interpret: bool = True) -> jax.Array:
    """x (..., rows, d), scale (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    rb = min(row_block, rows)
    while rows % rb:
        rb //= 2
    grid = (rows // rb,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
