"""Intra-stage Pareto tuning + inter-stage MILP: properties & cross-checks."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro.configs.base import get_arch
from repro.core.inter_stage import (StageCand, pipeline_objective,
                                    simulate_pipeline, solve_exact,
                                    solve_milp)
from repro.core.intra_stage import (IntraStageResult, ParetoPoint,
                                    pareto_front, tune_stage)
from repro.core.schedule import Candidate


def _pp(t, d):
    return ParetoPoint(t=t, d=d, mem=0.0,
                       cand=Candidate(b=1, dp=1, tp=1, zero=1, ckpt=0,
                                      wo=0, go=0, oo=0, ao=0))


# -- pareto_front ---------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.tuples(st.floats(0.1, 10.0), st.floats(0.0, 10.0)),
                    min_size=1, max_size=60))
    def test_pareto_front_nondominated(pts):
        front = pareto_front([_pp(t, d) for t, d in pts], max_points=100)
        # no point in the front dominates another
        for a in front:
            for b in front:
                if a is not b:
                    assert not a.dominates(b)
        # every input point is dominated-or-equal by some front point
        for t, d in pts:
            assert any(f.t <= t + 1e-12 and f.d <= d + 1e-12
                       for f in front)
else:
    def test_property_tests_need_hypothesis():
        pytest.importorskip("hypothesis")


def test_pareto_decimation():
    pts = [_pp(float(i), float(100 - i)) for i in range(100)]
    front = pareto_front(pts, max_points=10)
    assert len(front) <= 10
    assert front[0].t == min(p.t for p in pts)
    assert front[-1].d == min(p.d for p in pts)


# -- tune_stage -----------------------------------------------------------------


@pytest.fixture(scope="module")
def stage_result():
    return tune_stage(get_arch("granite-3-8b"), seq_len=4096, layers=40,
                      n_devices=16, global_batch_per_stage=32, grad_accum=8,
                      refine=False)


def test_tune_stage_feasible(stage_result):
    assert stage_result.n_feasible > 0
    assert stage_result.frontier


def test_tune_stage_frontier_sorted(stage_result):
    ts = [p.t for p in stage_result.frontier]
    ds = [p.d for p in stage_result.frontier]
    assert ts == sorted(ts)
    assert ds == sorted(ds, reverse=True)


def test_tune_stage_respects_budget(stage_result):
    from repro.core.costmodel import CostParams
    from repro.core.hardware import V5E
    budget = V5E.hbm_bytes * CostParams().mem_headroom
    for p in stage_result.frontier:
        assert p.mem <= budget


def test_tune_stage_candidates_legal(stage_result):
    for p in stage_result.frontier:
        c = p.cand
        assert c.dp * c.tp == 16
        assert 8 * c.b * c.dp == 32          # G*b*dp == global batch
        assert 0 <= c.zero <= 3
        assert 0 <= c.ckpt <= 40


# -- pipeline objective vs simulator ---------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(0.1, 2.0), min_size=1, max_size=6),
           st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6),
           st.integers(1, 16))
    def test_objective_close_to_simulation(ts, ds, G):
        n = min(len(ts), len(ds))
        ts, ds = ts[:n], ds[:n]
        obj = pipeline_objective(ts, ds, G)
        sim = simulate_pipeline(ts, ds, G)
        # the analytic objective upper-bounds a GPipe simulation and is
        # tight within the sum of deltas (deltas placed optimistically)
        assert obj >= sim - sum(ds) - 1e-6
        assert obj <= sim + sum(ds) + sum(ts) + 1e-6


def test_objective_uniform_no_delta():
    # classic GPipe formula: (G - 1 + S) * t when all stages equal, d=0
    ts, G = [1.0] * 4, 8
    assert pipeline_objective(ts, [0.0] * 4, G) == pytest.approx(
        (G - 1) * 1.0 + 4.0)
    assert simulate_pipeline(ts, [0.0] * 4, G) == pytest.approx(
        (G - 1 + 4) * 1.0)


# -- MILP vs exact ---------------------------------------------------------------


def _rand_instance(rng, S, ncand):
    layers_opts = [2, 3, 4]
    cands = []
    for i in range(S):
        cs = []
        for _ in range(ncand):
            cs.append(StageCand(layers=int(rng.choice(layers_opts)),
                                n_devices=4,
                                t=float(rng.uniform(0.1, 2.0)),
                                d=float(rng.uniform(0.0, 1.0))))
        cands.append(cs)
    return cands


@pytest.mark.parametrize("seed", range(6))
def test_milp_matches_exact(seed):
    rng = np.random.default_rng(seed)
    S = int(rng.integers(2, 4))
    cands = _rand_instance(rng, S, 5)
    total_layers = S * 3
    total_devices = S * 4
    G = int(rng.integers(1, 9))
    exact = solve_exact(cands, total_layers=total_layers,
                        total_devices=total_devices, G=G)
    milp = solve_milp(cands, total_layers=total_layers,
                      total_devices=total_devices, G=G)
    if exact is None:
        assert milp is None
    else:
        assert milp is not None
        assert milp.objective == pytest.approx(exact.objective, rel=1e-6)


def test_milp_respects_budgets():
    cands = [[StageCand(layers=2, n_devices=4, t=1.0, d=0.0),
              StageCand(layers=4, n_devices=4, t=2.0, d=0.0)]] * 2
    sol = solve_milp(cands, total_layers=6, total_devices=8, G=4)
    assert sol is not None
    assert sum(c.layers for c in sol.selection) == 6
    assert sum(c.n_devices for c in sol.selection) == 8


def test_milp_infeasible_returns_none():
    cands = [[StageCand(layers=2, n_devices=4, t=1.0, d=0.0)]] * 2
    assert solve_milp(cands, total_layers=5, total_devices=8, G=1) is None


def test_milp_prefers_balanced_pipeline():
    """Imbalanced layer split must lose to balanced when G is large."""
    fast = StageCand(layers=3, n_devices=4, t=1.0, d=0.0)
    slow = StageCand(layers=4, n_devices=4, t=1.5, d=0.0)
    faster = StageCand(layers=2, n_devices=4, t=0.7, d=0.0)
    cands = [[fast, slow, faster], [fast, slow, faster]]
    sol = solve_milp(cands, total_layers=6, total_devices=8, G=64)
    assert sol is not None
    assert [c.layers for c in sol.selection] == [3, 3]


def test_milp_imbalance_awareness_changes_choice():
    """A candidate with smaller t but huge d on stage 0 (no fill slack)
    must lose to a balanced one when G is small — the paper's Shortcoming
    #3 example."""
    cheap_t_huge_d = StageCand(layers=2, n_devices=4, t=1.0, d=8.0)
    balanced = StageCand(layers=2, n_devices=4, t=1.3, d=0.1)
    cands = [[cheap_t_huge_d, balanced]]
    sol = solve_milp(cands, total_layers=2, total_devices=4, G=2)
    assert sol is not None
    assert sol.selection[0].t == pytest.approx(1.3)
    # with huge G the amortized t wins
    sol2 = solve_milp(cands, total_layers=2, total_devices=4, G=512)
    assert sol2.selection[0].t == pytest.approx(1.0)
