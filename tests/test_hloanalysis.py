"""HLO analysis: trip-count weighting, dot FLOPs, collective wire bytes —
checked against hand-crafted HLO snippets and a real compiled program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hloanalysis import analyze, parse_hlo

SYNTH = """
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %y = f32[128,128] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %y)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%zero, %x)
  %w = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body
  %r = f32[128,128] get-tuple-element(%w), index=1
  %ar = f32[128,128] all-reduce(%r), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ag = f32[128,128] all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


def test_synthetic_trip_count_and_flops():
    st = analyze(SYNTH)
    # one 128x128x128 dot per iteration, 10 iterations
    want = 10 * 2 * 128 * 128 * 128
    assert st.dot_flops == pytest.approx(want)


def test_synthetic_collectives():
    st = analyze(SYNTH)
    msg = 128 * 128 * 4
    # ring all-reduce over 4: wire = 2*(g-1)/g * msg
    want_ar = 2 * 3 / 4 * msg
    # all-gather: (g-1) * input bytes
    want_ag = 3 * msg
    assert st.collective_by_kind["all-reduce"] == pytest.approx(want_ar)
    assert st.collective_by_kind["all-gather"] == pytest.approx(want_ag)
    assert st.n_collectives == 2


def test_parse_computations():
    comps = parse_hlo(SYNTH)
    assert "__entry__" in comps
    assert "body" in comps and "cond" in comps


# -- real compiled programs -----------------------------------------------------


def test_real_matmul_flops():
    m, k, n = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    hlo = f.lower(jnp.zeros((m, k)), jnp.zeros((k, n))).compile().as_text()
    st = analyze(hlo)
    assert st.dot_flops == pytest.approx(2 * m * k * n)


def test_real_scan_trip_count():
    L, d = 7, 32

    @jax.jit
    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    hlo = f.lower(jnp.zeros((L, d, d)), jnp.zeros((4, d))).compile().as_text()
    st = analyze(hlo)
    want = L * 2 * 4 * d * d
    # CPU may fuse/pad; require within 2x and at least the exact flops
    assert st.dot_flops >= want * 0.99
    assert st.dot_flops <= want * 2.5


def test_hbm_bytes_positive_and_sane():
    @jax.jit
    def f(a):
        return jnp.tanh(a) * 2.0

    hlo = f.lower(jnp.zeros((1024, 1024))).compile().as_text()
    st = analyze(hlo)
    assert st.hbm_bytes >= 2 * 1024 * 1024 * 4     # read + write
    assert st.hbm_bytes <= 16 * 1024 * 1024 * 4


def test_dus_inplace_write_counted_once():
    """A scan writing into a stacked output should count slice bytes per
    iteration, not the full buffer each time."""
    L, d = 16, 256

    @jax.jit
    def f(x):
        def body(c, _):
            return c, c * 1.5
        _, ys = jax.lax.scan(body, x, None, length=L)
        return ys

    hlo = f.lower(jnp.zeros((d,))).compile().as_text()
    st = analyze(hlo)
    # per iter: read d floats, write d floats (+ loop bookkeeping).
    # full-buffer-per-iter would be ~L*L*d*4 = 67MB; slice-aware ~ L*2*d*4
    assert st.hbm_bytes < L * d * 4 * 20
