"""Cost model invariants: memory/runtime monotonicity in each Mist knob."""
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.costmodel import StageCostModel, arch_stats, estimate_plan
from repro.core.plan import single_stage_plan
from repro.configs.base import ShapeConfig


@pytest.fixture(scope="module")
def scm():
    return StageCostModel(get_arch("granite-3-8b"), 4096)


def _env(scm, **kw):
    base = dict(b=1.0, dp=4.0, tp=4.0, L=40.0, G=8.0, ckpt=0.0, zero=1,
                wo=0.0, go=0.0, oo=0.0, ao=0.0, inflight=1.0)
    base.update(kw)
    return base


def test_ckpt_reduces_memory_increases_time(scm):
    lo = scm.evaluate(_env(scm, ckpt=0.0))
    hi = scm.evaluate(_env(scm, ckpt=40.0))
    assert hi["mem_peak"][()] < lo["mem_peak"][()]
    assert hi["t_stable"][()] > lo["t_stable"][()]


def test_zero_levels_reduce_memory(scm):
    mems = [float(scm.evaluate(_env(scm, zero=z))["mem_peak"]) for z in
            (0, 1, 2, 3)]
    assert mems[1] < mems[0]
    assert mems[2] < mems[1]
    assert mems[3] < mems[2]


def test_zero23_add_communication(scm):
    t1 = scm.evaluate(_env(scm, zero=1))
    t3 = scm.evaluate(_env(scm, zero=3))
    assert float(t3["items"]["zero3_allgather_fwd"]) > 0.0
    assert float(t1["items"]["zero3_allgather_fwd"]) == 0.0
    assert float(t3["items"]["zero2_reduce_scatter"]) > 0.0


def test_offload_reduces_memory_adds_dma(scm):
    off = scm.evaluate(_env(scm, oo=1.0, ao=1.0, ckpt=40.0))
    on = scm.evaluate(_env(scm, oo=0.0, ao=0.0, ckpt=40.0))
    assert float(off["mem_peak"]) < float(on["mem_peak"])
    assert float(off["items"]["opt_swap_in"]) > 0.0
    assert float(off["items"]["act_offload_out"]) > 0.0
    # optimizer swap is once-per-step -> lands in d, not t
    assert float(off["d_delta"]) > float(on["d_delta"])


def test_tp_reduces_memory_adds_comm(scm):
    t1 = scm.evaluate(_env(scm, tp=1.0, dp=16.0))
    t8 = scm.evaluate(_env(scm, tp=8.0, dp=2.0))
    assert float(t8["mem_peak"]) < float(t1["mem_peak"])
    assert float(t8["items"]["tp_fwd"]) > 0.0
    assert float(t1["items"]["tp_fwd"]) == 0.0


def test_bigger_microbatch_longer_step(scm):
    a = scm.evaluate(_env(scm, b=1.0))
    b = scm.evaluate(_env(scm, b=4.0))
    assert float(b["t_stable"]) > float(a["t_stable"])
    assert float(b["mem_peak"]) > float(a["mem_peak"])


def test_batched_matches_scalar(scm):
    ck = np.array([0.0, 10.0, 20.0, 40.0])
    env = _env(scm, ckpt=ck)
    batched = scm.evaluate(env)
    for i, c in enumerate(ck):
        single = scm.evaluate(_env(scm, ckpt=float(c)))
        np.testing.assert_allclose(batched["t_stable"][i],
                                   single["t_stable"][()], rtol=1e-12)
        np.testing.assert_allclose(batched["mem_peak"][i],
                                   single["mem_peak"][()], rtol=1e-12)


def test_dp_grad_sync_in_delta_not_stable(scm):
    """ZeRO-1 grad all-reduce happens once per step -> d_delta only."""
    r = scm.evaluate(_env(scm, zero=1, dp=8.0, tp=2.0))
    assert float(r["items"]["dp_grad_sync"]) > 0.0
    assert float(r["d_delta"]) > 0.0


# -- arch stats ---------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-72b", "dbrx-132b",
                                  "zamba2-2.7b", "xlstm-1.3b"])
def test_arch_stats_consistent_with_param_count(arch):
    cfg = get_arch(arch)
    st = arch_stats(cfg)
    total = st.n_layer * cfg.num_layers + st.n_shared + st.n_embed
    assert total == pytest.approx(cfg.param_count(), rel=1e-6)


def test_moe_active_params_less_than_total():
    st = arch_stats(get_arch("dbrx-132b"))
    assert st.n_layer_active < st.n_layer
    # 16 experts top-4 -> MLP params active fraction ~ 4/16
    cfg = get_arch("dbrx-132b")
    expert = 3 * cfg.d_model * cfg.moe_d_ff
    assert st.n_layer - st.n_layer_active == pytest.approx(
        (cfg.num_experts - cfg.num_experts_per_tok) * expert)


# -- whole-plan estimate --------------------------------------------------------


def test_estimate_plan_runs_and_fits_logic():
    cfg = get_arch("granite-3-8b")
    shape = ShapeConfig("t", 4096, 32, "train")
    plan = single_stage_plan(cfg.num_layers, dp=4, tp=4, micro_batch=1,
                             grad_accum=8, zero=2, ckpt_layers=cfg.num_layers)
    est = estimate_plan(cfg, shape, plan)
    assert est["t_step"] > 0
    assert est["throughput_samples"] == pytest.approx(
        32 / est["t_step"])
    # full remat + ZeRO-2 on 16 devices of an 8B model should fit
    assert est["fits"]
