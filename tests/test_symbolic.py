"""Symbolic expression engine: correctness + batched-broadcast semantics."""
import math
import pickle

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro.core import symbolic as S
from repro.core.symbolic import Const, Sym, ceil_div, smax, smin, where, wrap


def test_basic_arithmetic():
    x, y = Sym("x"), Sym("y")
    e = (x + 2) * y - x / y
    assert e(x=4.0, y=2.0) == pytest.approx((4 + 2) * 2 - 4 / 2)


def test_batched_broadcast():
    x, y = Sym("x"), Sym("y")
    e = x * y + 1
    xs = np.arange(5, dtype=float)
    out = e(x=xs, y=2.0)
    np.testing.assert_allclose(out, xs * 2 + 1)


def test_min_max_where():
    x = Sym("x")
    e = where(x > 3, smax(x, 10.0), smin(x, 1.0))
    assert e(x=5.0) == 10.0
    assert e(x=2.0) == 1.0
    np.testing.assert_allclose(e(x=np.array([0.0, 4.0])), [0.0, 10.0])


def test_ceil_div():
    e = ceil_div(Sym("a"), Sym("b"))
    assert e(a=7.0, b=2.0) == 4.0
    assert e(a=6.0, b=2.0) == 3.0


def test_constant_folding():
    e = Const(2) * Const(3) + Const(0)
    assert isinstance(e, Const) and e.v == 6.0
    x = Sym("x")
    assert (x * 1) is x
    assert (x + 0) is x
    z = x * 0
    assert isinstance(z, Const) and z.v == 0.0


def test_unbound_symbol_raises():
    with pytest.raises(KeyError):
        Sym("nope")(x=1.0)


def test_memo_shared_subexpression():
    x = Sym("x")
    sub = x * x
    e = sub + sub
    assert e(x=3.0) == 18.0


# -- pickling re-interns through the constructors -----------------------------
# Hash-consed nodes use __new__-level caches + __slots__, which the default
# pickle protocol cannot reconstruct; __reduce__ re-enters the constructors
# so round-trips preserve interned identity (the property spawn-based
# worker pools and the multi-host sweep rely on).


def test_pickle_round_trip_is_identity():
    x, y = Sym("x"), Sym("y")
    exprs = [
        Const(2.5),
        x,
        x + 1,                                   # the ISSUE's repro case
        smax(x * y, 3.0) + ceil_div(x, 2.0),
        where(x > y, x - y, y - x),
    ]
    for e in exprs:
        r = pickle.loads(pickle.dumps(e))
        assert r is e, f"round-trip broke interning for {e!r}"


def test_pickle_existing_nodes_add_no_intern_entries():
    x = Sym("x")
    e = (x + 1) * smin(x, 7.0)
    before = S.intern_cache_stats()
    out = pickle.loads(pickle.dumps(e))
    assert out is e
    assert S.intern_cache_stats() == before


def test_pickle_shared_subdag_stays_shared():
    x = Sym("x")
    sub = (x + 1.0) * (x + 2.0)
    pair = (sub + 3.0, sub * 4.0)
    a, b = pickle.loads(pickle.dumps(pair))
    assert a is pair[0] and b is pair[1]
    assert a.a is b.a                            # the shared sub-DAG node


def test_pickle_nan_const_round_trips_without_interning():
    e = Const(float("nan"))
    r = pickle.loads(pickle.dumps(e))
    assert isinstance(r, Const) and math.isnan(r.v)
    assert r is not e                            # NaN is never interned


def test_pickle_evaluates_identically():
    x, y = Sym("x"), Sym("y")
    e = where(x > y, x / y, y / x) + smax(x, y)
    r = pickle.loads(pickle.dumps(e))
    xs = np.linspace(0.5, 4.0, 17)
    np.testing.assert_array_equal(e(x=xs, y=2.0), r(x=xs, y=2.0))


# -- hypothesis: random expression trees evaluate like direct numpy ----------


def _build(t):
    if isinstance(t, S.Expr):
        return t
    op, a, b = t
    a, b = _build(a), _build(b)
    return {"+": a + b, "-": a - b, "*": a * b}[op]


def _direct(t, env):
    if isinstance(t, Const):
        return t.v
    if isinstance(t, Sym):
        return env[t.name]
    op, a, b = t
    a, b = _direct(a, env), _direct(b, env)
    return {"+": a + b, "-": a - b, "*": a * b}[op]


if HAVE_HYPOTHESIS:
    _leaf = st.one_of(
        st.floats(min_value=0.1, max_value=10.0).map(Const),
        st.sampled_from(["x", "y", "z"]).map(Sym),
    )

    def _tree(depth):
        if depth == 0:
            return _leaf
        sub = _tree(depth - 1)
        return st.one_of(
            _leaf,
            st.tuples(st.sampled_from("+-*"), sub, sub),
        )

    @settings(max_examples=100, deadline=None)
    @given(_tree(4), st.floats(0.1, 5.0), st.floats(0.1, 5.0),
           st.floats(0.1, 5.0))
    def test_random_trees_match_numpy(t, x, y, z):
        env = {"x": x, "y": y, "z": z}
        expr = _build(t)
        got = expr(**env)
        want = _direct(t, env)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    @settings(max_examples=50, deadline=None)
    @given(_tree(4),
           st.lists(st.floats(0.1, 5.0), min_size=3, max_size=3))
    def test_batched_equals_scalar_loop(t, vals):
        expr = _build(t)
        xs = np.asarray(vals)
        batched = expr(x=xs, y=2.0, z=3.0)
        looped = np.asarray([expr(x=float(v), y=2.0, z=3.0) for v in vals])
        np.testing.assert_allclose(batched, looped, rtol=1e-12)
else:
    def test_property_tests_need_hypothesis():
        pytest.importorskip("hypothesis")
