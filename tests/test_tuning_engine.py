"""Compiled tuning engine: tape/grid/memoization equivalence guarantees.

The refactor contract is *identical results*: tape-compiled evaluation must
match the recursive reference walk bitwise (atol 0), the struct-of-arrays
grid must reproduce the nested-loop enumeration exactly (content AND order,
so Pareto tie-breaking is unchanged), and the compiled tuner must return the
same frontiers/objective/plan as the legacy engine.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro.configs.base import ShapeConfig, get_arch
from repro.core import symbolic as S
from repro.core.costmodel import StageCostModel
from repro.core.intra_stage import (ParetoPoint, pareto_front,
                                    pareto_front_indices, tune_stage)
from repro.core.schedule import (Candidate, candidate_grid,
                                 enumerate_candidates)
from repro.core.symbolic import BinOp, Const, Sym, compile_tape, smax, smin
from repro.core.tuner import MistTuner, TuneSpec, tune


# -- hash-consing --------------------------------------------------------------


def test_hash_consing_interns_structurally_equal_nodes():
    x, y = Sym("x"), Sym("y")
    assert Sym("x") is x
    assert Const(2.5) is Const(2.5)
    assert (x + y) is (Sym("x") + Sym("y"))
    assert smax(x * y, 3.0) is smax(x * y, 3.0)
    # distinct structures stay distinct
    assert (x + y) is not (y + x)


def test_hash_consing_preserves_folding():
    x = Sym("x")
    assert (x * 1) is x
    assert (x + 0) is x
    z = x * 0
    assert isinstance(z, Const) and z.v == 0.0


def test_tape_cse_shared_subdag_evaluated_once():
    x = Sym("x")
    sub = (x + 1.0) * (x + 2.0)
    a, b = sub + 3.0, sub * 4.0
    tape = compile_tape({"a": a, "b": b})
    # leaves: x, 1, 2, 3, 4 -> 5 ops total, NOT 8 (sub shared, not re-run)
    assert len(tape) == 5
    out = tape.run({"x": 7.0})
    assert out["a"] == (8.0 * 9.0) + 3.0
    assert out["b"] == (8.0 * 9.0) * 4.0


def test_tape_slot_reuse_bounds_live_buffers():
    x, y = Sym("x"), Sym("y")
    chain = x
    for _ in range(50):
        chain = (chain + x) * y           # 100 ops over just two leaves
    tape = compile_tape({"o": chain})
    assert len(tape) == 100
    assert tape.n_slots <= 5              # slots recycled along the chain
    assert tape.run({"x": 1.0, "y": 1.0})["o"] == 51.0


# -- tape vs recursive evaluation ---------------------------------------------


def test_tape_matches_recursive_on_mixed_dag():
    x, y = Sym("x"), Sym("y")
    e1 = smin(x / y, S.ceil(x) * 2.0) + S.where(x > y, x - y, y - x)
    e2 = (x / y) * (x / y) + e1
    tape = compile_tape({"e1": e1, "e2": e2})
    env = {"x": np.linspace(0.1, 9.0, 23), "y": 2.0}
    got, memo = tape.run(env), {}
    np.testing.assert_allclose(got["e1"], e1.evaluate(env, memo), atol=0)
    np.testing.assert_allclose(got["e2"], e2.evaluate(env, memo), atol=0)


@pytest.mark.parametrize("arch,role", [
    ("granite-3-8b", (True, True)),
    ("granite-3-8b", (False, False)),
    ("qwen2-72b", (True, False)),
    ("dbrx-132b", (False, True)),
    ("zamba2-2.7b", (True, True)),
])
def test_stage_cost_model_tape_matches_recursive(arch, role):
    cfg = get_arch(arch)
    scm = StageCostModel(cfg, 4096, has_embed=role[0], has_head=role[1])
    L = min(16, cfg.num_layers)
    grid = candidate_grid(cfg, n_devices=8, layers=L, global_batch=16,
                          grad_accum=4)
    env = grid.env(layers=L, grad_accum=4, inflight=2.0)
    a = scm.evaluate(env)
    b = scm.evaluate_recursive(env)
    for k in ("mem_fwd", "mem_bwd", "mem_peak", "t_stable", "d_delta",
              "t_step", "t_first", "t_last"):
        np.testing.assert_allclose(a[k], b[k], atol=0, err_msg=k)
    for k in a["items"]:
        np.testing.assert_allclose(a["items"][k], b["items"][k], atol=0,
                                   err_msg=k)


def test_split_tapes_match_full_evaluation():
    cfg = get_arch("granite-3-8b")
    scm = StageCostModel(cfg, 4096)
    grid = candidate_grid(cfg, n_devices=16, layers=40, global_batch=32,
                          grad_accum=4)
    env = grid.env(layers=40, grad_accum=4)
    full = scm.evaluate_recursive(env)
    mem = scm.evaluate_memory(env)
    np.testing.assert_allclose(mem["mem_peak"], full["mem_peak"], atol=0)
    feas = np.nonzero(mem["mem_peak"] <= scm.memory_budget())[0]
    times = scm.evaluate_times(grid.take(feas).env(layers=40, grad_accum=4))
    np.testing.assert_allclose(times["t_stable"], full["t_stable"][feas],
                               atol=0)
    np.testing.assert_allclose(times["d_delta"], full["d_delta"][feas],
                               atol=0)


if HAVE_HYPOTHESIS:
    _leaf = st.one_of(
        st.floats(min_value=0.1, max_value=10.0).map(Const),
        st.sampled_from(["x", "y", "z"]).map(Sym),
    )

    def _tree(depth):
        if depth == 0:
            return _leaf
        sub = _tree(depth - 1)
        return st.one_of(
            _leaf, st.tuples(st.sampled_from("+-*/^v"), sub, sub))

    def _build(t):
        if isinstance(t, S.Expr):
            return t
        op, a, b = t
        a, b = _build(a), _build(b)
        return {"+": a + b, "-": a - b, "*": a * b, "/": a / b,
                "^": smax(a, b), "v": smin(a, b)}[op]

    @settings(max_examples=100, deadline=None)
    @given(st.lists(_tree(4), min_size=1, max_size=4),
           st.lists(st.floats(0.1, 5.0), min_size=3, max_size=3))
    def test_tape_matches_recursive_on_random_dags(trees, vals):
        outs = {f"o{i}": _build(t) for i, t in enumerate(trees)}
        tape = compile_tape(outs)
        env = {"x": np.asarray(vals), "y": 2.0, "z": 0.7}
        got, memo = tape.run(env), {}
        for k, e in outs.items():
            np.testing.assert_allclose(got[k], e.evaluate(env, memo),
                                       atol=0, err_msg=k)
else:
    def test_property_tests_need_hypothesis():
        pytest.importorskip("hypothesis")


# -- struct-of-arrays grid ----------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(n_devices=16, layers=40, global_batch=32, grad_accum=8),
    dict(n_devices=16, layers=40, global_batch=32, grad_accum=8,
         ckpt_granularity=5),
    dict(n_devices=8, layers=13, global_batch=24, grad_accum=3,
         zeros=(1,), ratios=(0.0,), ratio_dims=()),
    dict(n_devices=8, layers=13, global_batch=24, grad_accum=3,
         ckpt_values=(13,), max_tp=4),
    dict(n_devices=8, layers=13, global_batch=24, grad_accum=3,
         ratio_dims=("wo", "go", "oo", "ao"), ratios=(0.0, 0.5, 1.0)),
    dict(n_devices=6, layers=10, global_batch=30, grad_accum=5),
])
def test_candidate_grid_matches_enumeration(kw):
    cfg = get_arch("granite-3-8b")
    grid = candidate_grid(cfg, **kw)
    legacy = list(enumerate_candidates(cfg, **kw))
    assert len(grid) == len(legacy)
    for i in range(len(grid)):
        assert grid.candidate(i) == legacy[i]


def test_grid_env_matches_env_from_candidates():
    cfg = get_arch("granite-3-8b")
    kw = dict(n_devices=8, layers=20, global_batch=16, grad_accum=4)
    grid = candidate_grid(cfg, **kw)
    cands = list(enumerate_candidates(cfg, **kw))
    scm = StageCostModel(cfg, 2048)
    a = grid.env(layers=20, grad_accum=4, inflight=3.0)
    b = scm.env_from_candidates(cands, layers=20, grad_accum=4, inflight=3.0)
    for k, v in b.items():
        np.testing.assert_allclose(a[k], v, atol=0, err_msg=k)


# -- vectorized pareto selection ----------------------------------------------


def _pp(t, d):
    return ParetoPoint(t=t, d=d, mem=0.0,
                       cand=Candidate(b=1, dp=1, tp=1, zero=1, ckpt=0,
                                      wo=0, go=0, oo=0, ao=0))


def test_pareto_front_indices_matches_object_version():
    rng = np.random.default_rng(0)
    for trial in range(30):
        n = int(rng.integers(1, 300))
        t = rng.uniform(0.1, 3.0, n).round(2)   # rounding forces ties
        d = rng.uniform(0.0, 3.0, n).round(2)
        for max_points in (4, 16, 1000):
            idx = pareto_front_indices(t, d, max_points=max_points)
            ref = pareto_front([_pp(float(t[i]), float(d[i]))
                                for i in range(n)], max_points=max_points)
            assert [(t[i], d[i]) for i in idx] == [(p.t, p.d) for p in ref]


# -- tune_stage / tuner engine equivalence ------------------------------------


def test_tune_stage_engines_identical_frontier():
    cfg = get_arch("granite-3-8b")
    kw = dict(seq_len=4096, layers=40, n_devices=16,
              global_batch_per_stage=32, grad_accum=8)
    a = tune_stage(cfg, engine="compiled", **kw)
    b = tune_stage(cfg, engine="legacy", **kw)
    assert a.n_evaluated == b.n_evaluated
    assert a.n_feasible == b.n_feasible
    assert [(p.t, p.d, p.mem, p.cand) for p in a.frontier] \
        == [(p.t, p.d, p.mem, p.cand) for p in b.frontier]


def test_tuner_engines_identical_objective_and_plan():
    cfg = get_arch("granite-3-8b")
    shape = ShapeConfig("t", 4096, 32, "train")
    new = tune(cfg, shape, 16, space="mist", stage_counts=(1, 2),
               grad_accums=(4,))
    old = tune(cfg, shape, 16, space="mist", stage_counts=(1, 2),
               grad_accums=(4,), engine="legacy")
    assert new.objective == old.objective
    assert new.plan == old.plan
    assert (new.best_S, new.best_G) == (old.best_S, old.best_G)
    assert new.per_sg == old.per_sg


def test_unknown_engine_rejected():
    cfg = get_arch("granite-3-8b")
    with pytest.raises(ValueError):
        tune_stage(cfg, seq_len=2048, layers=8, n_devices=4,
                   global_batch_per_stage=8, grad_accum=2, engine="nope")


# -- frontier memoization -----------------------------------------------------


def test_frontier_memo_reuses_identical_hypotheses():
    cfg = get_arch("granite-3-8b")
    spec = TuneSpec(arch=cfg, seq_len=4096, global_batch=32, n_devices=16,
                    space="mist", stage_counts=(1,), grad_accums=(4,))
    tuner = MistTuner(spec)
    knobs = {"zeros": (0, 1, 2, 3), "ratios": (0.0, 0.5, 1.0),
             "ratio_dims": ("oo", "ao"), "ckpt": "tune"}
    r1 = tuner._frontier(layers=40, n_dev=16, G=4, role=(True, True),
                         inflight=1.0, knobs=knobs)
    swept = tuner._n_swept
    r2 = tuner._frontier(layers=40, n_dev=16, G=4, role=(True, True),
                         inflight=1.0, knobs=knobs)
    assert r2 is r1                       # served from the memo
    assert tuner._memo_hits == 1
    assert tuner._n_swept == swept        # nothing re-swept
    # any key component change misses
    r3 = tuner._frontier(layers=40, n_dev=16, G=4, role=(True, True),
                         inflight=2.0, knobs=knobs)
    assert r3 is not r1


def test_repeated_tune_on_same_tuner_uses_memo():
    cfg = get_arch("granite-3-8b")
    spec = TuneSpec(arch=cfg, seq_len=4096, global_batch=32, n_devices=16,
                    space="zero", stage_counts=(1, 2), grad_accums=(4,))
    tuner = MistTuner(spec)
    first = tuner.tune()
    second = tuner.tune()
    assert second.objective == first.objective
    assert second.plan == first.plan
    assert second.n_memo_hits > 0
    assert second.n_swept == 0            # everything served from the memo


# -- ratio refinement stays inside the declared space (satellite fix) ---------


def test_refinement_restricted_to_swept_ratio_dims():
    cfg = get_arch("granite-3-8b")
    res = tune_stage(cfg, seq_len=4096, layers=40, n_devices=16,
                     global_batch_per_stage=32, grad_accum=8,
                     ratio_dims=("oo", "ao"), refine=True)
    for p in res.frontier:
        assert p.cand.wo == 0.0, "wo escaped the declared search space"
        assert p.cand.go == 0.0, "go escaped the declared search space"
