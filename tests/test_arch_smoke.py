"""Per-architecture smoke tests on reduced same-family configs (the full
configs are exercised only by the dry-run): forward loss + one train step
(finite, shapes), prefill->decode consistency for cached inference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, list_archs
from repro.core.plan import single_stage_plan
from repro.models.common import ExecConfig
from repro.models.zoo import build_model

ARCHS = list_archs()


def _batch(cfg, b=2, s=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    if cfg.family == "vlm":
        st = s - cfg.num_patches
        return {"patch_embeds": jax.random.normal(
                    ks[0], (b, cfg.num_patches, cfg.d_model),
                    jnp.float32).astype(jnp.bfloat16),
                "tokens": jax.random.randint(ks[1], (b, st), 0,
                                             cfg.vocab_size),
                "labels": jax.random.randint(ks[1], (b, st), 0,
                                             cfg.vocab_size)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(
                    ks[0], (b, cfg.encoder_seq, cfg.d_model),
                    jnp.float32).astype(jnp.bfloat16),
                "tokens": jax.random.randint(ks[1], (b, s), 0,
                                             cfg.vocab_size),
                "labels": jax.random.randint(ks[1], (b, s), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_arch(request.param).reduced()
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, axes


def test_forward_loss_finite(arch_setup):
    cfg, model, params, _ = arch_setup
    ec = ExecConfig(ckpt_layers=cfg.num_layers // 2)
    loss = model.loss_fn(params, _batch(cfg), ec)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0     # ~log(V) at init


def test_grads_finite_and_nonzero(arch_setup):
    cfg, model, params, _ = arch_setup
    ec = ExecConfig(ckpt_layers=cfg.num_layers)
    g = jax.grad(lambda p: model.loss_fn(p, _batch(cfg), ec))(params)
    leaves = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in leaves)
    total = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
                for x in leaves)
    assert total > 0.0


def test_remat_does_not_change_loss(arch_setup):
    cfg, model, params, _ = arch_setup
    batch = _batch(cfg)
    l0 = model.loss_fn(params, batch, ExecConfig(ckpt_layers=0,
                                                 remat_policy="none"))
    l1 = model.loss_fn(params, batch, ExecConfig(
        ckpt_layers=cfg.num_layers, remat_policy="full"))
    assert float(l0) == pytest.approx(float(l1), rel=2e-2, abs=2e-2)


def test_one_train_step_reduces_loss(arch_setup):
    cfg, model, params, axes = arch_setup
    from repro.training import optimizer as OPT
    from repro.core.plan import StageConfig
    stage = StageConfig(layers=cfg.num_layers, micro_batch=2, dp=1, tp=1,
                        zero=0, ckpt_layers=0)
    state = OPT.init_state(params, axes, stage)
    batch = _batch(cfg)
    ec = ExecConfig(ckpt_layers=0, remat_policy="none")

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, ec))(state["params"])
        grads = {n: g.astype(jnp.float32) for n, g in grads.items()}
        new_state, m = OPT.adam_update(state, grads,
                                       OPT.AdamConfig(lr=5e-3))
        return new_state, loss

    l0 = None
    for _ in range(4):
        state, loss = step(state, batch)
        if l0 is None:
            l0 = float(loss)
    assert float(loss) < l0


def test_decode_matches_prefill(arch_setup):
    """Teacher-forced decode over cached state must match a fresh full
    forward at every position (prefill/decode consistency)."""
    cfg, model, params, _ = arch_setup
    if cfg.family in ("vlm", "audio"):
        pytest.skip("frontend-stub families checked in serve smoke")
    if cfg.is_moe:
        # capacity dropping makes prefill lossy by design; decode never
        # drops -> compare with drop-free capacity
        cfg = cfg.replace(capacity_factor=8.0)
        from repro.models.zoo import build_model
        model = build_model(cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    ec = ExecConfig(ckpt_layers=0, remat_policy="none")
    logits_p, caches = model.prefill_fn(params, {"tokens": toks[:, :s // 2]},
                                        ec, True)
    from repro.models.zoo import pad_caches
    caches = pad_caches(caches, s - s // 2)   # room for the decoded tokens
    # decode the second half token by token
    outs = []
    for i in range(s // 2, s):
        lg, caches = model.decode_fn(params, toks[:, i:i + 1], caches, ec)
        outs.append(lg[:, -1])
    got = jnp.stack(outs, axis=1)
    # reference: full prefill up to each position
    want = []
    for i in range(s // 2, s):
        lw, _ = model.prefill_fn(params, {"tokens": toks[:, :i + 1]}, ec,
                                 True)
        want.append(lw[:, -1])
    want = jnp.stack(want, axis=1)
    # bf16 caches + recompute-vs-cached paths accumulate ~0.2-0.4 absolute
    # noise on isolated near-zero logits over multiple layers; exact
    # equivalence is pinned per-mixer in test_kernels and the mixer-level
    # unit checks, so the model-level check is statistical
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    close = np.isclose(g, w, atol=0.3, rtol=0.3)
    assert close.mean() > 0.995, f"{(~close).sum()}/{close.size} mismatched"
    assert np.max(np.abs(g - w)) < 1.0


def test_long_500k_only_on_subquadratic():
    for name in ARCHS:
        cfg = get_arch(name)
        if "long_500k" in cfg.shapes:
            assert cfg.family in ("hybrid", "ssm"), \
                f"{name} is quadratic but claims long_500k"


def test_all_archs_registered():
    assert len(ARCHS) == 10
