"""Multi-device integration tests (subprocess: these need
--xla_force_host_platform_device_count, which must NOT leak into the other
tests' single-device jax runtime)."""
import json
import pathlib
import subprocess
import sys

import pytest

from repro import compat

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


PIPELINE_NUMERIC = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_arch
from repro.core.plan import Plan, StageConfig
from repro.models.zoo import build_model
from repro.parallel.pipeline import make_pipeline_train_step
import repro.training.optimizer as OPT
from repro.models.common import ExecConfig

cfg = get_arch('granite-3-8b').reduced().replace(num_layers=4)
model = build_model(cfg)
from repro import compat
mesh = compat.make_mesh((2, 2, 2), ('stage', 'data', 'model'))
G, b = 2, 2
stages = tuple(StageConfig(layers=2, micro_batch=b, dp=2, tp=2, zero=1,
                           ckpt_layers=2 if i == 0 else 0)
               for i in range(2))
plan = Plan(grad_accum=G, stages=stages)
with compat.set_mesh(mesh):
    params, axes = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (G, 4, 64), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (G, 4, 64), 0, cfg.vocab_size)
    ec = ExecConfig(ckpt_layers=0, remat_policy='none')
    ref = np.mean([float(model.loss_fn(params,
        {'tokens': tokens[i], 'labels': labels[i]}, ec)) for i in range(G)])
    step = make_pipeline_train_step(model, plan, mesh, donate=False)
    state = OPT.init_state(params, axes, plan.stages[0])
    state = jax.device_put(state, step.state_shardings)
    state2, m = step.fn(state, {'tokens': tokens, 'labels': labels})
    diff = abs(float(m['loss']) - ref)
    assert diff < 5e-3, (float(m['loss']), ref)
    assert float(m['grad_norm']) > 0
    # one more step changes the loss (optimizer applied across stages)
    state3, m2 = step.fn(state2, {'tokens': tokens, 'labels': labels})
    assert float(m2['loss']) < float(m['loss'])
    print('PIPELINE_OK', diff)
"""


@pytest.mark.skipif(not compat.supports_pipeline_stage_mapping(),
                    reason="partial-manual shard_map (scan+ppermute over a "
                           "manual stage axis) aborts the XLA SPMD "
                           "partitioner bundled with jax 0.4.x")
def test_pipeline_matches_reference():
    out = _run(PIPELINE_NUMERIC, devices=8)
    assert "PIPELINE_OK" in out


SINGLE_STAGE_SPMD = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import single_stage_plan
from repro.models.zoo import build_model
from repro.training.step import make_train_step, init_sharded_state
from repro.parallel import sharding as SH

cfg = get_arch('qwen2-moe-a2.7b').reduced()
model = build_model(cfg)
mesh = compat.make_mesh((2, 2), ('data', 'model'))
plan = single_stage_plan(cfg.num_layers, dp=2, tp=2, micro_batch=2,
                         grad_accum=2, zero=2,
                         ckpt_layers=cfg.num_layers // 2)
with compat.set_mesh(mesh):
    step = make_train_step(model, plan, mesh, donate=False)
    state, sh = init_sharded_state(model, plan, mesh, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {'tokens': jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
             'labels': jax.random.randint(key, (8, 64), 0, cfg.vocab_size)}
    losses = []
    for _ in range(3):
        state, m = step.fn(state, batch)
        losses.append(float(m['loss']))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    print('SPMD_OK', losses)
"""


def test_single_stage_spmd_zero2():
    out = _run(SINGLE_STAGE_SPMD, devices=4)
    assert "SPMD_OK" in out


OFFLOAD_STATE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import single_stage_plan
from repro.models.zoo import build_model
from repro.training.step import make_train_step, init_sharded_state

cfg = get_arch('granite-3-8b').reduced()
model = build_model(cfg)
mesh = compat.make_mesh((2, 1), ('data', 'model'))
# oo=0.5 -> half the stacked optimizer state host-offloaded (pinned_host
# where the backend has a host memory space; resident fallback otherwise)
plan = single_stage_plan(cfg.num_layers, dp=2, tp=1, micro_batch=2,
                         grad_accum=1, zero=1, oo=0.5, wo=0.5,
                         ckpt_layers=cfg.num_layers)
with compat.set_mesh(mesh):
    step = make_train_step(model, plan, mesh, donate=False)
    state, sh = init_sharded_state(model, plan, mesh, jax.random.PRNGKey(0))
    kinds = {l.sharding.memory_kind for l in jax.tree.leaves(state['mu'])}
    hk = compat.host_memory_kind()
    if hk is not None:
        assert hk in kinds, kinds

    key = jax.random.PRNGKey(1)
    batch = {'tokens': jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
             'labels': jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}
    l0 = None
    for _ in range(3):
        state, m = step.fn(state, batch)
        if l0 is None: l0 = float(m['loss'])
    assert float(m['loss']) < l0
    print('OFFLOAD_OK')
"""


def test_host_offloaded_optimizer_state():
    out = _run(OFFLOAD_STATE, devices=2)
    assert "OFFLOAD_OK" in out


ELASTIC = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import single_stage_plan
from repro.models.zoo import build_model
from repro.training.step import make_train_step, init_sharded_state
from repro.training.checkpoint import Checkpointer

cfg = get_arch('granite-3-8b').reduced()
model = build_model(cfg)
tmp = tempfile.mkdtemp()
# train on (2,1) mesh, checkpoint, restore onto (4,1) mesh
mesh_a = compat.make_mesh((2, 1), ('data', 'model'))
plan_a = single_stage_plan(cfg.num_layers, dp=2, tp=1, micro_batch=2,
                           grad_accum=1, zero=1)
key = jax.random.PRNGKey(1)
batch = {'tokens': jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
         'labels': jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}
with compat.set_mesh(mesh_a):
    step_a = make_train_step(model, plan_a, mesh_a, donate=False)
    state, _ = init_sharded_state(model, plan_a, mesh_a, jax.random.PRNGKey(0))
    state, m_a = step_a.fn(state, batch)
    ck = Checkpointer(tmp)
    ck.save(1, state)

mesh_b = compat.make_mesh((4, 1), ('data', 'model'))
plan_b = single_stage_plan(cfg.num_layers, dp=4, tp=1, micro_batch=1,
                           grad_accum=1, zero=2)
with compat.set_mesh(mesh_b):
    step_b = make_train_step(model, plan_b, mesh_b, donate=False)
    abs_state, sh_b = init_sharded_state(model, plan_b, mesh_b,
                                         jax.random.PRNGKey(0))
    stp, restored, _ = Checkpointer(tmp).restore(shardings=sh_b)
    state_b, m_b = step_b.fn(restored, batch)
    assert np.isfinite(float(m_b['loss']))
    # restored params equal saved ones
    w = 'layers/mlp/w_up' if 'layers/mlp/w_up' in restored['params'] else \
        sorted(restored['params'])[0]
    print('ELASTIC_OK', float(m_b['loss']))
"""


def test_elastic_restore_different_mesh():
    out = _run(ELASTIC, devices=4)
    assert "ELASTIC_OK" in out
