"""Prefill/decode parity across every cache family the zoo serves
(docs/serving.md).

The serve search space moves *where* work runs (dp/tp/zero) and *how*
the KV cache is stored (bf16/int8); it must never move *what* the model
computes.  This suite pins the numerics the tuner is trusted not to
perturb, one test per contract:

1. **Teacher-forced decode == full-sequence prefill, per step.**  For
   each cache family — GQA self-attention (granite), MLA absorbed-decode
   latents (minicpm3), pure recurrent SSM state (xlstm), hybrid
   mamba+attention (zamba2), enc-dec cross-attention (whisper), and the
   VLM patch-prefix decoder (internvl2) — decode logits at step k match
   a fresh prefill over prompt+k tokens within bf16 tolerance, at EVERY
   step, not just the last.
2. **The int8 KV path is a bounded perturbation.**  ``quantize_caches``
   converts exactly the self-attention {k, v, pos} leaves, decode writes
   stay int8 (+f32 scales), and the quantized logits track the bf16
   decode within the per-token scale error — greedy argmax unchanged.
3. **Plan choice is invisible to generate().**  The serve tuner's plan
   and the hand-built dp-only baseline emit identical token ids on a
   reduced golden arch — the end-to-end acceptance criterion, in tier-1
   (benchmarks/serve_throughput.py asserts the same on the full smoke
   cell, with timing).

Model-level GQA parity on the *training* archs lives in
tests/test_arch_smoke.py; this suite owns the serve-specific surface
(frontend-stub families, quantized caches, the tuned-plan loop).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.common import ExecConfig
from repro.models.zoo import build_model, pad_caches, quantize_caches

# one representative per cache family (reduced() configs)
FAMILY_ARCHS = {
    "gqa": "granite-3-8b",            # plain GQA self-attn {k, v, pos}
    "mla": "minicpm3-4b",             # MLA absorbed-decode latent cache
    "ssm": "xlstm-1.3b",              # pure recurrent state, no KV growth
    "hybrid": "zamba2-2.7b",          # interleaved mamba state + GQA KV
    "encdec": "whisper-small",        # self KV + frozen cross-attn KV
    "vlm": "internvl2-1b",            # GQA behind a patch-embed prefix
}

_EC = ExecConfig(ckpt_layers=0, remat_policy="none")
_B, _PROMPT, _STEPS = 2, 8, 3


def _prompt_batch(cfg, b, s, seed=0):
    """Tokens plus whatever frontend stub the family needs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    batch = {"tokens": jax.random.randint(ks[1], (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[0], (b, cfg.num_patches, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[0], (b, cfg.encoder_seq, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.fixture(scope="module", params=sorted(FAMILY_ARCHS),
                ids=sorted(FAMILY_ARCHS))
def family_setup(request):
    cfg = get_arch(FAMILY_ARCHS[request.param]).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _decode_steps(model, params, caches, toks, start, steps):
    """Teacher-forced decode; returns last-position logits per step."""
    outs = []
    for k in range(steps):
        lg, caches = model.decode_fn(params, toks[:, start + k:start + k + 1],
                                     caches, _EC)
        outs.append(lg[:, -1])
    return outs, caches


def test_decode_matches_prefill_every_step(family_setup):
    """Contract 1: at every decode step k, cached decode logits equal a
    fresh full-sequence prefill over prompt+k tokens (bf16 tolerance —
    cached-vs-recomputed paths differ by accumulation order only)."""
    cfg, model, params = family_setup
    full = _prompt_batch(cfg, _B, _PROMPT + _STEPS)
    toks = full["tokens"]

    _, caches = model.prefill_fn(params, dict(full, tokens=toks[:, :_PROMPT]),
                                 _EC, True)
    caches = pad_caches(caches, _STEPS)
    got, _ = _decode_steps(model, params, caches, toks, _PROMPT, _STEPS)

    for k in range(_STEPS):
        ref, _ = model.prefill_fn(
            params, dict(full, tokens=toks[:, :_PROMPT + k + 1]), _EC, True)
        g = np.asarray(got[k], np.float32)
        w = np.asarray(ref[:, -1], np.float32)
        close = np.isclose(g, w, atol=0.3, rtol=0.3)
        assert close.mean() > 0.995, \
            f"step {k}: {(~close).sum()}/{close.size} logits diverged"
        assert np.max(np.abs(g - w)) < 1.0, f"step {k}"


def test_prefill_logits_deterministic(family_setup):
    """Same params + prompt -> bitwise-identical prefill logits; the
    parity contracts above are meaningful only if the baseline itself is
    stable run to run."""
    cfg, model, params = family_setup
    batch = _prompt_batch(cfg, _B, _PROMPT)
    a, _ = model.prefill_fn(params, batch, _EC, True)
    b, _ = model.prefill_fn(params, batch, _EC, True)
    assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# 2. the int8 KV-cache path
# ---------------------------------------------------------------------------

# families whose self-attn caches have the quantized read/write path
# (must track serve_space.int8_kv_supported)
_INT8_ARCHS = ("granite-3-8b", "zamba2-2.7b", "internvl2-1b")


@pytest.mark.parametrize("arch", _INT8_ARCHS)
def test_int8_decode_tracks_bf16(arch):
    """Quantized KV decode: writes stay int8+scales, logits track the
    bf16 decode within the quantization error, greedy tokens unchanged."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    full = _prompt_batch(cfg, _B, _PROMPT + _STEPS, seed=3)
    toks = full["tokens"]
    pre = dict(full, tokens=toks[:, :_PROMPT])

    _, c16 = model.prefill_fn(params, pre, _EC, True)
    g16, _ = _decode_steps(model, params, pad_caches(c16, _STEPS),
                           toks, _PROMPT, _STEPS)

    _, craw = model.prefill_fn(params, pre, _EC, True)
    c8 = pad_caches(quantize_caches(craw), _STEPS)
    g8, c8_out = _decode_steps(model, params, c8, toks, _PROMPT, _STEPS)

    # decode preserved the quantized layout end to end: every
    # self-attn {k, v, pos} dict still holds int8 values + f32 scales
    quantized = []

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "pos" in node:
                quantized.append(node)
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
    walk(c8_out)
    assert quantized, "no self-attn caches found"
    for node in quantized:
        assert node["k"].dtype == jnp.int8 and node["v"].dtype == jnp.int8
        assert node["k_scale"].dtype == jnp.float32
        assert node["v_scale"].dtype == jnp.float32

    a16 = np.asarray(jnp.stack(g16, 1), np.float32)
    a8 = np.asarray(jnp.stack(g8, 1), np.float32)
    err = np.max(np.abs(a16 - a8))
    assert err < 0.5
    # greedy tokens: where the bf16 top-2 margin exceeds the measured
    # quantization error the argmax CANNOT move (at random init many
    # logits are near-uniform, so an unconditional argmax equality would
    # test tie-breaking, not the cache path)
    top2 = np.sort(a16, axis=-1)[..., -2:]
    decisive = (top2[..., 1] - top2[..., 0]) > 2.0 * err
    agree = a16.argmax(-1) == a8.argmax(-1)
    assert agree[decisive].all()
    assert decisive.any() or agree.mean() > 0.5


def test_quantize_caches_touches_only_self_attn():
    """MLA latents, SSM/mLSTM state, and pos-less cross-attn caches have
    no quantized path and must pass through quantize_caches unchanged."""
    for arch in ("minicpm3-4b", "xlstm-1.3b", "whisper-small"):
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        caches = model.init_caches(2, 16)
        out = quantize_caches(caches)
        before = jax.tree_util.tree_leaves_with_path(caches)
        after = jax.tree_util.tree_leaves_with_path(out)
        if arch == "whisper-small":
            # self KV {k, v, pos} quantizes; the cross cache {k, v} (no
            # pos — it is written once at prefill) must not
            keys_after = {jax.tree_util.keystr(p) for p, _ in after}
            assert any("k_scale" in k and "self" in k for k in keys_after)
            assert not any("scale" in k and "cross" in k
                           for k in keys_after)
            cross_b = [(p, l) for p, l in before
                       if "cross" in jax.tree_util.keystr(p)]
            cross_a = [(p, l) for p, l in after
                       if "cross" in jax.tree_util.keystr(p)]
            for (pb, lb), (pa, la) in zip(cross_b, cross_a):
                assert lb.dtype == la.dtype and lb.shape == la.shape
        else:
            assert len(before) == len(after)
            for (pb, lb), (pa, la) in zip(before, after):
                assert jax.tree_util.keystr(pb) == jax.tree_util.keystr(pa)
                assert lb.dtype == la.dtype


def test_int8_support_table_matches_cache_shape():
    """serve_space.int8_kv_supported says yes exactly when the arch's
    cache tree has the {k, v, pos} self-attn dicts quantize_caches (and
    the decode read path) handle."""
    from repro.configs.base import list_archs
    from repro.core.serve_space import int8_kv_supported

    def has_quantizable(caches):
        found = []

        def walk(node):
            if isinstance(node, dict):
                if "k" in node and "v" in node and "pos" in node:
                    found.append(True)
                    return
                for v in node.values():
                    walk(v)
            elif isinstance(node, (list, tuple)):
                for v in node:
                    walk(v)
        walk(caches)
        return bool(found)

    for arch in list_archs():
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        caches = jax.eval_shape(lambda m=model: m.init_caches(2, 16))
        if int8_kv_supported(cfg):
            assert has_quantizable(caches), arch
        # (the converse is intentionally weaker: whisper HAS a
        # quantizable self cache but is excluded because its cross cache
        # shares the decode path without a quantized read)


# ---------------------------------------------------------------------------
# 3. tuned plan == baseline plan, token for token
# ---------------------------------------------------------------------------


def test_tuned_serve_plan_generates_identical_tokens():
    """The acceptance criterion, end to end on a reduced golden arch:
    generate() under the serve tuner's winning plan emits exactly the
    token ids the hand-built dp-only baseline emits."""
    from repro import compat
    from repro.core.plan import single_stage_plan
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate, tuned_serve_plan

    cfg = get_arch("granite-3-8b").reduced()
    model = build_model(cfg)
    n = len(jax.devices())
    batch, plen, gen = 2, 8, 4

    plan, report = tuned_serve_plan(cfg, batch=batch, max_len=plen + gen,
                                    n_devices=n)
    assert report.plan is plan and not report.infeasible
    base = single_stage_plan(cfg.num_layers, dp=n, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0)

    toks = {}
    for name, p in (("base", base), ("tuned", plan)):
        st = p.stages[0]
        mesh = make_host_mesh(st.dp, st.tp)
        with compat.set_mesh(mesh):
            params, _ = model.init(jax.random.PRNGKey(0))
            prompts = jax.random.randint(jax.random.PRNGKey(1),
                                         (batch, plen), 0,
                                         cfg.vocab_size).astype(jnp.int32)
            toks[name] = np.asarray(generate(model, params, prompts, gen,
                                             mesh, p))
    assert toks["base"].shape == (batch, gen)
    assert (toks["base"] == toks["tuned"]).all(), \
        "tuned serve plan changed generated tokens"
