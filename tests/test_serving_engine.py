"""Continuous-batching serve engine guarantees
(docs/continuous-batching.md).

Three groups:

1. **Allocator invariants** — hypothesis traffic over
   ``PagedKvAllocator``: no page is ever owned by two live requests, a
   release (retire or preemption) returns every owned page,
   ``used + free == num_pages`` at every point, and ownership is exactly
   ``ceil(covered_rows / page_size)`` pages.
2. **Token identity** — the engine's per-request greedy tokens equal a
   batch-1 static decode (``generate()`` semantics) at the same global
   ``max_len``, for all six cache families (GQA / MLA / SSM / hybrid /
   enc-dec / VLM) and the int8 KV fallback: batching policy must never
   move numerics.
3. **Paged memory bitwise** — ``engine.memory_bytes()`` equals
   ``concrete_paged_cache_bytes`` at dp == tp == 1, the symbolic serve
   estimate equals ``LoweredPlan.memory_report()`` on paged serve
   shapes, and the engine's probe-based leaf classification agrees with
   the layout derivation's.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro import compat
from repro.configs.base import ShapeConfig, get_arch
from repro.core.plan import Plan, single_stage_plan
from repro.launch.mesh import make_host_mesh
from repro.lowering import lower_plan
from repro.lowering.cache_layout import (concrete_paged_cache_bytes,
                                         derive_cache_layout, is_paged_leaf)
from repro.models.zoo import build_model, pad_caches, quantize_caches
from repro.serving import (ContinuousBatchingEngine, ContinuousScheduler,
                           PagedKvAllocator, ServeRequest, pages_for)
from repro.serving.pages import classify_cache_tree
from repro.training.step import make_prefill_step, make_serve_step

# one arch per KV/state cache family (mirrors tests/test_serve_correctness)
FAMILY_ARCHS = {
    "gqa": "granite-3-8b",
    "mla": "minicpm3-4b",
    "ssm": "xlstm-1.3b",
    "hybrid": "zamba2-2.7b",
    "encdec": "whisper-small",
    "vlm": "internvl2-1b",
}

SLOTS, PAGE = 2, 8
PLENS, GENS = (6, 10), (6, 3)


# -- 1. allocator invariants ---------------------------------------------------


class TestAllocator:
    def test_lowest_id_first_and_release_returns_all(self):
        a = PagedKvAllocator(num_pages=6, page_size=4)
        assert a.admit("r0", rows=9) == [0, 1, 2]     # ceil(9/4)
        assert a.admit("r1", rows=1) == [3]
        assert a.used == 4 and a.free == 2
        assert a.extend("r0", rows=13) == [4]
        assert a.extend("r0", rows=13) == []          # already covered
        assert a.extend("r1", rows=24) is None        # 6 - 2 free < 5
        assert sorted(a.release("r0")) == [0, 1, 2, 4]
        assert a.used == 1 and a.free == 5
        assert a.highwater == 5

    def test_watermark_gates_admission_only(self):
        a = PagedKvAllocator(num_pages=4, page_size=2, watermark=2)
        assert a.can_admit(4)                          # leaves 2 free
        assert not a.can_admit(6)                      # would leave 1
        assert a.can_admit(6, ignore_watermark=True)
        a.admit("r0", rows=4)
        # extension may dip below the watermark freely
        assert a.extend("r0", rows=8) == [2, 3]
        with pytest.raises(RuntimeError):
            a.admit("r1", rows=1)

    def test_double_admit_rejected(self):
        a = PagedKvAllocator(num_pages=2, page_size=2)
        a.admit("r0", rows=1)
        with pytest.raises(ValueError):
            a.admit("r0", rows=1)

    if HAVE_HYPOTHESIS:
        @given(st.data())
        @settings(max_examples=60, deadline=None)
        def test_invariants_under_random_traffic(self, data):
            num_pages = data.draw(st.integers(1, 24), label="num_pages")
            page_size = data.draw(st.integers(1, 8), label="page_size")
            a = PagedKvAllocator(num_pages=num_pages, page_size=page_size)
            covered = {}                    # rid -> rows granted so far
            next_rid = 0
            max_rows = num_pages * page_size
            for _ in range(data.draw(st.integers(1, 40), label="steps")):
                ops = ["admit"] + (["extend", "release"] if covered else [])
                op = data.draw(st.sampled_from(ops), label="op")
                if op == "admit":
                    rows = data.draw(st.integers(1, max_rows), label="rows")
                    if a.can_admit(rows):
                        pages = a.admit(next_rid, rows)
                        assert len(pages) == pages_for(rows, page_size)
                        covered[next_rid] = rows
                        next_rid += 1
                    else:
                        with pytest.raises(RuntimeError):
                            a.admit(next_rid, rows)
                elif op == "extend":
                    rid = data.draw(st.sampled_from(sorted(covered)),
                                    label="rid")
                    rows = data.draw(st.integers(1, max_rows), label="rows")
                    need = (pages_for(rows, page_size)
                            - len(a.pages(rid)))
                    free_before = a.free
                    got = a.extend(rid, rows)
                    if got is None:                    # pool exhausted
                        assert need > free_before
                    else:
                        assert len(got) == max(0, need)
                        covered[rid] = max(covered[rid], rows)
                else:
                    rid = data.draw(st.sampled_from(sorted(covered)),
                                    label="rid")
                    freed = a.release(rid)
                    assert len(freed) == pages_for(covered.pop(rid),
                                                   page_size)
                # global invariants, every step
                assert a.used + a.free == num_pages
                owned = [p for rid in a.owners() for p in a.pages(rid)]
                assert len(owned) == len(set(owned))       # no aliasing
                assert len(owned) == a.used
                for rid in a.owners():
                    assert len(a.pages(rid)) == pages_for(covered[rid],
                                                          page_size)
            for rid in list(a.owners()):
                a.release(rid)
            assert a.free == num_pages                 # everything freed


# -- scheduler policy ----------------------------------------------------------


class TestScheduler:
    def test_preempt_youngest_requeues_at_head(self):
        alloc = PagedKvAllocator(num_pages=4, page_size=4)
        sched = ContinuousScheduler(slots=2, allocator=alloc)
        a, b = ServeRequest("a", {}, 8), ServeRequest("b", {}, 8)
        sched.submit(a)
        sched.submit(b)
        sa = sched.admit(a, rows=7)                    # 2 pages
        sb = sched.admit(b, rows=7)                    # 2 pages: pool full
        b.prefilled = ("tok", "caches", 7)
        sched.active[sa].pos = 8      # next step writes row 8: third page
        assert sched.ensure_coverage(sa) is None       # exhausted
        victim = sched.preempt_youngest()
        assert victim == sb
        assert sched.waiting[0] is b                   # requeued at HEAD
        assert b.prefilled is None                     # full replay
        assert alloc.free == 2
        assert sched.ensure_coverage(sa) == [2]        # now succeeds

    def test_retire_frees_slot_and_pages(self):
        alloc = PagedKvAllocator(num_pages=4, page_size=4)
        sched = ContinuousScheduler(slots=1, allocator=alloc)
        r = ServeRequest("r", {}, 2)
        sched.submit(r)
        slot = sched.admit(r, rows=3)
        assert not sched.can_try_admit()               # no free slot
        sched.retire(slot)
        assert alloc.free == 4 and not sched.active

    def test_peak_pages_covers_admission_and_tail(self):
        alloc = PagedKvAllocator(num_pages=8, page_size=4)
        sched = ContinuousScheduler(slots=1, allocator=alloc)
        assert sched.peak_pages(rows=3, max_new=1) == 1    # admit: rows+1
        assert sched.peak_pages(rows=3, max_new=14) == 4   # tail: rows+13


# -- 2 + 3. per-family token identity and the bitwise memory contract ----------


def _prompt_batch(fam, cfg, plen, seed):
    k = jax.random.PRNGKey(seed)
    b = {"tokens": jax.random.randint(k, (1, plen), 0,
                                      cfg.vocab_size).astype(jnp.int32)}
    if fam == "vlm":
        b["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 1),
            (1, cfg.num_patches, cfg.d_model)).astype(jnp.bfloat16)
    if fam == "encdec":
        b["frames"] = jax.random.normal(
            jax.random.fold_in(k, 1),
            (1, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    return b


def _static_ref(model, params, low, prompt, gen, max_len, kv8):
    """generate() semantics at batch 1: real prefill, padded contiguous
    cache at the engine's global max_len, greedy decode."""
    prefill = make_prefill_step(model, return_cache=True, lowered=low)
    logits, caches = prefill.fn(params, prompt)
    if kv8:
        caches = quantize_caches(caches)
    rows = None
    for path, leaf in jax.tree_util.tree_leaves_with_path(caches):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key == "pos":
            rows = int(np.asarray(leaf).reshape(-1)[0])
            break
    if rows is None:                    # pure-state families (SSM)
        rows = prompt["tokens"].shape[1]
    caches = pad_caches(caches, max_len - rows)
    serve = make_serve_step(model, batch=1, max_len=max_len, donate=False,
                            lowered=low)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(gen - 1):
        logits, caches = serve.fn(params, tok, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)


def _run_family(fam, arch, kv_dtype="bf16"):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    max_len = 64 if fam == "vlm" else 32
    plan = single_stage_plan(cfg.num_layers, dp=1, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0,
                             kv_cache_dtype=kv_dtype, page_size=PAGE)
    mesh = make_host_mesh(1, 1)
    low = lower_plan(cfg, None, plan, mesh)
    with compat.set_mesh(mesh):
        params, _ = model.init(jax.random.PRNGKey(0))
        kv8 = kv_dtype == "int8"
        eng = ContinuousBatchingEngine(model, params, plan, mesh,
                                       slots=SLOTS, max_len=max_len,
                                       page_size=PAGE, lowered=low)
        prompts = [_prompt_batch(fam, cfg, pl, 100 + i)
                   for i, pl in enumerate(PLENS)]
        for i, (p, g) in enumerate(zip(prompts, GENS)):
            eng.submit(p, g, rid=i)
        res = eng.run()
        for i, (p, g) in enumerate(zip(prompts, GENS)):
            ref = _static_ref(model, params, low, p, g, max_len, kv8)
            assert np.array_equal(res[i], ref), \
                f"{fam}: request {i} diverged: {res[i]} != {ref}"
        # the bitwise paged-memory contract, on the engine's REAL arrays
        want = int(concrete_paged_cache_bytes(cfg, SLOTS, max_len, PAGE,
                                              kv_dtype, dp_size=1,
                                              tp_size=1))
        assert eng.memory_bytes() == want


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
def test_paged_decode_token_identical(fam):
    """Every cache family: continuous/paged decode emits exactly the
    static path's tokens, and the engine's allocation matches the
    derived paged layout byte for byte."""
    _run_family(fam, FAMILY_ARCHS[fam])


def test_paged_decode_token_identical_int8():
    """The int8 KV fallback pages quantized k/v + f32 scales; identity
    holds against the int8 static path (same quantize, same pages)."""
    _run_family("gqa", FAMILY_ARCHS["gqa"], kv_dtype="int8")


@pytest.mark.parametrize("fam", sorted(FAMILY_ARCHS))
def test_classification_agrees_with_layout(fam):
    """The engine's probe-based leaf classification and the layout
    derivation's ``is_paged_leaf`` are the SAME predicate — otherwise
    the memory contract could pass by coincidence."""
    cfg = get_arch(FAMILY_ARCHS[fam]).reduced()
    model = build_model(cfg)
    max_len = 64 if fam == "vlm" else 32
    specs = classify_cache_tree(model.init_caches, SLOTS, max_len,
                                jnp.bfloat16)
    layout = derive_cache_layout(cfg, SLOTS, max_len, "bf16")
    assert [s.paged for s in specs] \
        == [is_paged_leaf(lf, max_len) for lf in layout.leaves]
    assert [s.key for s in specs] == [lf.key for lf in layout.leaves]


def test_paged_estimate_matches_memory_report():
    """Two-evaluation contract on paged serve shapes: the symbolic serve
    model prices plan.page_size > 0 with pool bytes that equal the
    lowered ``memory_report()`` bitwise."""
    import dataclasses
    from repro.core.costmodel import estimate_serve_plan
    cfg = get_arch("granite-3-8b").reduced()
    base = single_stage_plan(cfg.num_layers, dp=1, tp=1, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0)
    shape = ShapeConfig("serve", 32, 2, "decode")
    mesh = compat.abstract_mesh((1, 1), ("data", "model"))
    seen = set()
    for ps in (0, 8, 16):
        plan = dataclasses.replace(base, page_size=ps)
        rep = lower_plan(cfg, shape, plan, mesh).memory_report()
        est = estimate_serve_plan(cfg, shape, plan)
        assert est["mem_decode"] == rep.peak_bytes, (ps, est["mem_decode"],
                                                     rep.peak_bytes)
        seen.add(rep.peak_bytes)
    assert len(seen) == 3      # paging really moves the priced bytes


def test_page_size_plan_json_round_trip():
    """page_size survives Plan JSON; the 0 default is OMITTED so every
    pre-existing golden plan fixture stays byte-identical."""
    base = single_stage_plan(4, dp=1, tp=1, micro_batch=1, grad_accum=1)
    assert '"page_size"' not in base.to_json()
    paged = single_stage_plan(4, dp=1, tp=1, micro_batch=1, grad_accum=1,
                              page_size=16)
    doc = paged.to_json()
    assert '"page_size": 16' in doc
    assert Plan.from_json(doc).page_size == 16
    assert Plan.from_json(base.to_json()).page_size == 0


def test_tuner_page_grid_sweeps_and_defaults():
    """The serve tuner: no page_grid and page_grid=(0,) are byte-
    identical (golden stability); a real grid yields a plan whose
    page_size is drawn from it and priced consistently."""
    from repro.core.tuner import MistTuner, TuneSpec
    cfg = get_arch("granite-3-8b").reduced()
    kw = dict(arch=cfg, seq_len=64, global_batch=4, n_devices=1,
              space="serve")
    r_none = MistTuner(TuneSpec(**kw)).tune()
    r_zero = MistTuner(TuneSpec(**kw, page_grid=(0,))).tune()
    assert r_none.plan.to_json() == r_zero.plan.to_json()
    assert r_none.plan.page_size == 0
    r_grid = MistTuner(TuneSpec(**kw, page_grid=(0, 8, 16))).tune()
    assert r_grid.plan.page_size in (0, 8, 16)
    assert r_grid.n_swept >= r_none.n_swept      # grid multiplies the sweep
