"""The shared KV/state-cache layout: one derivation, two evaluation modes.

The serve-side twin of tests/test_state_layout.py.  Contracts pinned:

1. **Symbolic == concrete == oracle, bitwise.**  ``cache_bytes`` runs
   the same formula over Exprs (``SYMBOLIC_OPS``) and floats
   (``CONCRETE_OPS``); both must agree bit for bit with each other —
   and with ``stage_cache_bytes``, the independent walk over the
   PartitionSpec tables ``cache_specs`` actually emits — on randomized
   serve shapes (arch x batch x max_len x dp x tp x kv dtype).

2. **The key table mirrors the sharder.**  ``SEQ_CACHE_KEYS`` is a
   jax-free literal copy of ``sharding._SEQ_LEAF_SEQ_DIM``; drift in
   either is a silent cost-model/runtime split.

3. **The serve cost model == the lowered report, bitwise.**
   ``estimate_serve_plan``'s mem_decode/mem_prefill equal
   ``memory_report().peak_bytes`` of the matching lowering — the PR-5
   two-evaluation contract, extended to serve shapes — including the
   int8 KV path and the compiled-tape evaluation the tuner sweeps with.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro import compat
from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.core import symbolic as S
from repro.core.costmodel import ServeCostModel, estimate_serve_plan
from repro.core.plan import single_stage_plan
from repro.lowering.cache_layout import (SEQ_CACHE_KEYS, cache_bytes,
                                         concrete_cache_bytes,
                                         derive_cache_layout,
                                         symbolic_cache_bytes)
from repro.lowering.lower import lower_plan
from repro.lowering.memory import stage_cache_bytes
from repro.lowering.state_layout import CONCRETE_OPS

# every cache family in the zoo: GQA dense/moe, MLA latent, SSM state,
# hybrid mamba+attn, enc-dec cross-attn, vlm
_ARCHS = ("granite-3-8b", "qwen2-moe-a2.7b", "minicpm3-4b",
          "xlstm-1.3b", "zamba2-2.7b", "whisper-small", "internvl2-1b")


def _concrete_via_specs(arch, batch, max_len, dp, tp, kv):
    """The oracle: lower a real plan and walk the actual spec tables."""
    cfg = get_arch(arch).reduced()
    plan = single_stage_plan(cfg.num_layers, dp=dp, tp=tp, micro_batch=1,
                             grad_accum=1, zero=0, ckpt_layers=0,
                             kv_cache_dtype=kv)
    mesh = compat.abstract_mesh((dp, tp), ("data", "model"))
    low = lower_plan(cfg, None, plan, mesh)
    shape = ShapeConfig("serve", max_len, batch, "decode")
    return stage_cache_bytes(low, shape)


# ---------------------------------------------------------------------------
# 1. symbolic == concrete == oracle, bitwise
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        arch=st.sampled_from(_ARCHS),
        batch=st.sampled_from((1, 2, 3, 4, 8)),
        max_len=st.sampled_from((17, 32, 48, 64, 96)),
        dp=st.sampled_from((1, 2, 3, 4, 8)),
        tp=st.sampled_from((1, 2, 3, 4, 8)),
        kv=st.sampled_from(("bf16", "int8")),
    )
    def test_symbolic_matches_concrete_and_specs_bitwise(
            arch, batch, max_len, dp, tp, kv):
        """Random serve shapes: Expr evaluation, concrete-ops evaluation,
        and the raw spec-table walk agree bit for bit."""
        cfg = get_arch(arch).reduced()
        sym = symbolic_cache_bytes(cfg, batch, max_len, kv)
        got_sym = float(np.asarray(sym.evaluate(
            {"dp": float(dp), "tp": float(tp)}, {})))
        got_conc = concrete_cache_bytes(cfg, batch, max_len, kv,
                                        dp_size=dp, tp_size=tp)
        assert got_sym == got_conc, (arch, batch, max_len, dp, tp, kv)
        want = _concrete_via_specs(arch, batch, max_len, dp, tp, kv)
        assert got_conc == want, (arch, batch, max_len, dp, tp, kv)

else:                                                # pragma: no cover

    def test_property_tests_need_hypothesis():
        pytest.importorskip("hypothesis")


def test_seeded_sweep_bitwise():
    """Hypothesis-free randomized sweep (seeded) so the three-way
    bitwise contract is exercised even where hypothesis is absent."""
    import random
    rng = random.Random(0xcac4e)
    for _ in range(24):
        arch = rng.choice(_ARCHS)
        batch = rng.choice((1, 2, 3, 4, 8))
        max_len = rng.choice((17, 32, 48, 96))
        dp, tp = rng.choice((1, 2, 3, 4, 8)), rng.choice((1, 2, 3, 4, 8))
        kv = rng.choice(("bf16", "int8"))
        cfg = get_arch(arch).reduced()
        sym = symbolic_cache_bytes(cfg, batch, max_len, kv)
        got_sym = float(np.asarray(sym.evaluate(
            {"dp": float(dp), "tp": float(tp)}, {})))
        got_conc = concrete_cache_bytes(cfg, batch, max_len, kv,
                                        dp_size=dp, tp_size=tp)
        want = _concrete_via_specs(arch, batch, max_len, dp, tp, kv)
        assert got_sym == got_conc == want, \
            (arch, batch, max_len, dp, tp, kv)


def test_indivisible_batch_shards_kv_sequence():
    """batch=3 on dp=2: the batch dim cannot shard, so eligible KV
    leaves shard their sequence dim over dp instead (and state-cache
    leaves replicate) — both evaluations must track the cascade."""
    cfg = get_arch("granite-3-8b").reduced()
    got = concrete_cache_bytes(cfg, 3, 64, "bf16", dp_size=2, tp_size=1)
    want = _concrete_via_specs("granite-3-8b", 3, 64, 2, 1, "bf16")
    assert got == want
    # k/v DID shard on seq: strictly less than fully-replicated bytes
    repl = concrete_cache_bytes(cfg, 3, 64, "bf16", dp_size=1, tp_size=1)
    assert got < repl


def test_int8_halves_kv_and_adds_scales():
    """int8 caches: k/v at 1 byte plus f32 per-(token, head) scales —
    the layout records exactly what init_caches allocates."""
    cfg = get_arch("granite-3-8b").reduced()
    lay16 = derive_cache_layout(cfg, 2, 32, "bf16")
    lay8 = derive_cache_layout(cfg, 2, 32, "int8")
    keys8 = {l.key for l in lay8.leaves}
    assert {"k_scale", "v_scale"} <= keys8
    assert {l.key for l in lay16.leaves} | {"k_scale", "v_scale"} == keys8
    b16 = concrete_cache_bytes(cfg, 2, 32, "bf16", dp_size=1, tp_size=1)
    b8 = concrete_cache_bytes(cfg, 2, 32, "int8", dp_size=1, tp_size=1)
    assert b8 < b16    # scales cost less than the halved k/v saves


# ---------------------------------------------------------------------------
# 2. the key table mirrors the sharder
# ---------------------------------------------------------------------------


def test_seq_cache_keys_mirror_sharding_table():
    from repro.parallel.sharding import _SEQ_LEAF_SEQ_DIM
    assert set(SEQ_CACHE_KEYS) == set(_SEQ_LEAF_SEQ_DIM)


# ---------------------------------------------------------------------------
# 3. serve cost model == lowered memory report, bitwise
# ---------------------------------------------------------------------------

_SERVE_PLANS = [
    # (arch, dp, tp, zero, kv)
    ("granite-3-8b", 1, 1, 0, "bf16"),
    ("granite-3-8b", 4, 2, 0, "bf16"),
    ("granite-3-8b", 2, 4, 3, "int8"),
    ("qwen2-moe-a2.7b", 2, 2, 0, "bf16"),
    ("minicpm3-4b", 2, 1, 0, "bf16"),      # MLA latent cache
    ("zamba2-2.7b", 2, 2, 3, "bf16"),      # hybrid mamba+attn caches
    ("whisper-small", 2, 1, 0, "bf16"),    # enc-dec cross-attn caches
    ("xlstm-1.3b", 1, 2, 0, "bf16"),       # pure recurrent state
]


@pytest.mark.parametrize("arch,dp,tp,zero,kv", _SERVE_PLANS)
def test_estimate_serve_plan_matches_report_bitwise(arch, dp, tp, zero, kv):
    cfg = get_arch(arch).reduced()
    plan = single_stage_plan(cfg.num_layers, dp=dp, tp=tp, micro_batch=1,
                             grad_accum=1, zero=zero, ckpt_layers=0,
                             kv_cache_dtype=kv)
    mesh = compat.abstract_mesh((dp, tp), ("data", "model"))
    for kind, field in (("decode", "mem_decode"), ("prefill", "mem_prefill")):
        shape = ShapeConfig("serve", 48, 4, kind)
        est = estimate_serve_plan(cfg, shape, plan)
        rep = lower_plan(cfg, shape, plan, mesh).memory_report()
        assert est[field] == rep.peak_bytes, \
            (arch, kind, est[field], rep.peak_bytes)


def test_tape_matches_expr_evaluation():
    """The compiled tape the tuner sweeps with is bitwise-identical to
    recursive Expr evaluation, scalar and vectorized."""
    cfg = get_arch("granite-3-8b").reduced()
    scm = ServeCostModel(cfg, batch=4, max_len=48)
    envs = [dict(dp=1.0, tp=1.0, z1=0.0, z2=0.0, z3=0.0, kv8=0.0),
            dict(dp=2.0, tp=4.0, z1=1.0, z2=1.0, z3=1.0, kv8=1.0),
            dict(dp=8.0, tp=1.0, z1=0.0, z2=0.0, z3=0.0, kv8=1.0)]
    vec = {k: np.asarray([e[k] for e in envs]) for k in envs[0]}
    got = scm.evaluate(vec)
    for i, e in enumerate(envs):
        memo = {}
        full = dict(e, wo=0.0, oo=0.0, L=float(cfg.num_layers))
        for name, expr in scm.exprs.items():
            want = float(np.asarray(expr.evaluate(full, memo)))
            assert float(got[name][i]) == want, (name, e)


def test_estimate_serve_plan_rejects_pipeline():
    from repro.core.plan import Plan, StageConfig
    cfg = get_arch("granite-3-8b").reduced()
    st0 = StageConfig(layers=cfg.num_layers // 2, micro_batch=1, dp=1,
                      tp=1, zero=0, ckpt_layers=0)
    plan = Plan(grad_accum=1, stages=(st0, st0))
    with pytest.raises(ValueError, match="single-stage"):
        estimate_serve_plan(cfg, ShapeConfig("serve", 48, 4, "decode"),
                            plan)


@pytest.mark.parametrize("arch", list_archs())
def test_every_zoo_arch_derives_a_layout(arch):
    """Every family abstract-allocates; every leaf records a real shape
    and the batch dim the sharder would find."""
    cfg = get_arch(arch).reduced()
    lay = derive_cache_layout(cfg, 2, 32, "bf16")
    assert lay.leaves
    for leaf in lay.leaves:
        assert leaf.itemsize > 0
        if leaf.bdim is not None:
            assert leaf.shape[leaf.bdim] == 2
    # the derivation is cached: same key, same object
    assert derive_cache_layout(cfg, 2, 32, "bf16") is lay
