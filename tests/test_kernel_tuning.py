"""Kernel-config plan dimension: byte-identity at the frozen default,
bitwise symbolic/concrete roofline agreement, legal-grid invariants,
and the tuned path end to end (docs/kernel-tuning.md)."""
import json

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import golden
from repro.core import symbolic as S
from repro.core.costmodel_params import (KERNEL_CONCRETE_OPS,
                                         KERNEL_SYMBOLIC_OPS, KernelCoeffs,
                                         kernel_time_terms,
                                         kernel_vmem_terms)
from repro.core.plan import DEFAULT_KERNEL_CONFIG, KernelConfig, Plan
from repro.core.schedule import DEFAULT_KERNEL_GRID
from repro.core.tuner import MistTuner, TuneSpec

CONFIGS = [
    DEFAULT_KERNEL_CONFIG,
    KernelConfig(1024, 1024, 512, 256),
    KernelConfig(128, 256, 128, 64),
    KernelConfig(256, 512, 512, 512),
]


def _spec(arch, **kw):
    return TuneSpec(arch=arch, seq_len=2048, global_batch=16, n_devices=8,
                    stage_counts=(1,), grad_accums=(2,), **kw)


# -- (a) frozen-default byte-identity ----------------------------------------


def test_frozen_default_matches_golden_fixture():
    """With the kernel dimension frozen to the default tuple (the default
    TuneSpec), a golden cell reproduces its committed fixture — the
    kernel machinery is byte-invisible until actually swept."""
    space, arch = "megatron", "granite-3-8b"
    path = golden.golden_path(space, arch)
    if not path.exists():
        pytest.skip("golden fixtures not generated")
    want = json.loads(path.read_text())
    doc = golden.compute_doc(space, arch)
    assert golden.fingerprint(doc) == want["fingerprint"], \
        golden.diff_docs(want["doc"], doc)


def test_explicit_default_grid_is_identical():
    """Passing kernel_grid=DEFAULT_KERNEL_GRID explicitly is the same
    sweep as not mentioning kernels at all."""
    arch = get_arch("granite-3-8b").reduced()
    r0 = MistTuner(_spec(arch)).tune()
    r1 = MistTuner(_spec(arch, kernel_grid=DEFAULT_KERNEL_GRID)).tune()
    assert r0.objective == r1.objective
    assert r0.plan.to_json() == r1.plan.to_json()


def test_default_kernel_omitted_from_plan_json():
    arch = get_arch("granite-3-8b").reduced()
    rep = MistTuner(_spec(arch)).tune()
    assert rep.plan.kernel == DEFAULT_KERNEL_CONFIG
    assert '"kernel"' not in rep.plan.to_json()
    assert Plan.from_json(rep.plan.to_json()) == rep.plan


def test_nondefault_kernel_roundtrips():
    arch = get_arch("granite-3-8b").reduced()
    rep = MistTuner(_spec(arch)).tune()
    tuned = rep.plan.replace(kernel=KernelConfig(1024, 512, 128, 256))
    assert '"kernel"' in tuned.to_json()
    assert Plan.from_json(tuned.to_json()) == tuned


# -- (b) symbolic == concrete roofline, bitwise ------------------------------


@pytest.mark.parametrize("config", CONFIGS, ids=[str(c.astuple())
                                                 for c in CONFIGS])
def test_time_terms_symbolic_matches_concrete(config):
    """The ONE shared formula evaluated over Exprs (what the tapes
    compile) and over floats (what the bench predictor uses) agrees
    BITWISE — same arithmetic in the same order (the state_layout
    idiom)."""
    kc = KernelCoeffs()
    kw = dict(seq=2048, b=4.0, tp=2.0, sp_div=2.0, num_heads=32,
              head_dim=128, d_model=4096, ssd_heads=64, ssd_head_dim=64,
              ssd_state=128, hbm_bw=819e9, peak_flops=197e12, kc=kc)
    qb, kvb, rnb, sch = (float(v) for v in config.astuple())
    sym = kernel_time_terms(qb=S.Sym("qb"), kvb=S.Sym("kvb"),
                            rnb=S.Sym("rnb"), sch=S.Sym("sch"),
                            ops=KERNEL_SYMBOLIC_OPS, **kw)
    con = kernel_time_terms(qb=qb, kvb=kvb, rnb=rnb, sch=sch,
                            ops=KERNEL_CONCRETE_OPS, **kw)
    env = {"qb": qb, "kvb": kvb, "rnb": rnb, "sch": sch}
    for op in ("attn", "rms", "ssd"):
        got = float(S.wrap(sym[op]).evaluate(env, {}))
        assert got == con[op], (op, got, con[op])


@pytest.mark.parametrize("config", CONFIGS, ids=[str(c.astuple())
                                                 for c in CONFIGS])
def test_vmem_terms_symbolic_matches_concrete(config):
    kw = dict(head_dim=128, d_model=4096, ssd_head_dim=64, ssd_state=128)
    qb, kvb, rnb, sch = (float(v) for v in config.astuple())
    sym = kernel_vmem_terms(qb=S.Sym("qb"), kvb=S.Sym("kvb"),
                            rnb=S.Sym("rnb"), sch=S.Sym("sch"),
                            ops=KERNEL_SYMBOLIC_OPS, **kw)
    con = kernel_vmem_terms(qb=qb, kvb=kvb, rnb=rnb, sch=sch,
                            ops=KERNEL_CONCRETE_OPS, **kw)
    env = {"qb": qb, "kvb": kvb, "rnb": rnb, "sch": sch}
    for op in ("attn", "rms", "ssd"):
        got = float(S.wrap(sym[op]).evaluate(env, {}))
        assert got == con[op], (op, got, con[op])


def test_delta_term_is_exactly_zero_at_default():
    """The cost model prices kernels as roofline(config) -
    roofline(default); at the default binding the delta is EXACTLY 0.0
    (not just small), which is what keeps every golden plan bitwise
    stable."""
    from repro.core.costmodel import StageCostModel
    arch = get_arch("granite-3-8b").reduced()
    scm = StageCostModel(arch, 2048)
    env = {k: float(v) for k, v in
           zip(("qb", "kvb", "rnb", "sch"), DEFAULT_KERNEL_CONFIG.astuple())}
    env.update(b=2.0, dp=2.0, tp=2.0, zero=1.0, ckpt=float(arch.num_layers),
               wo=0.0, go=0.0, oo=0.0, ao=0.0, L=float(arch.num_layers),
               inflight=1.0, G=2.0)
    val = scm.kernel_time_delta.evaluate(scm._env(env), {})
    assert float(np.asarray(val)) == 0.0


# -- legal grid --------------------------------------------------------------


def test_legal_grid_invariants():
    from repro.kernels.autotune import legal_kernel_grid, predict_vmem
    arch = get_arch("granite-3-8b")
    seq = 2048
    grid = legal_kernel_grid(arch, seq_len=seq, max_tuples=8)
    assert grid[0] == DEFAULT_KERNEL_CONFIG.astuple()
    assert len(grid) <= 8 and len(set(grid)) == len(grid)
    from repro.core.hardware import V5E
    vdef = predict_vmem(arch, DEFAULT_KERNEL_CONFIG)
    for qb, kvb, rnb, sch in grid:
        for v in (qb, kvb, rnb, sch):
            assert v >= 8 and (v & (v - 1)) == 0, grid
        assert seq % qb == 0 and seq % kvb == 0
        v = predict_vmem(arch, KernelConfig(qb, kvb, rnb, sch))
        for op in ("attn", "rms", "ssd"):
            assert v[op] <= max(V5E.vmem_bytes, vdef[op])


def test_plan_validation_rejects_bad_kernel_blocks():
    from repro.core.schedule import validate_plan
    arch = get_arch("granite-3-8b").reduced()
    plan = MistTuner(_spec(arch)).tune().plan
    bad = plan.replace(kernel=KernelConfig(attn_q_block=96))
    assert any("attn_q_block" in p for p in validate_plan(bad, arch, 8, 16))
    assert not any("kernel" in p or "block" in p
                   for p in validate_plan(plan, arch, 8, 16))


# -- tuned path end to end ---------------------------------------------------


def test_kernel_sweep_improves_and_verifies():
    """Sweeping the kernel dimension can only improve the objective (the
    default tuple rides in the grid), and whatever the tuner selects
    must instantiate through the real Pallas kernels."""
    from repro.kernels.autotune import verify_config
    arch = get_arch("granite-3-8b").reduced()
    base = MistTuner(_spec(arch)).tune()
    tuned = MistTuner(_spec(arch, kernel_tune=True)).tune()
    assert tuned.objective <= base.objective
    assert verify_config(arch, seq_len=512, config=tuned.plan.kernel)


def test_kernel_sweep_worker_identity():
    """The kernel grid rides inside TuneSpec, so forked sweep workers
    recompute the identical grid and the merged memo selects the same
    plan as the serial engine."""
    arch = get_arch("granite-3-8b").reduced()
    grid = ((512, 512, 256, 256), (1024, 1024, 512, 256))
    r1 = MistTuner(_spec(arch, kernel_grid=grid)).tune()
    r2 = MistTuner(_spec(arch, kernel_grid=grid, workers=2)).tune()
    assert r1.objective == r2.objective
    assert r1.plan.to_json() == r2.plan.to_json()


def test_tuned_plan_lowers_with_kernel_exec_config():
    """plan.kernel threads through lower_plan into every stage's
    ExecConfig (and the serve config)."""
    from repro import compat
    from repro.lowering.lower import lower_plan
    arch = get_arch("granite-3-8b").reduced()
    plan = MistTuner(_spec(arch)).tune().plan.replace(
        kernel=KernelConfig(1024, 512, 128, 256), attn_impl="pallas",
        use_pallas=True)
    st = plan.stages[0]
    mesh = compat.abstract_mesh((st.dp, st.tp), ("data", "model"))
    low = lower_plan(arch, None, plan, mesh)
    ec = low.stages[0].exec_cfg
    assert (ec.attn_q_block, ec.attn_kv_block, ec.rmsnorm_block,
            ec.ssd_chunk) == (1024, 512, 128, 256)
    assert low.serve_exec_cfg.attn_q_block == 1024
    assert low.plan_exec_cfg.rmsnorm_block == 128


def test_calibration_keeps_frozen_default_plan():
    """Calibrated roofline scales reshape the sweep but cancel in the
    delta at the default config — frozen-default plans are invariant."""
    from repro.core.costmodel import CostParams
    arch = get_arch("granite-3-8b").reduced()
    base = MistTuner(_spec(arch)).tune()
    cp = CostParams(kernels=KernelCoeffs(attn_scale=3.7, rms_scale=0.2,
                                         ssd_scale=11.0))
    scaled = MistTuner(_spec(arch), cp=cp).tune()
    assert base.objective == scaled.objective
    assert base.plan.to_json() == scaled.plan.to_json()
