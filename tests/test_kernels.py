"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles in
ref.py, including Pallas (interpret=True) and the blocked custom-VJP
backward vs autodiff of the naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}
RTOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _qkv(bh, sq, sk, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (bh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (bh, sk, d), jnp.float32).astype(dtype)
    return q, k, v


# -- flash attention: blocked jnp path ----------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,sq,sk,d,block", [
    (2, 64, 64, 32, 16),
    (1, 128, 128, 64, 64),
    (3, 32, 128, 16, 32),     # cross-attn style (sq != sk, non-causal only)
    (2, 256, 256, 128, 128),
])
def test_blocked_fwd_matches_naive(dtype, bh, sq, sk, d, block):
    q, k, v = _qkv(bh, sq, sk, d, dtype)
    causal = sq == sk
    want = ref.naive_attention(q, k, v, causal=causal)
    got, _ = ops._blocked_fwd(q, k, v, causal, 1.0 / np.sqrt(d), block)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=RTOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blocked_bwd_matches_naive_grad(dtype):
    bh, s, d, block = 2, 64, 32, 16
    q, k, v = _qkv(bh, s, s, d, dtype)

    def f_ref(q, k, v):
        return (ref.naive_attention(q, k, v, causal=True)
                .astype(jnp.float32).sum())

    def f_blk(q, k, v):
        return ops._flash(q, k, v, True, 1.0 / np.sqrt(d), block, block,
                          False).astype(jnp.float32).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_blk = jax.grad(f_blk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_blk):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16
                                   else 1e-3, rtol=5e-2)


# -- flash attention: Pallas kernel (interpret mode) ---------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,d,qb,kb", [
    (2, 128, 64, 64, 64),
    (1, 256, 128, 128, 64),
    (2, 64, 32, 32, 32),
])
def test_pallas_flash_matches_naive(dtype, bh, s, d, qb, kb):
    from repro.kernels.flash_attention import flash_attention_fwd
    q, k, v = _qkv(bh, s, s, d, dtype)
    want = ref.naive_attention(q, k, v, causal=True)
    got = flash_attention_fwd(q, k, v, causal=True, q_block=qb, kv_block=kb,
                              interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=RTOL[dtype])


@pytest.mark.parametrize("qb,kb", [(32, 32), (32, 64), (64, 32), (128, 128),
                                   (128, 64), (64, 128)])
def test_pallas_flash_block_sweep(qb, kb):
    """The kernel-config dimension: every (q_block, kv_block) tile pair the
    tuner can emit must produce identical attention output."""
    from repro.kernels.flash_attention import flash_attention_fwd
    q, k, v = _qkv(2, 128, 128, 64, jnp.float32)
    want = ref.naive_attention(q, k, v, causal=True)
    got = flash_attention_fwd(q, k, v, causal=True, q_block=qb, kv_block=kb,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("qb,kb", [(32, 64), (64, 32), (128, 128)])
def test_attention_block_sweep_fwd_bwd(qb, kb):
    """fwd AND bwd through the dispatch wrapper at asymmetric tile pairs:
    the gradient must match autodiff of the naive reference regardless of
    the tuned tiling (tiles change the schedule, never the math)."""
    b, s, h, hd = 2, 128, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)

    def f(impl, q_block=None, kv_block=None):
        def g(q, k, v):
            return ops.attention(q, k, v, impl=impl, q_block=q_block,
                                 kv_block=kv_block) \
                .astype(jnp.float32).sum()
        return g

    got = ops.attention(q, k, v, impl="pallas", q_block=qb, kv_block=kb)
    want = ops.attention(q, k, v, impl="naive")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)
    g_ref = jax.grad(f("naive"), argnums=(0, 1, 2))(q, k, v)
    g_tile = jax.grad(f("pallas", qb, kb), argnums=(0, 1, 2))(q, k, v)
    for a, bb_ in zip(g_ref, g_tile):
        np.testing.assert_allclose(np.asarray(bb_), np.asarray(a),
                                   atol=1e-3, rtol=1e-3)


def test_pallas_flash_noncausal():
    from repro.kernels.flash_attention import flash_attention_fwd
    q, k, v = _qkv(2, 128, 128, 64, jnp.float32)
    want = ref.naive_attention(q, k, v, causal=False)
    got = flash_attention_fwd(q, k, v, causal=False, q_block=64, kv_block=64,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# -- GQA wrapper ----------------------------------------------------------------


@pytest.mark.parametrize("impl", ["naive", "blocked", "pallas"])
@pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (4, 1)])
def test_attention_gqa_wrapper(impl, h, kv):
    b, s, hd = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    got = ops.attention(q, k, v, impl=impl, block=32)
    # reference: expand kv heads then run naive per head
    g = h // kv
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    want = jnp.stack([
        ref.naive_attention(q[:, :, i].reshape(b, s, hd).reshape(b, s, hd),
                            kx[:, :, i], vx[:, :, i], causal=True)
        for i in range(h)], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


# -- RMSNorm ---------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 64, 256), (1, 8, 512)])
def test_rmsnorm_pallas_matches_ref(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) \
        .astype(dtype)
    scale = jax.random.normal(jax.random.PRNGKey(1), shape[-1:],
                              jnp.float32).astype(dtype)
    want = ref.rmsnorm_ref(x, scale)
    got = ops.rmsnorm(x, scale, impl="pallas")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=RTOL[dtype])


@pytest.mark.parametrize("block", [32, 64, 128])
def test_rmsnorm_block_sweep(block):
    """The rmsnorm row-block is a tuned knob; output is block-invariant."""
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 128), jnp.float32)
    scale = jax.random.normal(jax.random.PRNGKey(5), (128,), jnp.float32)
    want = ref.rmsnorm_ref(x, scale)
    got = ops.rmsnorm(x, scale, impl="pallas", block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# -- Mamba2 SSD chunk scan ---------------------------------------------------------


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
])
def test_ssd_scan_matches_sequential_ref(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32))
    bb = jax.random.normal(ks[3], (b, s, h, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, s, h, n), jnp.float32)
    want = ref.ssd_ref(xh, dt, a, bb, cc)
    got, _ = ops.ssd_scan(xh, dt, a, bb, cc, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-4)


def test_ssd_scan_bf16():
    b, s, h, p, n = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32))
    bb = jax.random.normal(ks[3], (b, s, h, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, s, h, n), jnp.float32)
    want = ref.ssd_ref(xh, dt, a, bb, cc)
    got, _ = ops.ssd_scan(xh.astype(jnp.bfloat16), dt, a,
                          bb.astype(jnp.bfloat16), cc.astype(jnp.bfloat16),
                          chunk=16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=0.05, rtol=0.05)


# -- int8 KV cache -------------------------------------------------------------


def test_int8_kv_decode_close_to_bf16():
    """Quantized KV decode must track the bf16 decode closely."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_arch
    from repro.models import layers as L
    from repro.models.common import ExecConfig, ParamBuilder

    cfg = get_arch("granite-3-8b").reduced()
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    L.init_attention(pb.scope("a"), cfg)
    p = {k.split("/", 1)[1]: v for k, v in pb.params.items()}
    ec = ExecConfig()
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5

    def run(dtype):
        cache = L.init_self_kv_cache(cfg, B, S, dtype)
        outs = []
        for i in range(S):
            o, cache = L.attention(p, x[:, i:i + 1], cfg, ec, cache=cache)
            outs.append(o)
        return jnp.concatenate(outs, axis=1)

    ref_out = run(jnp.bfloat16)
    q_out = run(jnp.int8)
    err = float(jnp.max(jnp.abs(q_out.astype(jnp.float32)
                                - ref_out.astype(jnp.float32))))
    assert err < 0.1, err


def test_quantize_kv_roundtrip():
    import jax
    import jax.numpy as jnp
    from repro.models.layers import dequantize_kv, quantize_kv

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 64),
                          jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = dequantize_kv(q, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(np.max(np.abs(np.asarray(x))))
                               / 127 * 0.51 + 1e-6)
