"""End-to-end system behaviour: tune -> plan -> (reduced) execution, plus the
roofline/report plumbing and gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import ShapeConfig, get_arch
from repro.core.plan import single_stage_plan
from repro.core.schedule import validate_plan
from repro.core.tuner import tune


def test_tune_then_execute_reduced():
    """The tuner's plan (topology scaled down) must actually run: tune for
    16 devices, execute the same knobs (zero/ckpt semantics) on 1 device
    with the reduced config."""
    cfg = get_arch("granite-3-8b")
    shape = ShapeConfig("t", 4096, 32, "train")
    rep = tune(cfg, shape, 16, space="mist", stage_counts=(1,),
               grad_accums=(4,))
    assert rep.plan is not None
    assert validate_plan(rep.plan, cfg, 16, 32) == []

    rcfg = cfg.reduced()
    from repro.models.zoo import build_model
    from repro.training.step import make_train_step, init_sharded_state
    from repro.launch.mesh import make_host_mesh
    model = build_model(rcfg)
    tuned = rep.plan.stages[0]
    plan = single_stage_plan(
        rcfg.num_layers, dp=1, tp=1, micro_batch=2, grad_accum=2,
        zero=tuned.zero,
        ckpt_layers=min(tuned.ckpt_layers, rcfg.num_layers),
        oo=tuned.oo, ao=tuned.ao)
    mesh = make_host_mesh(1, 1)
    with compat.set_mesh(mesh):
        step = make_train_step(model, plan, mesh, donate=False)
        state, _ = init_sharded_state(model, plan, mesh,
                                      jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (4, 64), 0,
                                              rcfg.vocab_size),
                 "labels": jax.random.randint(key, (4, 64), 0,
                                              rcfg.vocab_size)}
        state, m = step.fn(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_roofline_report_terms():
    from repro.core.hardware import V5E
    from repro.perf.hloanalysis import HLOStats
    from repro.perf.roofline import report_from_stats
    st = HLOStats(dot_flops=1e15, hbm_bytes=1e12,
                  collective_wire_bytes=1e11,
                  collective_by_kind={"all-reduce": 1e11})
    rep = report_from_stats(st, arch="a", shape="s", mesh="16x16",
                            chips=256, model_flops_global=2e17)
    assert rep.t_compute == pytest.approx(1e15 / V5E.peak_flops_bf16)
    assert rep.t_memory == pytest.approx(1e12 / V5E.hbm_bw)
    assert rep.t_collective == pytest.approx(1e11 / V5E.ici_bw_total)
    assert rep.bottleneck == "compute"
    assert 0 < rep.roofline_fraction <= 1.0
    assert rep.useful_ratio == pytest.approx(2e17 / (256 * 1e15))


def test_model_flops_for_kinds():
    from repro.perf.roofline import model_flops_for
    cfg = get_arch("granite-3-8b")
    n = cfg.param_count(active_only=True)
    tr = model_flops_for(cfg, ShapeConfig("t", 4096, 256, "train"))
    pf = model_flops_for(cfg, ShapeConfig("p", 4096, 256, "prefill"))
    dc = model_flops_for(cfg, ShapeConfig("d", 4096, 256, "decode"))
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(tr / 3)
    assert dc == pytest.approx(2 * n * 256)


def test_moe_uses_active_params():
    from repro.perf.roofline import model_flops_for
    cfg = get_arch("dbrx-132b")
    t = model_flops_for(cfg, ShapeConfig("t", 4096, 8, "train"))
    n_act = cfg.param_count(active_only=True)
    n_tot = cfg.param_count()
    assert t == pytest.approx(6 * n_act * 8 * 4096)
    assert n_act < 0.5 * n_tot


def test_gradient_compression_roundtrip():
    from repro.parallel.compression import (compress_with_feedback,
                                            dequantize_int8, quantize_int8)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    q, s = quantize_int8(g["w"])
    assert q.dtype == jnp.int8
    deq = dequantize_int8(q, s)
    err = float(jnp.max(jnp.abs(deq - g["w"])))
    assert err <= float(s) * 0.51 + 1e-6            # half-ulp bound

    res = {"w": jnp.zeros_like(g["w"])}
    out1, res1 = compress_with_feedback(g, res)
    # error feedback: residual carries the quantization error
    np.testing.assert_allclose(np.asarray(out1["w"] + res1["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_interference_channels_in_schedule():
    """Every cost item referenced by the overlap schedule exists in the
    cost model."""
    from repro.core.costmodel import StageCostModel
    from repro.core.schedule import OVERLAP_SCHEDULE
    scm = StageCostModel(get_arch("granite-3-8b"), 1024)
    for ph in OVERLAP_SCHEDULE:
        for item in ph.compute + ph.g2g + ph.d2h + ph.h2d:
            assert item in scm.items, item
