"""MistTuner end-to-end: search-space inclusion, plan legality, breakdown."""
import pytest

from repro.configs.base import ShapeConfig, get_arch
from repro.core.schedule import validate_plan
from repro.core.tuner import MistTuner, TuneSpec, tune

SHAPE = ShapeConfig("t", 4096, 32, "train")


@pytest.fixture(scope="module")
def reports():
    cfg = get_arch("granite-3-8b")
    out = {}
    for space in ("megatron", "ckpt", "zero", "offload", "mist"):
        out[space] = tune(cfg, SHAPE, 16, space=space, stage_counts=(1, 2),
                          grad_accums=(2, 4, 8))
    return out


def test_all_spaces_feasible_on_8b_16dev(reports):
    for space, rep in reports.items():
        assert rep.plan is not None, f"{space} infeasible"


def test_space_inclusion_monotonicity(reports):
    """Larger search spaces can only improve the (modeled) objective:
    megatron ⊂ ckpt ⊂ mist and megatron ⊂ zero ⊂ mist (paper Fig. 13)."""
    eps = 1e-9
    assert reports["ckpt"].objective <= reports["megatron"].objective + eps
    assert reports["zero"].objective <= reports["megatron"].objective + eps
    assert reports["offload"].objective <= reports["ckpt"].objective + eps
    assert reports["mist"].objective <= reports["ckpt"].objective + eps
    assert reports["mist"].objective <= reports["zero"].objective + eps
    assert reports["mist"].objective <= reports["offload"].objective + eps


def test_plans_validate(reports):
    cfg = get_arch("granite-3-8b")
    for space, rep in reports.items():
        errs = validate_plan(rep.plan, cfg, 16, SHAPE.global_batch)
        assert not errs, f"{space}: {errs}"


def test_megatron_space_is_full_ckpt(reports):
    plan = reports["megatron"].plan
    for st in plan.stages:
        assert st.ckpt_layers >= st.layers
        assert st.zero == 1
        assert st.oo == st.ao == st.wo == st.go == 0.0


def test_tuner_reports_counts(reports):
    rep = reports["mist"]
    assert rep.n_points > 1000
    assert rep.tune_seconds < 300
    assert rep.best_S in (1, 2)


def test_imbalance_awareness_not_worse():
    cfg = get_arch("granite-3-8b")
    aware = tune(cfg, SHAPE, 16, space="mist", stage_counts=(2,),
                 grad_accums=(4,))
    blind = tune(cfg, SHAPE, 16, space="mist", stage_counts=(2,),
                 grad_accums=(4,), imbalance_aware=False)
    assert aware.plan is not None and blind.plan is not None
    # evaluate BOTH chosen plans under the imbalance-aware objective
    from repro.core.costmodel import estimate_plan
    t_aware = estimate_plan(cfg, SHAPE, aware.plan)["t_step"]
    t_blind = estimate_plan(cfg, SHAPE, blind.plan)["t_step"]
    assert t_aware <= t_blind * 1.05


def test_uniform_heuristic_not_better_than_mist():
    cfg = get_arch("granite-3-8b")
    uni = tune(cfg, SHAPE, 16, space="uniform", stage_counts=(2,),
               grad_accums=(4,))
    mist = tune(cfg, SHAPE, 16, space="mist", stage_counts=(2,),
                grad_accums=(4,))
    if uni.plan is not None and mist.plan is not None:
        assert mist.objective <= uni.objective + 1e-9


def test_infeasible_when_tiny_devices():
    """72B on 2 chips with 16 GiB cannot fit even with everything on."""
    cfg = get_arch("qwen2-72b")
    rep = tune(cfg, ShapeConfig("t", 4096, 8, "train"), 2,
               space="mist", stage_counts=(1, 2), grad_accums=(1, 2, 4))
    assert rep.infeasible or rep.plan is None or rep.objective > 0
