"""Distributed sweep fan-out + persistent memo store guarantees
(docs/distributed-sweep.md): the RPC transport, byte-identical plans
across serial / local-pool / multi-host execution, graceful degradation
on unreachable hosts, and the content-addressed memo store's round-trip
and invalidation semantics."""
import dataclasses
import pickle
import socket

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro.calibration.profile import CalibrationProfile
from repro.configs.base import ShapeConfig, get_arch
from repro.core import memo_store, remote
from repro.core.memo_store import (MemoStore, report_key, tuner_fingerprint,
                                   unit_key)
from repro.core.remote import (RemoteError, RpcServer, host_assignments,
                               parse_addr, recv_frame, request, send_frame)
from repro.core.sweep import _shard_units, _sweep_units, plan_units, \
    prefetch_frontiers
from repro.core.tuner import MistTuner, TuneSpec, _space_knobs, tune
from repro.service.tune_service import TuneService, tune_remote
from repro.service.worker import SweepWorker

ARCH = "granite-3-8b"
SHAPE = ShapeConfig("t", 4096, 32, "train")
SMALL = dict(stage_counts=(1, 2), grad_accums=(2, 4))
TINY = dict(stage_counts=(1, 2), grad_accums=(2,), layer_window=1)


def _spec(space="mist", small=SMALL, **kw):
    cfg = get_arch(ARCH)
    return TuneSpec(arch=cfg, seq_len=SHAPE.seq_len,
                    global_batch=SHAPE.global_batch, n_devices=16,
                    space=space, **{**small, **kw})


def _report_key(rep):
    return (rep.objective, rep.plan, rep.best_S, rep.best_G,
            tuple(rep.per_sg), rep.n_milp)


def _memo_snapshot(tuner):
    return {k: [(p.t, p.d, p.mem, p.cand) for p in r.frontier]
            for k, r in tuner._frontier_memo.items()}


@pytest.fixture
def fast_fail(monkeypatch):
    """Unreachable hosts fail in milliseconds instead of the production
    connect timeout."""
    monkeypatch.setattr(remote, "CONNECT_TIMEOUT", 0.2)
    monkeypatch.setattr(remote, "RETRIES", 0)
    monkeypatch.setattr(remote, "RETRY_BACKOFF_S", 0.0)


@pytest.fixture
def workers():
    """Two in-thread sweep daemons, torn down after the test."""
    ws = [SweepWorker() for _ in range(2)]
    for w in ws:
        w.start_in_thread()
    yield ws
    for w in ws:
        w.shutdown()


# -- transport ----------------------------------------------------------------


class TestTransport:
    def test_frame_round_trip(self):
        a, b = socket.socketpair()
        try:
            payload = ("sweep", b"x" * 100_000, {"k": (1, 2.5)})
            send_frame(a, payload)
            assert recv_frame(b) == payload
        finally:
            a.close()
            b.close()

    def test_frame_rejects_bad_magic(self):
        a, b = socket.socketpair()
        try:
            a.sendall(b"BAD!" + (8).to_bytes(8, "big"))
            with pytest.raises(ConnectionError, match="magic"):
                recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_parse_addr(self):
        assert parse_addr("10.0.0.1:7421") == ("10.0.0.1", 7421)
        assert parse_addr(":7421") == ("127.0.0.1", 7421)
        with pytest.raises(ValueError):
            parse_addr("nohost")

    def test_request_round_trip_and_error_propagation(self):
        def boom():
            raise ValueError("sentinel-message")
        srv = RpcServer({"echo": lambda x: x, "boom": boom})
        srv.start_in_thread()
        try:
            assert request(srv.addr, "echo", {"a": (1, 2)}) == {"a": (1, 2)}
            assert request(srv.addr, "ping")["pid"]
            # handler exceptions arrive as RemoteError carrying the remote
            # traceback, and are NOT retried (the handler did run)
            with pytest.raises(RemoteError, match="sentinel-message"):
                request(srv.addr, "boom")
            with pytest.raises(RemoteError, match="unknown op"):
                request(srv.addr, "nope")
        finally:
            srv.shutdown()

    def test_shutdown_op_stops_server(self, fast_fail):
        srv = RpcServer({})
        t = srv.start_in_thread()
        assert request(srv.addr, "shutdown") == "bye"
        t.join(timeout=5)
        assert not t.is_alive()
        srv.server.server_close()

    def test_unreachable_host_raises_connection_error(self, fast_fail):
        with pytest.raises(ConnectionError):
            request("127.0.0.1:1", "ping")

    def test_host_assignments_round_robin(self):
        assert host_assignments(5, ["a", "b"]) == [("a", [0, 2, 4]),
                                                   ("b", [1, 3])]
        assert host_assignments(1, ["a", "b"]) == [("a", [0])]
        assert host_assignments(0, ["a"]) == []


# -- multi-host fan-out: byte-identical plans ---------------------------------


class TestFanout:
    @pytest.mark.parametrize("space", ["megatron", "zero", "mist",
                                       "uniform"])
    def test_hosts_plan_identical_to_serial(self, workers, space):
        cfg = get_arch(ARCH)
        ser = tune(cfg, SHAPE, 16, space=space, workers=0, **SMALL)
        hosts = tuple(w.addr for w in workers)
        for n_workers in (1, 2):
            rep = tune(cfg, SHAPE, 16, space=space, workers=n_workers,
                       hosts=hosts, **SMALL)
            assert _report_key(rep) == _report_key(ser)
            assert rep.hosts_used == 2
            assert rep.n_host_failures == 0

    def test_hosts_memo_identical_to_local(self, workers):
        knobs = _space_knobs("mist", get_arch(ARCH).num_layers)
        t1 = MistTuner(_spec())
        prefetch_frontiers(t1, t1._cells(), knobs, workers=1)
        th = MistTuner(_spec(hosts=tuple(w.addr for w in workers)))
        stats = prefetch_frontiers(th, th._cells(), knobs, workers=2,
                                   hosts=th.spec.hosts)
        assert stats.hosts_used == 2
        assert _memo_snapshot(t1) == _memo_snapshot(th)

    def test_dead_host_degrades_to_local(self, workers, fast_fail):
        """One live + one dead host: the dead host's shards re-run
        locally and the plan is still byte-identical."""
        cfg = get_arch(ARCH)
        ser = tune(cfg, SHAPE, 16, space="mist", workers=0, **SMALL)
        with pytest.warns(RuntimeWarning, match="fall back"):
            rep = tune(cfg, SHAPE, 16, space="mist", workers=1,
                       hosts=("127.0.0.1:1", workers[0].addr), **SMALL)
        assert _report_key(rep) == _report_key(ser)
        assert rep.hosts_used == 1
        assert rep.n_host_failures >= 1

    def test_all_hosts_dead_degrades_to_local(self, fast_fail):
        cfg = get_arch(ARCH)
        ser = tune(cfg, SHAPE, 16, space="mist", workers=0, **SMALL)
        with pytest.warns(RuntimeWarning):
            rep = tune(cfg, SHAPE, 16, space="mist", workers=1,
                       hosts=("127.0.0.1:1", "127.0.0.1:2"), **SMALL)
        assert _report_key(rep) == _report_key(ser)
        assert rep.hosts_used == 0

    def test_worker_daemon_serves_pool_task_payloads(self, workers):
        """The daemon's sweep op is the same `_pool_task` body: shipping
        it a shard returns the bitwise-identical memo shard a local
        execution computes."""
        spec = _spec(small=TINY)
        tuner = MistTuner(spec)
        knobs = _space_knobs("mist", spec.arch.num_layers)
        plan = plan_units(tuner, tuner._cells(), knobs)
        shards = _shard_units(plan, 2)
        payload = pickle.dumps((spec, knobs, plan,
                                [list(s) for s in shards]))
        outs = pickle.loads(request(workers[0].addr, "sweep", payload))
        assert len(outs) == len(shards)
        for shard_idxs, (shard, n_swept, _h, _m) in zip(shards, outs):
            local_shard, local_n = _sweep_units(tuner, plan, knobs,
                                                shard_idxs)
            assert n_swept == local_n
            assert {k: [(p.t, p.d, p.mem, p.cand) for p in r.frontier]
                    for k, r in shard} \
                == {k: [(p.t, p.d, p.mem, p.cand) for p in r.frontier]
                    for k, r in local_shard}


# -- partition property: any sharding merges to the same memo -----------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def test_any_partition_merges_bitwise_identical(data):
        """Hypothesis: ANY partition of the unit plan across ANY number of
        shards (hosts x workers), each executed by an independent tuner
        (= a different process/host), merges to a bitwise-identical
        frontier memo."""
        spec = _spec(small=TINY)
        knobs = _space_knobs("mist", spec.arch.num_layers)
        ref = MistTuner(spec)
        cells = ref._cells()
        plan = plan_units(ref, cells, knobs)
        _sweep_and_merge(ref, plan, knobs, [list(range(len(plan)))])
        n_shards = data.draw(st.integers(1, max(1, len(plan))),
                             label="n_shards")
        assign = data.draw(
            st.lists(st.integers(0, n_shards - 1), min_size=len(plan),
                     max_size=len(plan)), label="assignment")
        shards = [[i for i, a in enumerate(assign) if a == s]
                  for s in range(n_shards)]
        merged = MistTuner(spec)
        for shard_idxs in shards:
            if not shard_idxs:
                continue
            # fresh tuner per shard = a different host's executor
            worker_tuner = MistTuner(spec)
            shard, _n = _sweep_units(worker_tuner, plan, knobs, shard_idxs)
            merged._frontier_memo.update(shard)
        assert _memo_snapshot(merged) == _memo_snapshot(ref)

    def _sweep_and_merge(tuner, plan, knobs, shards):
        for shard_idxs in shards:
            shard, _n = _sweep_units(tuner, plan, knobs, shard_idxs)
            tuner._frontier_memo.update(shard)


# -- memo store ---------------------------------------------------------------


class TestMemoStore:
    def test_unit_round_trip_warms_plan(self, tmp_path):
        """A second tuner preloading from the store has nothing left to
        sweep: plan_units drops every warm unit."""
        d = str(tmp_path / "memo")
        r1 = MistTuner(_spec(memo_dir=d)).tune()
        assert not r1.from_memo and r1.n_swept > 0
        t2 = MistTuner(_spec(memo_dir=d, workers=1))
        knobs = _space_knobs("mist", t2.spec.arch.num_layers)
        store = MemoStore(d)
        n = store.preload(t2, t2._cells(), knobs)
        assert n > 0 and store.unit_misses == 0
        assert len(plan_units(t2, t2._cells(), knobs)) == 0

    def test_report_cache_round_trip(self, tmp_path):
        d = str(tmp_path / "memo")
        r1 = MistTuner(_spec(memo_dir=d)).tune()
        r2 = MistTuner(_spec(memo_dir=d)).tune()
        assert r2.from_memo and not r1.from_memo
        assert _report_key(r2) == _report_key(r1)

    def test_report_cache_ignores_execution_routing(self, tmp_path):
        """A report computed under one (engine, backend, workers, hosts)
        setting serves every other — those fields never change the
        answer, so the key excludes them."""
        d = str(tmp_path / "memo")
        r1 = MistTuner(_spec(memo_dir=d, workers=4)).tune()
        r2 = MistTuner(_spec(memo_dir=d, workers=0, backend="auto")).tune()
        assert r2.from_memo
        assert _report_key(r2) == _report_key(r1)

    def test_subset_query_served_from_unit_store(self, tmp_path):
        """A DIFFERENT query (fewer grad-accums → different report key)
        whose stage hypotheses are a subset of a previous sweep's runs
        without sweeping anything: the frontier memo is a cross-job
        cache, not just a same-query one."""
        d = str(tmp_path / "memo")
        MistTuner(_spec(memo_dir=d)).tune()
        rep = MistTuner(_spec(memo_dir=d,
                              small=dict(stage_counts=(1, 2),
                                         grad_accums=(2,)))).tune()
        assert not rep.from_memo          # different query...
        assert rep.n_swept == 0           # ...but zero cold sweeps
        assert rep.n_store_hits > 0

    def test_key_invalidation_on_profile_change(self, tmp_path):
        """A calibration-profile cost override must move every address:
        stale frontiers fitted under other constants are never served."""
        t1 = MistTuner(_spec())
        prof = CalibrationProfile.make(platform="cpu",
                                       cost={"runtime_reserved": 2.0**30})
        t2 = MistTuner(_spec(profile=prof))
        knobs = _space_knobs("mist", t1.spec.arch.num_layers)
        mk = dict(layers=20, n_dev=8, G=2, role=(True, True), inflight=1.0,
                  knobs=knobs)
        k1 = unit_key(tuner_fingerprint(t1), t1._memo_key(**mk))
        k2 = unit_key(tuner_fingerprint(t2), t2._memo_key(**mk))
        assert k1 != k2
        assert report_key(t1) != report_key(t2)

    def test_key_invalidation_on_knob_and_kernel_grid(self):
        t = MistTuner(_spec())
        fp = tuner_fingerprint(t)
        base_knobs = _space_knobs("mist", t.spec.arch.num_layers)
        zero_knobs = _space_knobs("zero", t.spec.arch.num_layers)
        mk = dict(layers=20, n_dev=8, G=2, role=(True, True), inflight=1.0)
        k_mist = unit_key(fp, t._memo_key(**mk, knobs=base_knobs))
        k_zero = unit_key(fp, t._memo_key(**mk, knobs=zero_knobs))
        assert k_mist != k_zero
        tg = MistTuner(_spec(kernel_grid=((512, 512, 256, 256),
                                          (256, 512, 256, 256))))
        k_grid = unit_key(tuner_fingerprint(tg),
                          tg._memo_key(**mk, knobs=base_knobs))
        assert k_grid != k_mist

    def test_key_invalidation_on_workload_change(self):
        t1 = MistTuner(_spec())
        t2 = MistTuner(dataclasses.replace(_spec(), seq_len=2048))
        assert report_key(t1) != report_key(t2)
        assert tuner_fingerprint(t1) != tuner_fingerprint(t2)

    def test_corrupt_entry_treated_cold(self, tmp_path):
        d = str(tmp_path / "memo")
        MistTuner(_spec(memo_dir=d)).tune()
        store = MemoStore(d)
        n_poisoned = 0
        for kind in ("units", "reports"):
            base = tmp_path / "memo" / kind
            for p in base.rglob("*.pkl"):
                p.write_bytes(b"not a pickle")
                n_poisoned += 1
        assert n_poisoned > 0
        rep = MistTuner(_spec(memo_dir=d)).tune()      # recomputes cleanly
        assert not rep.from_memo
        ser = MistTuner(_spec()).tune()
        assert _report_key(rep) == _report_key(ser)

    def test_atomic_write_layout(self, tmp_path):
        """Entries land under <kind>/<hh>/<hash>.pkl with no temp-file
        litter left behind."""
        d = str(tmp_path / "memo")
        MistTuner(_spec(memo_dir=d)).tune()
        files = list((tmp_path / "memo").rglob("*"))
        assert any(f.suffix == ".pkl" for f in files)
        assert not [f for f in files if f.suffix == ".tmp"]
        for f in files:
            if f.suffix == ".pkl":
                assert f.parent.name == f.stem[:2]

    def test_canonical_hash_stability(self):
        """Digest is structural, not pickle-bytes: equal values hash
        equal, tuples/lists distinguish from their elements, floats are
        bit-exact."""
        assert memo_store.digest({"a": (1, 2.5)}) \
            == memo_store.digest({"a": (1, 2.5)})
        assert memo_store.digest(0.1 + 0.2) != memo_store.digest(0.3)
        assert memo_store.digest((1,)) != memo_store.digest(1)

    def test_gc_prunes_oldest_access_first(self, tmp_path):
        """gc(max_bytes) evicts by last ACCESS, not write order: a _get
        hit refreshes the entry's timestamp, so warm entries outlive
        cold-but-newer ones.  Evictions are whole-entry unlinks and the
        pass is idempotent at the cap."""
        import os
        store = MemoStore(str(tmp_path / "memo"))
        keys = [ch * 64 for ch in "abcd"]
        for k in keys:
            store._put("units", k, b"payload")
        for i, k in enumerate(keys):       # ages: a oldest ... d newest
            t = 1_000_000 + i * 100
            os.utime(store._path("units", k), (t, t))
        # a hit on the OLDEST-written entry refreshes it to now
        assert store._get("units", keys[0]) == b"payload"
        size = os.path.getsize(store._path("units", keys[0]))
        stats = store.gc(max_bytes=2 * size)
        assert stats == {"scanned": 4, "removed": 2,
                         "bytes_before": 4 * size,
                         "bytes_after": 2 * size}
        assert store._get("units", keys[1]) is None    # oldest access
        assert store._get("units", keys[2]) is None
        assert store._get("units", keys[0]) == b"payload"   # refreshed
        assert store._get("units", keys[3]) == b"payload"   # newest
        assert store.gc(max_bytes=2 * size)["removed"] == 0  # idempotent
        with pytest.raises(ValueError):
            store.gc(max_bytes=-1)

    def test_gc_bounds_a_real_store(self, tmp_path):
        """gc(0) empties a store a real tune populated; the next query
        recomputes cleanly (an evicted entry is a miss, never an error)."""
        d = str(tmp_path / "memo")
        MistTuner(_spec(memo_dir=d)).tune()
        store = MemoStore(d)
        stats = store.gc(max_bytes=0)
        assert stats["removed"] == stats["scanned"] > 0
        assert stats["bytes_after"] == 0
        assert store.count("units") == 0 and store.count("reports") == 0
        rep = MistTuner(_spec(memo_dir=d)).tune()
        assert not rep.from_memo
        assert _report_key(rep) == _report_key(MistTuner(_spec()).tune())


# -- persistent tune service --------------------------------------------------


class TestTuneService:
    def test_service_round_trip_and_warm_hit(self, tmp_path):
        svc = TuneService(str(tmp_path / "memo"))
        svc.start_in_thread()
        try:
            spec = _spec()
            ser = MistTuner(spec).tune()
            r1 = tune_remote(spec, svc.addr)
            assert _report_key(r1) == _report_key(ser)
            assert not r1.from_memo
            r2 = tune_remote(spec, svc.addr)
            assert r2.from_memo
            assert _report_key(r2) == _report_key(ser)
            stats = request(svc.addr, "stats")
            assert stats["queries"] == 2 and stats["report_hits"] == 1
        finally:
            svc.shutdown()

    def test_service_gc_zero_cap_empties_store(self, tmp_path):
        """--gc-max-bytes 0: every entry is evicted after each query, so
        the warm path never hits — but answers stay correct."""
        d = str(tmp_path / "memo")
        svc = TuneService(d, gc_max_bytes=0)
        svc.start_in_thread()
        try:
            r1 = tune_remote(_spec(), svc.addr)
            assert not r1.from_memo
            stats = request(svc.addr, "stats")
            assert stats["gc_max_bytes"] == 0
            assert stats["last_gc"]["bytes_after"] == 0
            store = MemoStore(d)
            assert store.count("units") == 0
            assert store.count("reports") == 0
            r2 = tune_remote(_spec(), svc.addr)      # recomputes cleanly
            assert not r2.from_memo
            assert _report_key(r2) == _report_key(r1)
        finally:
            svc.shutdown()

    def test_service_gc_generous_cap_keeps_warm_path(self, tmp_path):
        svc = TuneService(str(tmp_path / "memo"), gc_max_bytes=1 << 30)
        svc.start_in_thread()
        try:
            tune_remote(_spec(), svc.addr)
            r2 = tune_remote(_spec(), svc.addr)
            assert r2.from_memo                      # nothing evicted
            stats = request(svc.addr, "stats")
            assert stats["last_gc"]["removed"] == 0
        finally:
            svc.shutdown()

    def test_service_overrides_client_routing(self, tmp_path):
        """The service applies its own memo/worker policy: a client spec
        pointing at a bogus memo_dir or dead hosts is re-routed."""
        svc = TuneService(str(tmp_path / "memo"))
        svc.start_in_thread()
        try:
            spec = _spec(memo_dir="/nonexistent/elsewhere",
                         hosts=("127.0.0.1:1",))
            rep = tune_remote(spec, svc.addr)
            ser = MistTuner(_spec()).tune()
            assert _report_key(rep) == _report_key(ser)
            assert rep.n_host_failures == 0    # dead client hosts ignored
        finally:
            svc.shutdown()
