"""Differential + equivalence suite for the tape evaluation backends.

Three executors exist for a compiled ``Tape``: the numpy instruction loop
(with and without ``out=`` scratch reuse), and the jax lowering
(``Tape.lower_jax``) in exact mode — the ``StageCostModel(backend="jax")``
path.  Under ``jax_enable_x64`` all of them must be **bitwise identical**
to the reference recursive ``Expr.evaluate`` walk, which is what lets the
tuner hand any backend's results to the same Pareto/MILP pipeline and
still guarantee identical plans.  The fused ``jax.jit`` mode is exempt
from bitwise (CPU FMA contraction, ~1-2 ulp) and asserted close instead.

Everything jax-dependent skips cleanly when jax is missing or cannot
produce 64-bit floats.
"""
import contextlib

import numpy as np
import pytest

from repro import compat
from repro.configs.base import ShapeConfig, get_arch
from repro.core import symbolic as S
from repro.core.costmodel import StageCostModel
from repro.core.intra_stage import tune_stage
from repro.core.schedule import candidate_grid
from repro.core.symbolic import (Const, Sym, ceil, compile_tape, smax, smin,
                                 where)
from repro.core.tuner import SPACES, MistTuner, TuneSpec, tune

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

ARCH = "granite-3-8b"
SHAPE = ShapeConfig("t", 2048, 16, "train")
SMALL = dict(stage_counts=(1, 2), grad_accums=(2, 4))


@contextlib.contextmanager
def x64_or_skip():
    """Enter 64-bit jax mode, or skip the test when that is impossible."""
    if not compat.has_jax():
        pytest.skip("jax unavailable; numpy backend only")
    with compat.enable_x64():
        if not compat.jax_x64_enabled():
            pytest.skip("this jax cannot produce 64-bit floats")
        yield


def _all_equal(ref, outs, err=""):
    for k in ref:
        np.testing.assert_array_equal(
            np.broadcast_to(ref[k], np.shape(outs[k])), np.asarray(outs[k]),
            err_msg=f"{err}{k}")


# -- differential: naive walk vs numpy tape vs scratch vs jax -----------------


def _mixed_dag():
    x, y = Sym("x"), Sym("y")
    e1 = smin(x / y, ceil(x) * 2.0) + where(x > y, x - y, y - x)
    e2 = (x / y) * (x / y) + e1 + smax(x, 3.0) - S.UnOp("abs", x - 2.0)
    return {"e1": e1, "e2": e2}


@pytest.mark.parametrize("env", [
    {"x": 4.0, "y": 2.0},                                   # scalar
    {"x": np.linspace(0.1, 9.0, 997), "y": 2.0},            # batched+scalar
    {"x": np.linspace(0.1, 9.0, 64),
     "y": np.linspace(1.0, 3.0, 64)},                       # batched
], ids=["scalar", "mixed", "batched"])
def test_differential_mixed_dag(env):
    outs = _mixed_dag()
    tape = compile_tape(outs)
    memo = {}
    ref = {k: e.evaluate(env, memo) for k, e in outs.items()}
    _all_equal(ref, tape.run(env), "tape:")
    sc = tape.make_scratch()
    tape.run(env, sc)
    _all_equal(ref, tape.run(env, sc), "scratch:")
    with x64_or_skip():
        _all_equal(ref, tape.lower_jax()(env), "jax-exact:")
        fused = tape.lower_jax(fused=True)(env)
        for k in ref:
            # fused is exempt from bitwise: CPU FMA contraction (plus
            # cancellation amplification) perturbs the last few ulps
            np.testing.assert_allclose(np.asarray(fused[k]), ref[k],
                                       rtol=1e-9, atol=1e-12,
                                       err_msg=f"jax-fused:{k}")


def test_jax_missing_symbol_raises_keyerror():
    with x64_or_skip():
        tape = compile_tape({"o": Sym("x") + Sym("y")})
        with pytest.raises(KeyError, match="unbound symbol"):
            tape.lower_jax()({"x": 1.0})


if HAVE_HYPOTHESIS:
    _leaf = st.one_of(
        st.floats(min_value=0.1, max_value=10.0).map(Const),
        st.sampled_from(["x", "y", "z"]).map(Sym),
    )

    def _tree(depth):
        if depth == 0:
            return _leaf
        sub = _tree(depth - 1)
        return st.one_of(
            _leaf,
            st.tuples(st.sampled_from(["+", "-", "*", "/", "^", "v", "<"]),
                      sub, sub),
            st.tuples(st.sampled_from(["ceil", "floor", "abs", "sqrt"]),
                      sub),
        )

    def _build(t):
        if isinstance(t, S.Expr):
            return t
        if len(t) == 2:
            if t[0] == "sqrt":          # domain-safe: sqrt of |.|
                return S.UnOp("sqrt", S.UnOp("abs", _build(t[1])))
            return S.UnOp(t[0], _build(t[1]))
        op, a, b = t
        a, b = _build(a), _build(b)
        return {"+": a + b, "-": a - b, "*": a * b, "/": a / b,
                "^": smax(a, b), "v": smin(a, b),
                "<": where(a < b, a, b)}[op]

    @settings(max_examples=60, deadline=None)
    @given(st.lists(_tree(4), min_size=1, max_size=4),
           st.lists(st.floats(0.1, 5.0), min_size=4, max_size=4))
    def test_random_dags_equal_across_backends(trees, vals):
        outs = {f"o{i}": _build(t) for i, t in enumerate(trees)}
        tape = compile_tape(outs)
        for env in ({"x": np.asarray(vals), "y": 2.0, "z": 0.7},
                    {"x": float(vals[0]), "y": float(vals[1]),
                     "z": float(vals[2])}):
            memo = {}
            ref = {k: e.evaluate(env, memo) for k, e in outs.items()}
            _all_equal(ref, tape.run(env), "tape:")
            sc = tape.make_scratch()
            tape.run(env, sc)
            _all_equal(ref, tape.run(env, sc), "scratch:")
            with x64_or_skip():
                _all_equal(ref, tape.lower_jax()(env), "jax:")
else:
    def test_property_tests_need_hypothesis():
        pytest.importorskip("hypothesis")


# -- StageCostModel backend dispatch ------------------------------------------


def _grid_env(cfg):
    grid = candidate_grid(cfg, n_devices=8, layers=16, global_batch=16,
                          grad_accum=2)
    return grid.env(layers=16, grad_accum=2, inflight=2.0)


def test_costmodel_jax_backend_bitwise_equals_numpy():
    cfg = get_arch(ARCH)
    a = StageCostModel(cfg, 2048)
    b = StageCostModel(cfg, 2048, backend="jax")
    env = _grid_env(cfg)
    with x64_or_skip():
        ra, rb = a.evaluate(env), b.evaluate(env)
        assert b.last_backend == "jax"
        for k in ("mem_fwd", "mem_bwd", "mem_peak", "t_stable", "d_delta",
                  "t_step", "t_first", "t_last"):
            np.testing.assert_array_equal(ra[k], rb[k], err_msg=k)
        for k in ra["items"]:
            np.testing.assert_array_equal(ra["items"][k], rb["items"][k],
                                          err_msg=k)
        ma, mb = a.evaluate_memory(env), b.evaluate_memory(env)
        np.testing.assert_array_equal(ma["mem_peak"], mb["mem_peak"])
        ta, tb = a.evaluate_times(env), b.evaluate_times(env)
        np.testing.assert_array_equal(ta["t_stable"], tb["t_stable"])
        np.testing.assert_array_equal(ta["d_delta"], tb["d_delta"])


def test_auto_backend_thresholds_on_grid_size():
    cfg = get_arch(ARCH)
    scm = StageCostModel(cfg, 2048, backend="auto")
    env = _grid_env(cfg)
    with x64_or_skip():
        scm.jax_auto_threshold = 10**9          # far above this grid
        scm.evaluate_memory(env)
        assert scm.last_backend == "numpy"
        scm.jax_auto_threshold = 1
        scm.evaluate_memory(env)
        assert scm.last_backend == "jax"


@pytest.mark.parametrize("backend", ["jax", "auto"])
def test_jax_backends_require_x64(backend):
    """Without x64, jax would evaluate in float32 and drift from numpy —
    voiding the identical-plan guarantee and poisoning the
    backend-interchangeable knob-tuple cache — so BOTH jax-capable
    backends must refuse jax and stay on numpy, whatever the grid size."""
    if not compat.has_jax():
        pytest.skip("jax unavailable")
    if compat.jax_x64_enabled():
        pytest.skip("x64 globally enabled; the refusal path is unreachable")
    cfg = get_arch(ARCH)
    scm = StageCostModel(cfg, 2048, backend=backend)
    scm.jax_auto_threshold = 1
    scm.evaluate_memory(_grid_env(cfg))
    assert scm.last_backend == "numpy"


def test_jax_backend_refuses_non_bitexact_tapes():
    """pow/log2 are not correctly rounded identically by libm and XLA
    (measured), so a tape containing them must report jax_bitexact=False
    and the backend dispatcher must refuse jax for it."""
    x = Sym("x")
    safe = compile_tape({"o": ceil(x) / 2.0 + smax(x, 1.0)})
    assert safe.jax_bitexact
    risky = compile_tape({"o": x ** Sym("y") + S.UnOp("log2", x)})
    assert not risky.jax_bitexact
    with x64_or_skip():
        # the lowering itself still works, just without the bitwise claim
        env = {"x": np.linspace(1.0, 4.0, 11), "y": np.full(11, 1.5)}
        np.testing.assert_allclose(np.asarray(risky.lower_jax()(env)["o"]),
                                   risky.run(env)["o"], rtol=1e-12)
        cfg = get_arch(ARCH)
        scm = StageCostModel(cfg, 2048, backend="jax")
        e = {k: np.asarray(v, np.float64)
             for k, v in env.items()}
        assert scm._use_jax(safe, e)
        assert not scm._use_jax(risky, e)


def test_jax_backend_degrades_without_jax(monkeypatch):
    cfg = get_arch(ARCH)
    scm = StageCostModel(cfg, 2048, backend="jax")
    monkeypatch.setattr(compat, "has_jax", lambda: False)
    r = scm.evaluate_memory(_grid_env(cfg))
    assert scm.last_backend == "numpy"
    ref = StageCostModel(cfg, 2048).evaluate_memory(_grid_env(cfg))
    np.testing.assert_array_equal(r["mem_peak"], ref["mem_peak"])


def test_unknown_backend_rejected():
    cfg = get_arch(ARCH)
    with pytest.raises(ValueError, match="backend"):
        StageCostModel(cfg, 2048, backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        MistTuner(TuneSpec(arch=cfg, seq_len=2048, global_batch=16,
                           n_devices=8, backend="cuda"))


# -- frontier / plan equivalence ----------------------------------------------


def _front_key(res):
    return (res.n_evaluated, res.n_feasible,
            [(p.t, p.d, p.mem, p.cand) for p in res.frontier])


def test_tune_stage_frontier_byte_identical_across_backends():
    cfg = get_arch(ARCH)
    kw = dict(seq_len=2048, layers=20, n_devices=8,
              global_batch_per_stage=16, grad_accum=2, inflight=2.0)
    ref = tune_stage(cfg, **kw)
    with x64_or_skip():
        jx = tune_stage(cfg, backend="jax", **kw)
        au = tune_stage(cfg, backend="auto", **kw)
    assert _front_key(jx) == _front_key(ref)
    assert _front_key(au) == _front_key(ref)


def _report_key(rep):
    return (rep.objective, rep.plan, rep.best_S, rep.best_G,
            tuple(rep.per_sg), rep.n_milp)


@pytest.mark.parametrize("space", SPACES)
def test_plans_identical_across_backends_all_spaces(space):
    cfg = get_arch(ARCH)
    ref = tune(cfg, SHAPE, 8, space=space, **SMALL)
    with x64_or_skip():
        jx = tune(cfg, SHAPE, 8, space=space, backend="jax", **SMALL)
        au = tune(cfg, SHAPE, 8, space=space, backend="auto", **SMALL)
    assert _report_key(jx) == _report_key(ref)
    assert _report_key(au) == _report_key(ref)


@pytest.mark.parametrize("workers", [0, 1, 4])
def test_jax_backend_plans_identical_across_worker_counts(workers):
    """The sweep executor must be backend-invisible too: forked workers
    deliberately sweep on numpy (forking a live XLA runtime is unsafe),
    which the bitwise guarantee makes indistinguishable."""
    cfg = get_arch(ARCH)
    ref = tune(cfg, SHAPE, 8, space="mist", workers=0, **SMALL)
    with x64_or_skip():
        rep = tune(cfg, SHAPE, 8, space="mist", backend="jax",
                   workers=workers, **SMALL)
    assert _report_key(rep) == _report_key(ref)
