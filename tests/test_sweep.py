"""Sweep-executor guarantees: the parallel (S, G) executor, the
G-collapsed multi-G sweeps, the knob-tuple tape cache, and `Tape.run`
scratch buffers must all be *bitwise invisible* — identical frontiers,
objectives, and plans to the plain serial compiled engine."""
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch
from repro.core.costmodel import StageCostModel
from repro.core.intra_stage import tune_stage, tune_stage_multi_g
from repro.core.inter_stage import solve_milp
from repro.core.schedule import candidate_grid
from repro.core.sweep import (plan_units, prefetch_frontiers, solve_cells,
                              _shard_units)
from repro.core.symbolic import Sym, ceil, compile_tape, smax, smin, where
from repro.core.tuner import MistTuner, TuneSpec, _space_knobs, tune

ARCH = "granite-3-8b"
SHAPE = ShapeConfig("t", 4096, 32, "train")
SMALL = dict(stage_counts=(1, 2), grad_accums=(2, 4))


def _spec(space="mist", workers=1, **kw):
    cfg = get_arch(ARCH)
    return TuneSpec(arch=cfg, seq_len=SHAPE.seq_len,
                    global_batch=SHAPE.global_batch, n_devices=16,
                    space=space, workers=workers, **{**SMALL, **kw})


def _report_key(rep):
    return (rep.objective, rep.plan, rep.best_S, rep.best_G,
            tuple(rep.per_sg), rep.n_milp)


# -- parallel vs serial plan equivalence --------------------------------------


@pytest.mark.parametrize("space", ["megatron", "zero", "mist", "uniform"])
def test_executor_plan_identical_to_serial(space):
    cfg = get_arch(ARCH)
    reps = [tune(cfg, SHAPE, 16, space=space, workers=w, **SMALL)
            for w in (0, 1, 4)]
    assert _report_key(reps[0]) == _report_key(reps[1]) \
        == _report_key(reps[2])


def test_workers4_deterministic_across_runs():
    cfg = get_arch(ARCH)
    a = tune(cfg, SHAPE, 16, space="mist", workers=4, **SMALL)
    b = tune(cfg, SHAPE, 16, space="mist", workers=4, **SMALL)
    assert _report_key(a) == _report_key(b)


# -- frontier-memo merge ------------------------------------------------------


def _memo_snapshot(tuner):
    return {k: [(p.t, p.d, p.mem, p.cand) for p in r.frontier]
            for k, r in tuner._frontier_memo.items()}


def test_memo_merge_matches_serial_memo():
    """Sharded workers must reassemble exactly the serial executor's memo:
    same keys, same frontiers."""
    knobs = _space_knobs("mist", get_arch(ARCH).num_layers)
    t1 = MistTuner(_spec())
    st1 = prefetch_frontiers(t1, t1._cells(), knobs, workers=1)
    t4 = MistTuner(_spec(workers=4))
    st4 = prefetch_frontiers(t4, t4._cells(), knobs, workers=4)
    assert st4.workers_used > 1
    assert st1.n_swept == st4.n_swept
    assert _memo_snapshot(t1) == _memo_snapshot(t4)


def test_memo_entries_match_standalone_tune_stage():
    """Executor-produced frontiers == direct tune_stage calls (the
    across-unit batched refinement must be invisible)."""
    cfg = get_arch(ARCH)
    spec = _spec()
    knobs = _space_knobs("mist", cfg.num_layers)
    tuner = MistTuner(spec)
    cells = tuner._cells()
    prefetch_frontiers(tuner, cells, knobs, workers=1)
    plan = plan_units(MistTuner(spec), cells, knobs)  # fresh: nothing memoized
    assert len(plan)
    for (layers, n_dev, role, inflight), gs in zip(plan.units,
                                                   plan.gs_per_unit):
        for G in gs:
            key = tuner._memo_key(layers=layers, n_dev=n_dev, G=G,
                                  role=role, inflight=inflight, knobs=knobs)
            got = tuner._frontier_memo[key]
            ref = tune_stage(
                cfg, seq_len=spec.seq_len, layers=layers, n_devices=n_dev,
                global_batch_per_stage=spec.global_batch, grad_accum=G,
                has_embed=role[0], has_head=role[1], inflight=inflight,
                zeros=knobs["zeros"], ratios=knobs["ratios"],
                ratio_dims=knobs["ratio_dims"],
                ckpt_values=None, max_tp=spec.max_tp,
                max_front=spec.max_front,
                scm=tuner.scm(*role), refine=bool(knobs["ratio_dims"]))
            assert got.n_evaluated == ref.n_evaluated
            assert got.n_feasible == ref.n_feasible
            assert [(p.t, p.d, p.mem, p.cand) for p in got.frontier] \
                == [(p.t, p.d, p.mem, p.cand) for p in ref.frontier]


def test_plan_units_skips_memoized_hypotheses():
    knobs = _space_knobs("mist", get_arch(ARCH).num_layers)
    tuner = MistTuner(_spec())
    cells = tuner._cells()
    prefetch_frontiers(tuner, cells, knobs, workers=1)
    again = plan_units(tuner, cells, knobs)
    assert len(again) == 0
    stats = prefetch_frontiers(tuner, cells, knobs, workers=1)
    assert stats.n_swept == 0


def test_shard_units_partitions_all_units():
    knobs = _space_knobs("mist", get_arch(ARCH).num_layers)
    tuner = MistTuner(_spec())
    plan = plan_units(tuner, tuner._cells(), knobs)
    shards = _shard_units(plan, 3)
    flat = sorted(i for s in shards for i in s)
    assert flat == list(range(len(plan)))


# -- G-collapsed sweeps -------------------------------------------------------


def test_tune_stage_multi_g_bitwise_equivalent():
    cfg = get_arch(ARCH)
    kw = dict(seq_len=4096, layers=20, n_devices=16,
              global_batch_per_stage=32, has_embed=False, has_head=True,
              inflight=2.0)
    gs = (1, 2, 4, 8)
    multi = tune_stage_multi_g(cfg, grad_accums=gs, **kw)
    for G in gs:
        single = tune_stage(cfg, grad_accum=G, **kw)
        assert multi[G].n_evaluated == single.n_evaluated
        assert multi[G].n_feasible == single.n_feasible
        assert [(p.t, p.d, p.mem, p.cand) for p in multi[G].frontier] \
            == [(p.t, p.d, p.mem, p.cand) for p in single.frontier]


def test_tune_stage_multi_g_handles_indivisible_g():
    cfg = get_arch(ARCH)
    res = tune_stage_multi_g(cfg, seq_len=2048, layers=8, n_devices=4,
                             global_batch_per_stage=8, grad_accums=(3, 16))
    # G=3 leaves no legal (b, dp); G=16 > batch/dp for dp>... both empty-ish
    assert res[3].n_evaluated == 0
    assert res[3].frontier == []


# -- knob-tuple tape cache ----------------------------------------------------


def test_time_cache_hit_returns_identical_results():
    cfg = get_arch(ARCH)
    scm = StageCostModel(cfg, 2048)
    grid = candidate_grid(cfg, n_devices=8, layers=16, global_batch=16,
                          grad_accum=2)
    env = grid.env(layers=16, grad_accum=2, inflight=1.0)
    fresh = scm.evaluate_times(env)
    key = ("k", 1)
    first = scm.evaluate_times(env, cache_key=key)
    assert scm.cache_misses >= 1
    hit = scm.evaluate_times(env, cache_key=key)
    assert scm.cache_hits >= 1
    for k in ("t_stable", "d_delta", "t_step", "t_first", "t_last"):
        np.testing.assert_array_equal(fresh[k], first[k])
        np.testing.assert_array_equal(fresh[k], hit[k])


def test_time_cache_recomputes_t_step_per_g():
    """The cache stores only G-independent outputs; t_step must follow the
    caller's G even on a hit."""
    cfg = get_arch(ARCH)
    scm = StageCostModel(cfg, 2048)
    grid = candidate_grid(cfg, n_devices=8, layers=16, global_batch=16,
                          grad_accum=2)
    env = grid.env(layers=16, grad_accum=2, inflight=1.0)
    key = ("g-indep",)
    a = scm.evaluate_times(env, cache_key=key)
    env8 = dict(env, G=8.0)
    b = scm.evaluate_times(env8, cache_key=key)
    np.testing.assert_array_equal(a["t_stable"], b["t_stable"])
    np.testing.assert_array_equal(8.0 * a["t_stable"] + a["d_delta"],
                                  b["t_step"])


def test_time_tape_is_g_and_inflight_independent():
    """The structural guarantee the whole G-collapse rests on: the time
    tape loads neither G nor inflight, the memory tape never loads G."""
    scm = StageCostModel(get_arch(ARCH), 2048)
    time_syms = {n for n, _ in scm.tape_time.sym_loads}
    mem_syms = {n for n, _ in scm.tape_mem.sym_loads}
    assert "G" not in time_syms and "inflight" not in time_syms
    assert "G" not in mem_syms


# -- parallel MILP phase ------------------------------------------------------


def test_solve_cells_matches_serial_milp():
    cfg = get_arch(ARCH)
    spec = _spec()
    knobs = _space_knobs("mist", cfg.num_layers)
    tuner = MistTuner(spec)
    prefetch_frontiers(tuner, tuner._cells(), knobs, workers=1)
    jobs = []
    for S, G in tuner._cells():
        cands = tuner._cands_for(S, G, knobs)
        if not any(not cs for cs in cands):
            jobs.append((S, G, cands))
    assert jobs
    par = solve_cells(jobs, total_layers=cfg.num_layers, total_devices=16,
                      workers=4)
    for S, G, cands in jobs:
        ser = solve_milp(cands, total_layers=cfg.num_layers,
                         total_devices=16, G=G)
        p = par[(S, G)]
        if ser is None:
            assert p is None
            continue
        assert p.objective == ser.objective
        assert [(c.layers, c.n_devices, c.t, c.d) for c in p.selection] \
            == [(c.layers, c.n_devices, c.t, c.d) for c in ser.selection]


# -- Tape scratch buffers -----------------------------------------------------


def test_tape_scratch_bitwise_and_output_freshness():
    x, y = Sym("x"), Sym("y")
    e1 = smin(x / y, ceil(x) * 2.0) + where(x > y, x - y, y - x)
    e2 = (x / y) * (x / y) + e1 + smax(x, 3.0)
    tape = compile_tape({"e1": e1, "e2": e2})
    sc = tape.make_scratch()
    env = {"x": np.linspace(0.1, 9.0, 997), "y": 2.0}
    base = tape.run(env)
    tape.run(env, sc)
    out = tape.run(env, sc)          # buffers active
    for k in base:
        np.testing.assert_array_equal(base[k], out[k])
    # outputs are fresh arrays, never aliases of scratch buffers
    assert not any(out[k] is b for k in out for b in sc.bufs
                   if b is not None)
    # self-resizes across batch-shape changes, and scalar envs do not
    # broadcast into stale buffers
    env2 = {"x": np.linspace(0.1, 9.0, 13), "y": 2.0}
    np.testing.assert_array_equal(tape.run(env2, sc)["e2"],
                                  tape.run(env2)["e2"])
    env3 = {"x": 4.0, "y": 2.0}
    a, b = tape.run(env3, sc), tape.run(env3)
    assert np.shape(a["e1"]) == np.shape(b["e1"])
    np.testing.assert_array_equal(a["e1"], b["e1"])
