"""Sharding rules (pure metadata — no multi-device needed) + Plan + schedule
legality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; example tests still run
    HAVE_HYPOTHESIS = False

from repro import compat
from repro.configs.base import get_arch
from repro.core.plan import Plan, StageConfig, megatron_baseline_plan, \
    single_stage_plan
from repro.core.schedule import (Candidate, ckpt_choices, divisors,
                                 enumerate_candidates, grad_accum_choices,
                                 legal_dp_tp, microbatch_choices,
                                 validate_plan)
from repro.models.zoo import abstract_params
from repro.parallel import sharding as SH


def _mesh(dp=1, tp=1):
    if dp * tp <= len(jax.devices()):
        return compat.make_mesh((dp, tp), ("data", "model"))
    # spec-only tests: abstract meshes carry shapes without devices
    return compat.abstract_mesh((dp, tp), ("data", "model"))


# -- choose_tp_dim / param_spec ------------------------------------------------


def test_choose_tp_priority():
    # heads beats vocab
    i = SH.choose_tp_dim(("vocab", "heads"), (100, 16), 4, False)
    assert i == 1
    # indivisible dims skipped
    i = SH.choose_tp_dim(("heads",), (6,), 4, False)
    assert i is None
    # layer axes never sharded
    i = SH.choose_tp_dim(("layers", "mlp"), (8, 64), 4, False)
    assert i == 1


def test_param_specs_divisible():
    """Every emitted spec must divide the dim it shards, and the layer dim
    is never sharded."""
    cfg = get_arch("granite-3-8b")
    ma = SH.MeshAxes(dp=("data",), tp="model", fsdp=("data",))
    params, axes = abstract_params(cfg)
    for dp, tp in ((4, 4), (2, 8)):
        mesh = _mesh(dp, tp)
        for name, sds in params.items():
            spec = SH.param_spec(name, sds.shape, axes[name], mesh, ma,
                                 zero3=True, ep_ok=False)
            for i, (dim, sp) in enumerate(zip(sds.shape, tuple(spec))):
                if sp is None:
                    continue
                size = tp if sp == "model" else dp
                assert dim % size == 0, (name, i, dim, sp)
                assert axes[name][i] not in SH.LAYER_AXES
    # at tp=8, attention q weights shard on the heads dim
    mesh = _mesh(2, 8)
    spec = SH.param_spec("layers/attn/wq", params["layers/attn/wq"].shape,
                         axes["layers/attn/wq"], mesh, ma, zero3=False,
                         ep_ok=False)
    assert "model" in tuple(spec)


def test_zero_levels_monotone_sharding():
    """grad_spec shards over dp iff zero >= 2; opt_spec iff zero >= 1."""
    cfg = get_arch("granite-3-8b")
    mesh = _mesh(4, 2)
    ma = SH.MeshAxes(dp=("data",), tp="model", fsdp=("data",))
    params, axes = abstract_params(cfg)
    name = "layers/mlp/wu"
    sds = params[name]
    g1 = SH.grad_spec(name, sds.shape, axes[name], mesh, ma, zero=1,
                      ep_ok=False)
    g2 = SH.grad_spec(name, sds.shape, axes[name], mesh, ma, zero=2,
                      ep_ok=False)
    o1 = SH.opt_spec(name, sds.shape, axes[name], mesh, ma, zero=1,
                     ep_ok=False)
    def has_data(spec):
        return any("data" in str(a) for a in tuple(spec) if a is not None)
    assert not has_data(g1)
    assert has_data(g2)
    assert has_data(o1)


# -- schedule enumeration -------------------------------------------------------


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6, 12]


def test_legal_dp_tp_respects_heads():
    cfg = get_arch("granite-3-8b")       # 32 heads
    pairs = legal_dp_tp(16, cfg)
    assert (16, 1) in pairs and (1, 16) in pairs
    cfg9 = cfg.replace(num_heads=9, num_kv_heads=3)
    pairs9 = legal_dp_tp(16, cfg9)
    assert all(tp in (1,) for _, tp in pairs9)  # 9 !% 2,4,8,16


def test_microbatch_choices_consistency():
    assert microbatch_choices(256, dp=8, grad_accum=4) == [8]
    assert microbatch_choices(256, dp=8, grad_accum=3) == []


def test_ckpt_choices_cover_extremes():
    cs = ckpt_choices(40, granularity=8)
    assert 0 in cs and 40 in cs


def test_enumerate_candidates_all_legal():
    cfg = get_arch("granite-3-8b")
    for c in enumerate_candidates(cfg, n_devices=8, layers=40,
                                  global_batch=32, grad_accum=4,
                                  ckpt_granularity=10):
        assert c.dp * c.tp == 8
        assert 4 * c.b * c.dp == 32
        assert cfg.num_heads % c.tp == 0


# -- Plan -------------------------------------------------------------------------


def test_plan_json_roundtrip():
    p = single_stage_plan(40, dp=4, tp=4, micro_batch=2, grad_accum=8,
                          zero=2, ckpt_layers=10, oo=0.5, ao=0.25)
    q = Plan.from_json(p.to_json())
    assert q == p


if HAVE_HYPOTHESIS:
    _ratio = st.floats(0.0, 1.0, allow_nan=False)
    _stage = st.builds(
        StageConfig,
        layers=st.integers(1, 128),
        micro_batch=st.integers(1, 64),
        dp=st.integers(1, 256),
        tp=st.integers(1, 64),
        zero=st.integers(0, 3),
        ckpt_layers=st.integers(0, 10**9),
        wo=_ratio, go=_ratio, oo=_ratio, ao=_ratio,
    )
    _plan = st.builds(
        Plan,
        grad_accum=st.integers(1, 512),
        stages=st.lists(_stage, min_size=1, max_size=4).map(tuple),
        sequence_parallel=st.booleans(),
        remat_policy=st.sampled_from(["full", "dots"]),
        attn_impl=st.sampled_from(["naive", "blocked", "pallas"]),
        use_pallas=st.booleans(),
        grad_compression=st.booleans(),
        kv_cache_dtype=st.sampled_from(["bf16", "int8"]),
    )

    @settings(max_examples=200, deadline=None)
    @given(_plan)
    def test_plan_json_roundtrip_property(plan):
        """LoweredPlan trusts serialized plans: to_json/from_json is the
        identity for every representable plan (floats ride through
        repr-exact JSON), and == means field-level equality."""
        assert Plan.from_json(plan.to_json()) == plan
else:
    def test_plan_roundtrip_needs_hypothesis():
        pytest.importorskip("hypothesis")


def test_validate_plan_catches_violations():
    cfg = get_arch("granite-3-8b")
    good = single_stage_plan(cfg.num_layers, dp=4, tp=4, micro_batch=2,
                             grad_accum=4, zero=1)
    assert validate_plan(good, cfg, 16, 32) == []
    bad_layers = single_stage_plan(39, dp=4, tp=4, micro_batch=2,
                                   grad_accum=4)
    assert validate_plan(bad_layers, cfg, 16, 32)
    bad_batch = single_stage_plan(cfg.num_layers, dp=4, tp=4, micro_batch=2,
                                  grad_accum=8)
    assert validate_plan(bad_batch, cfg, 16, 32)
    bad_ratio = single_stage_plan(cfg.num_layers, dp=4, tp=4, micro_batch=2,
                                  grad_accum=4, oo=1.5)
    assert validate_plan(bad_ratio, cfg, 16, 32)


def test_megatron_baseline_plan_shape():
    p = megatron_baseline_plan(40, 256, 256, tp=16)
    assert p.devices == 256
    assert p.stages[0].ckpt_layers >= 40
    assert p.global_batch() == 256


# -- cache specs -------------------------------------------------------------------


def test_cache_specs_batch_vs_seq_sharding():
    cfg = get_arch("granite-3-8b").reduced()
    from repro.models.zoo import build_model
    model = build_model(cfg)
    mesh = _mesh(1, 1)
    ma = SH.MeshAxes(dp=("data",), tp="model", fsdp=("data",))
    # batch divisible by dp -> batch sharded
    caches = jax.eval_shape(lambda: model.init_caches(8, 128))
    specs = SH.cache_specs(caches, mesh, ma, 8)
    leaves = jax.tree.leaves(specs,
                             is_leaf=lambda x: hasattr(x, "spec"))
    assert leaves
