"""Golden-plan regression: the tuner's selected plan + objective for every
search-space preset x model config is pinned under tests/golden/.

A failure here means the tuning result CHANGED — cost model, schedule
template, Pareto selection, MILP, or search-space drift.  If the change is
intentional, regenerate and commit the fixtures:

    PYTHONPATH=src python tools/regen_golden.py

The failure message is a field-level diff against the fixture, so the
shape of the drift (objective only? a knob? the whole plan?) is visible
without re-running anything.
"""
import json

import pytest

from repro.core import golden

CASES = [(s, a) for s in golden.GOLDEN_SPACES for a in golden.GOLDEN_ARCHS]


@pytest.mark.parametrize("space,arch", CASES,
                         ids=[f"{s}-{a}" for s, a in CASES])
def test_plan_matches_golden(space, arch):
    path = golden.golden_path(space, arch)
    assert path.exists(), (
        f"missing golden fixture {path.name}; generate it with "
        f"`PYTHONPATH=src python tools/regen_golden.py`")
    want = json.loads(path.read_text())
    doc = golden.compute_doc(space, arch)
    if golden.fingerprint(doc) == want["fingerprint"]:
        return
    diff = golden.diff_docs(want["doc"], doc)
    lines = "\n  ".join(diff or ["<fingerprint mismatch but no field "
                                 "diff — fixture file corrupted?>"])
    pytest.fail(
        f"tuned plan drifted from golden fixture {path.name} "
        f"(golden != current):\n  {lines}\nIf this change is intentional, "
        f"regenerate with `PYTHONPATH=src python tools/regen_golden.py` "
        f"and commit the updated fixtures.")


def test_fixture_fingerprints_self_consistent():
    """Each checked-in fixture's fingerprint matches its own document —
    catches hand-edited fixtures independently of any tuning run."""
    n = 0
    for space, arch in CASES:
        path = golden.golden_path(space, arch)
        if not path.exists():
            continue
        data = json.loads(path.read_text())
        assert golden.fingerprint(data["doc"]) == data["fingerprint"], \
            f"{path.name}: fingerprint does not match its own doc"
        n += 1
    assert n, "no golden fixtures found"


def test_diff_docs_reports_field_paths():
    a = {"plan": {"stages": [{"tp": 2, "ao": 0.5}]}, "objective": "1.0"}
    b = {"plan": {"stages": [{"tp": 4, "ao": 0.5}]}, "objective": "1.1"}
    diff = golden.diff_docs(a, b)
    assert any("plan.stages[0].tp: 2 != 4" in d for d in diff)
    assert any("objective" in d for d in diff)
