"""Training substrate: checkpoint roundtrip/atomicity, stateless data
pipeline, fault-tolerant loop (fault injection, NaN rollback, stragglers)."""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.training.checkpoint import Checkpointer, _flatten, _unflatten
from repro.training.data import BatchSpec, PackedCorpus, SyntheticLM, \
    microbatched
from repro.training.loop import LoopConfig, LoopStats, TrainLoop


# -- checkpoint ------------------------------------------------------------------


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "step": np.int32(7),
        "params": {"a/w": rng.normal(size=(4, 8)).astype(np.float32),
                   "b/w": rng.normal(size=(3,)).astype(np.float32)},
        "mu": {"a/w": {"host": rng.normal(size=(2, 8)).astype(np.float32),
                       "dev": rng.normal(size=(2, 8)).astype(np.float32)}},
    }


def test_flatten_roundtrip():
    s = _state()
    assert _unflatten(_flatten(s)).keys() == s.keys()
    f = _flatten(s)
    assert "mu::a/w::host" in f


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    s = _state()
    ck.save(7, s, {"arch": "test"})
    step, restored, manifest = ck.restore()
    assert step == 7
    assert manifest["arch"] == "test"
    np.testing.assert_array_equal(restored["params"]["a/w"],
                                  s["params"]["a/w"])
    np.testing.assert_array_equal(restored["mu"]["a/w"]["host"],
                                  s["mu"]["a/w"]["host"])


def test_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _state())
    assert not list(tmp_path.glob(".tmp_*"))
    assert (tmp_path / "step_000000001" / "MANIFEST.json").exists()


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for i in (1, 2, 3, 4):
        ck.save(i, _state())
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save_async(5, _state())
    ck.wait()
    assert ck.latest_step() == 5


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(tmp_path, keep=5)
    for i in (1, 2, 3):
        st = _state(i)
        ck.save(i, st)
    step, restored, _ = ck.restore(2)
    assert step == 2
    np.testing.assert_array_equal(restored["params"]["a/w"],
                                  _state(2)["params"]["a/w"])


def test_elastic_reshard_to_device(tmp_path):
    """Restore with target shardings places leaves on the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": np.ones((4, 4), np.float32)})
    sh = {"w": NamedSharding(mesh, P("data", None))}
    _, restored, _ = ck.restore(shardings=sh)
    assert isinstance(restored["w"], jax.Array)
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)


# -- data -------------------------------------------------------------------------


def test_synthetic_deterministic():
    spec = BatchSpec(global_batch=8, seq_len=32, vocab_size=100)
    d = SyntheticLM(spec, seed=1)
    b1, b2 = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_shards_differ():
    a = SyntheticLM(BatchSpec(8, 32, 100, n_shards=2, shard=0), seed=1)
    b = SyntheticLM(BatchSpec(8, 32, 100, n_shards=2, shard=1), seed=1)
    assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])
    assert a.batch(0)["tokens"].shape == (4, 32)


def test_labels_are_shifted_tokens():
    d = SyntheticLM(BatchSpec(2, 16, 50), seed=0)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_packed_corpus_mask_and_bounds():
    docs = [np.arange(1, 6), np.arange(10, 30)]
    spec = BatchSpec(global_batch=4, seq_len=16, vocab_size=64)
    pc = PackedCorpus(docs, spec, seed=0)
    b = pc.batch(0)
    assert b["tokens"].shape == (4, 16)
    assert b["loss_mask"].shape == (4, 16)
    assert set(np.unique(b["loss_mask"])) <= {0.0, 1.0}
    np.testing.assert_array_equal(pc.batch(0)["tokens"], b["tokens"])


def test_microbatched_layout():
    b = {"tokens": np.arange(24).reshape(8, 3)}
    mb = microbatched(b, 4)
    assert mb["tokens"].shape == (4, 2, 3)
    np.testing.assert_array_equal(mb["tokens"].reshape(8, 3), b["tokens"])


# -- loop -------------------------------------------------------------------------


def _toy_step(lr=0.5):
    def step(state, batch):
        w = state["w"]
        loss = float(np.sum((w - 3.0) ** 2))
        return {"w": w - lr * 2 * (w - 3.0)}, {"loss": loss}
    return step


def _batches(step):
    return {"x": np.zeros((1,))}


def test_loop_runs_and_converges(tmp_path):
    loop = TrainLoop(_toy_step(), {"w": np.zeros((2,), np.float32)},
                     _batches, ckpt_dir=tmp_path,
                     cfg=LoopConfig(total_steps=20, ckpt_every=5))
    stats = loop.run()
    assert stats.steps_done == 20
    assert stats.losses[-1] < stats.losses[0]
    assert Checkpointer(tmp_path).latest_step() == 20


def test_loop_fault_injection_restores(tmp_path):
    calls = {"n": 0}

    def fault(step):
        if step == 12 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("simulated node failure")

    loop = TrainLoop(_toy_step(), {"w": np.zeros((2,), np.float32)},
                     _batches, ckpt_dir=tmp_path,
                     cfg=LoopConfig(total_steps=20, ckpt_every=5),
                     fault_hook=fault)
    stats = loop.run()
    assert stats.restarts == 1
    assert stats.steps_done >= 20   # steps 10..12 replayed after restore


def test_loop_exceeds_max_restarts(tmp_path):
    def always_fail(step):
        raise RuntimeError("dead node")

    loop = TrainLoop(_toy_step(), {"w": np.zeros((2,))}, _batches,
                     ckpt_dir=tmp_path,
                     cfg=LoopConfig(total_steps=5, max_restarts=2),
                     fault_hook=always_fail)
    with pytest.raises(RuntimeError):
        loop.run()


def test_loop_nan_rollback(tmp_path):
    hits = {"n": 0}

    def step(state, batch):
        w = state["w"]
        hits["n"] += 1
        if hits["n"] == 7:
            return {"w": w}, {"loss": float("nan")}
        return {"w": w + 1}, {"loss": 1.0}

    loop = TrainLoop(step, {"w": np.zeros((1,), np.float32)}, _batches,
                     ckpt_dir=tmp_path,
                     cfg=LoopConfig(total_steps=10, ckpt_every=2))
    stats = loop.run()
    assert stats.rollbacks == 1
    # replayed steps after the rollback also count as executed work
    assert stats.steps_done >= 10


def test_loop_straggler_detection(tmp_path):
    times = iter([0.01] * 8 + [0.2] + [0.01] * 11)

    def step(state, batch):
        time.sleep(next(times))
        return state, {"loss": 1.0}

    loop = TrainLoop(step, {"w": np.zeros((1,))}, _batches,
                     ckpt_dir=tmp_path,
                     cfg=LoopConfig(total_steps=20, straggler_factor=3.0))
    stats = loop.run()
    assert stats.straggler_events >= 1
