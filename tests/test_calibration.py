"""Calibration subsystem (docs/calibration.md): profile round-trip, the
frozen-default guarantee, attribution, and the exact-scaling fit."""
import dataclasses
import pickle

import numpy as np
import pytest

from repro.calibration import (DEFAULT_PROFILE, CalibrationProfile,
                               load_profile)
from repro.calibration.fit import (ITEM_GROUP, attribute_cell, fit_profile,
                                   predict_step_scaled, scales_to_overrides)
from repro.calibration.measure import MeasuredCell, _cell_plans
from repro.configs.base import get_arch
from repro.core.costmodel import CostParams, StageCostModel, estimate_plan
from repro.core.interference import _DEFAULT, InterferenceModel


def _mk_cells(arch="granite-3-8b", n_dev=4):
    """Synthetic MeasuredCells (plans only, no jax execution)."""
    cfg = get_arch(arch).reduced()
    cells = []
    for label, plan in _cell_plans(cfg, n_dev):
        st0 = plan.stages[0]
        gbs = st0.dp * st0.micro_batch * plan.grad_accum
        cells.append(MeasuredCell(
            label=f"{arch}/{label}", arch=arch, reduced=True, seq_len=128,
            global_batch=gbs, plan=plan, steps=0, step_seconds=(),
            t_measured=0.0))
    return cells


# ---------------------------------------------------------------------------
# CalibrationProfile
# ---------------------------------------------------------------------------


class TestProfile:
    def test_frozen_default_cost_params_identity(self):
        base = CostParams()
        assert DEFAULT_PROFILE.cost_params(base) is base
        assert DEFAULT_PROFILE.cost_params() == CostParams()

    def test_frozen_default_interference_is_default(self):
        assert DEFAULT_PROFILE.interference_model().factors == _DEFAULT

    def test_frozen_default_model_outputs_identical(self):
        """StageCostModel with the default profile is bitwise-identical to
        no profile at all — the golden-fixture guarantee."""
        cfg = get_arch("granite-3-8b")
        a = StageCostModel(cfg, 4096)
        b = StageCostModel(cfg, 4096, profile=DEFAULT_PROFILE)
        env = dict(b=2.0, dp=8.0, tp=2.0, zero=1.0, ckpt=4.0, wo=0.0,
                   go=0.0, oo=0.0, ao=0.0, L=40.0, G=4.0, inflight=1.0)
        ra, rb = a.evaluate(dict(env)), b.evaluate(dict(env))
        for k in ("t_step", "t_stable", "d_delta", "mem_peak"):
            np.testing.assert_array_equal(ra[k], rb[k])
        assert b.jax_auto_threshold == a.jax_auto_threshold

    def test_with_cost_merges_over_existing_overrides(self):
        """tools/calibrate_reserved.py folds runtime_reserved into a
        profile tools/calibrate.py already fitted: other overrides are
        preserved, the new one lands, nothing else changes."""
        base = CalibrationProfile.make(platform="cpu",
                                       cost={"mxu_eff_peak": 0.41})
        merged = base.with_cost(runtime_reserved=2.0 * 2**30)
        assert dict(merged.cost) == {"mxu_eff_peak": 0.41,
                                     "runtime_reserved": 2.0 * 2**30}
        cp = merged.cost_params(CostParams())
        assert cp.runtime_reserved == 2.0 * 2**30
        assert cp.mxu_eff_peak == 0.41
        # updating an existing override replaces, not duplicates
        again = merged.with_cost(runtime_reserved=1.0 * 2**30)
        assert dict(again.cost)["runtime_reserved"] == 1.0 * 2**30

    def test_with_cost_rejects_unknown_field(self):
        with pytest.raises(ValueError, match="unknown CostParams"):
            DEFAULT_PROFILE.with_cost(runtime_reservd=1.0)

    def test_reserved_override_frozen_default_bitwise_guard(self):
        """The runtime_reserved fold keeps the frozen-default guarantee:
        a no-override profile returns the base CostParams ITSELF, and an
        override touches runtime_reserved alone — every other field stays
        bit-identical."""
        base = CostParams()
        assert DEFAULT_PROFILE.with_cost().cost_params(base) is base
        cp = DEFAULT_PROFILE.with_cost(
            runtime_reserved=base.runtime_reserved + 64 * 2**20
        ).cost_params(base)
        assert cp.runtime_reserved == base.runtime_reserved + 64 * 2**20
        for f in dataclasses.fields(CostParams):
            if f.name in ("runtime_reserved",):
                continue
            assert getattr(cp, f.name) == getattr(base, f.name), f.name

    def test_round_trip(self):
        p = CalibrationProfile.make(
            platform="cpu", source="test",
            cost={"mxu_eff_peak": 0.5, "ici_eff": 0.3,
                  "coll_latency_us": 90.0},
            kernels={"attn_scale": 1.5},
            interference={(0, 1): (1.1, 1.2), (0, 1, 2): (1.2, 1.3, 1.4)},
            jax_auto_threshold=1024)
        q = CalibrationProfile.from_json(p.to_json())
        assert q == p
        cp = q.cost_params()
        assert cp.mxu_eff_peak == 0.5
        assert cp.coll_latency_us == 90.0
        assert cp.kernels.attn_scale == 1.5
        assert cp.vpu_tax == CostParams().vpu_tax   # untouched field
        assert q.interference_model().factors[(0, 1)] == (1.1, 1.2)
        assert q.jax_auto_threshold == 1024

    def test_unknown_override_rejected(self):
        with pytest.raises(ValueError, match="CostParams"):
            CalibrationProfile.make(cost={"not_a_field": 1.0})
        with pytest.raises(ValueError, match="KernelCoeffs"):
            CalibrationProfile.make(kernels={"nope": 1.0})

    def test_newer_version_rejected(self):
        with pytest.raises(ValueError, match="version"):
            CalibrationProfile.from_json('{"version": 999}')

    def test_save_load(self, tmp_path):
        p = CalibrationProfile.make(platform="cpu",
                                    cost={"host_eff": 0.4})
        path = p.save(tmp_path / "sub" / "cpu.json")
        assert CalibrationProfile.load(path) == p

    def test_load_profile_resolution(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_CALIBRATION_PROFILE", raising=False)
        # missing file -> frozen default
        assert load_profile("cpu") is DEFAULT_PROFILE
        p = CalibrationProfile.make(platform="cpu",
                                    cost={"ici_eff": 0.2})
        p.save(tmp_path / "cpu.json")
        assert load_profile("cpu") == p
        # explicit env path wins
        q = CalibrationProfile.make(platform="other",
                                    cost={"ici_eff": 0.3})
        q.save(tmp_path / "explicit.json")
        monkeypatch.setenv("REPRO_CALIBRATION_PROFILE",
                           str(tmp_path / "explicit.json"))
        assert load_profile("cpu") == q

    def test_hashable_and_picklable_in_tunespec(self):
        from repro.core.tuner import TuneSpec
        p = CalibrationProfile.make(platform="cpu",
                                    cost={"mxu_eff_peak": 0.4},
                                    interference={(0, 1): (1.2, 1.3)})
        hash(p)
        spec = TuneSpec(arch=get_arch("granite-3-8b").reduced(),
                        seq_len=128, global_batch=8, n_devices=4,
                        profile=p)
        spec2 = pickle.loads(pickle.dumps(spec))
        assert spec2.profile == p
        from repro.core.tuner import MistTuner
        tuner = MistTuner(spec2)
        assert tuner.cp.mxu_eff_peak == 0.4


# ---------------------------------------------------------------------------
# Attribution + fitting
# ---------------------------------------------------------------------------


class TestFit:
    def test_item_groups_cover_all_items(self):
        scm = StageCostModel(get_arch("granite-3-8b").reduced(), 128)
        assert set(ITEM_GROUP) == set(scm.items)

    def test_attribution_matches_estimate_plan(self):
        cell = _mk_cells()[0]
        attr = attribute_cell(cell)
        est = estimate_plan(cell.config(), cell.shape(), cell.plan)
        assert attr.t_step_pred == pytest.approx(est["t_step"], rel=1e-12)

    def test_scaled_surrogate_equals_rebuilt_model(self):
        """The exact-scaling claim: dividing channel totals by the group
        scales == rebuilding the model with the equivalent CostParams."""
        for cell in _mk_cells():
            scales = (1e-3, 1e-2, 1.0)
            attr = attribute_cell(cell)
            prof = CalibrationProfile.make(
                platform="cpu",
                cost=scales_to_overrides(scales, CostParams()))
            real = estimate_plan(cell.config(), cell.shape(), cell.plan,
                                 profile=prof)["t_step"]
            sur = predict_step_scaled(attr, scales, InterferenceModel())
            assert sur == pytest.approx(real, rel=1e-9)

    def test_fit_recovers_synthetic_scales(self):
        """Measurements fabricated by a known scaled profile are recovered:
        fitted error collapses, uncalibrated error is huge."""
        cells = _mk_cells()
        true = CalibrationProfile.make(
            platform="cpu",
            cost=scales_to_overrides((3e-4, 2e-3, 1.0), CostParams()))
        for c in cells:
            c.t_measured = estimate_plan(c.config(), c.shape(), c.plan,
                                         profile=true)["t_step"]
        prof, report = fit_profile(cells, platform="cpu",
                                   fit_interference=False)
        assert report["improved"]
        assert report["mean_err_fitted"] < 0.02
        assert report["mean_err_uncalibrated"] > 0.9
        # the fitted profile predicts through the real model too
        for c in cells:
            pred = estimate_plan(c.config(), c.shape(), c.plan,
                                 profile=prof)["t_step"]
            assert pred == pytest.approx(c.t_measured, rel=0.05)

    def test_fit_keep_if_better_guard(self):
        """When measurements equal the uncalibrated predictions, fitting
        must not make things worse (and should return ~the base)."""
        cells = _mk_cells(n_dev=2)
        for c in cells:
            c.t_measured = estimate_plan(c.config(), c.shape(),
                                         c.plan)["t_step"]
        _prof, report = fit_profile(cells, platform="cpu",
                                    fit_interference=False)
        assert (report["mean_err_fitted"]
                <= report["mean_err_uncalibrated"] + 1e-12)

    def test_non_default_kernel_cell_refused(self):
        from repro.core.plan import KernelConfig
        import dataclasses
        cell = _mk_cells()[0]
        cell.plan = dataclasses.replace(
            cell.plan, kernel=KernelConfig(attn_q_block=256))
        with pytest.raises(ValueError, match="kernel"):
            attribute_cell(cell)

    def test_flops_helper_inverts_time_at_default_kernels(self):
        """evaluate_flops + the public mxu_efficiency helper reproduce the
        tape's t_fwd exactly at default kernel configs — the benchmark's
        inversion path cannot drift from the model."""
        cfg = get_arch("granite-3-8b").reduced()
        scm = StageCostModel(cfg, 128)
        env = dict(b=2.0, dp=2.0, tp=1.0, zero=1.0, ckpt=0.0, wo=0.0,
                   go=0.0, oo=0.0, ao=0.0, L=float(cfg.num_layers), G=2.0)
        out = scm.evaluate(dict(env))
        fl = scm.evaluate_flops(dict(env))
        tok = env["b"] * scm.seq
        eff = float(scm.mxu_efficiency(tok))
        t_fwd_from_flops = (float(fl["fwd"]) * (1.0 + scm.cp.vpu_tax)
                            / (scm.hw.peak_flops_bf16 * eff))
        assert t_fwd_from_flops == pytest.approx(
            float(out["items"]["fwd"]), rel=1e-12)
        assert float(fl["bwd"]) == pytest.approx(2 * float(fl["fwd"]))


# ---------------------------------------------------------------------------
# Measurement (one real end-to-end cell — also covers the driver)
# ---------------------------------------------------------------------------


def test_measure_and_fit_end_to_end():
    """One real measured cell through measure_plan -> fit_profile: the
    tune->execute->measure loop on the host backend."""
    jax = pytest.importorskip("jax")
    from repro.calibration.measure import measure_cells

    cells, skipped = measure_cells(("granite-3-8b",), steps=2, warmup=1,
                                   seq_len=64, max_cells_per_arch=1)
    assert cells, f"no cells measured; skipped={skipped}"
    cell = cells[0]
    assert cell.t_measured > 0
    assert len(cell.step_seconds) == 2
    assert cell.memory["modeled_peak_bytes"] > 0
    prof, report = fit_profile(cells, platform="cpu")
    assert (report["mean_err_fitted"]
            <= report["mean_err_uncalibrated"] + 1e-12)
    assert report["n_cells"] == 1
